//! Connection-scale bench for the event-loop front end: one load
//! generator sweeps 100 / 1 000 / 10 000 concurrent connections, each
//! cell measured twice — JSON lines vs the length-prefixed binary
//! frame protocol — against one in-process server. Records p50/p99
//! latency and throughput per (connections × protocol) cell into
//! `BENCH_serve_scale.json`.
//!
//!     cargo bench --bench serve_scale      (or `make serve-scale-bench`)
//!
//! The generator is closed-loop (one in-flight request per
//! connection) and single-threaded over the same readiness reactor the
//! server uses, so both endpoints exercise the nonblocking path. Both
//! endpoints live in one process: ~2 fds per connection, so the 10k
//! cell needs a raised `RLIMIT_NOFILE`; the achieved limit is recorded
//! and any clamped sweep is reported, never silently truncated.
//!
//! Env knobs (CI smoke uses small values):
//!   HN_SERVE_SCALE_CONNS  comma list, default "100,1000,10000"
//!   HN_SERVE_SCALE_REQS   total requests per cell,
//!                         default max(2*conns, 2000) capped at 20000

use hashednets::serve::frame::{self, FrameReply};
use hashednets::serve::poll::{
    raise_nofile_limit, set_nonblocking, Interest, Poller, PollerKind,
};
use hashednets::serve::{Backend, Client, ModelConfig, ServeOptions, Server};
use hashednets::util::json::{num, obj, Json};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_scale.json");
const ARTIFACT: &str = "hashnet_3l_h100_o10_c1-8";
const N_IN: usize = 784;
/// Per-cell wall-clock budget; a cell that exceeds it is recorded as
/// truncated (with however many requests completed) instead of hanging.
const CELL_BUDGET: Duration = Duration::from_secs(180);

/// Minimal manifest for the paper's 784-100-10 HashNet at 1/8
/// compression — the native backend never touches the HLO files.
fn synth_manifest_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hn_serve_scale_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp manifest dir");
    let manifest = format!(
        r#"{{
  "n_in": 784,
  "artifacts": [{{
    "name": "{ARTIFACT}", "method": "hashnet",
    "dims": [784, 100, 10], "budgets": [9812, 126], "batch": 32,
    "seed_base": 2654435769, "uses_soft_targets": false,
    "compression": 0.125, "virtual_params": 79510, "stored_params": 9938,
    "params": [
      {{"name": "w0", "shape": [9812], "init_std": 0.0504}},
      {{"name": "w1", "shape": [126], "init_std": 0.1405}}
    ],
    "graphs": {{"train": "absent.train.hlo.txt", "predict": "absent.predict.hlo.txt"}}
  }}]
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).expect("write manifest");
    dir
}

#[derive(Clone, Copy, PartialEq)]
enum Wire {
    Json,
    Binary,
}

impl Wire {
    fn name(self) -> &'static str {
        match self {
            Wire::Json => "json",
            Wire::Binary => "binary",
        }
    }
}

/// One load-generator connection: closed loop, one in-flight request.
struct LoadConn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    outpos: usize,
    sent_at: Instant,
    done: bool,
}

struct CellResult {
    connections: usize,
    completed: usize,
    errors: usize,
    wall_s: f64,
    p50_us: f64,
    p99_us: f64,
    truncated: bool,
}

/// Run one (connections × protocol) cell against `addr`.
fn run_cell(addr: &str, wire: Wire, conns: usize, total_reqs: usize, pixels: &[f32]) -> CellResult {
    let json_line = {
        let arr: Vec<String> = pixels.iter().map(|p| format!("{p}")).collect();
        format!("{{\"pixels\": [{}]}}\n", arr.join(", "))
    };
    let mut frame_buf = Vec::new();
    frame::encode_request(&mut frame_buf, 1, "", 0, pixels);

    let mut poller = Poller::new(PollerKind::Auto).expect("client poller");
    let mut slots: Vec<LoadConn> = Vec::with_capacity(conns);
    for i in 0..conns {
        let stream = TcpStream::connect(addr).unwrap_or_else(|e| {
            panic!("connect #{i}/{conns}: {e} (raise the fd limit?)")
        });
        stream.set_nodelay(true).ok();
        set_nonblocking(&stream).expect("nonblocking");
        poller.register(stream.as_raw_fd(), i, Interest::READ).expect("register");
        slots.push(LoadConn {
            stream,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            outpos: 0,
            sent_at: Instant::now(),
            done: false,
        });
    }

    let payload: &[u8] = match wire {
        Wire::Json => json_line.as_bytes(),
        Wire::Binary => &frame_buf,
    };
    let mut remaining_sends = total_reqs.saturating_sub(conns);
    let mut completed = 0usize;
    let mut errors = 0usize;
    let mut latencies_us: Vec<f64> = Vec::with_capacity(total_reqs);
    let t0 = Instant::now();

    // prime every connection with its first request
    for c in slots.iter_mut() {
        c.outbuf.extend_from_slice(payload);
        c.sent_at = Instant::now();
    }
    let mut events = Vec::new();
    let mut truncated = false;
    while completed + errors < total_reqs {
        if t0.elapsed() > CELL_BUDGET {
            truncated = true;
            break;
        }
        if slots.iter().all(|c| c.done) {
            // dead connections took their unsent requests with them
            truncated = true;
            break;
        }
        // writes first: nonblocking, loopback buffers almost never fill
        for (i, c) in slots.iter_mut().enumerate() {
            if c.done || c.outpos >= c.outbuf.len() {
                continue;
            }
            loop {
                match c.stream.write(&c.outbuf[c.outpos..]) {
                    Ok(0) => {
                        c.done = true;
                        errors += 1;
                        break;
                    }
                    Ok(n) => {
                        c.outpos += n;
                        if c.outpos >= c.outbuf.len() {
                            c.outbuf.clear();
                            c.outpos = 0;
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                        let _ = poller.modify(c.stream.as_raw_fd(), i, Interest::BOTH);
                        break;
                    }
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.done = true;
                        errors += 1;
                        break;
                    }
                }
            }
        }
        poller.wait(&mut events, Some(Duration::from_millis(100))).expect("wait");
        for ev in events.iter().copied() {
            let c = &mut slots[ev.token];
            if c.done {
                continue;
            }
            if ev.writable {
                let _ = poller.modify(c.stream.as_raw_fd(), ev.token, Interest::READ);
            }
            if !ev.readable {
                continue;
            }
            let mut chunk = [0u8; 8192];
            loop {
                match c.stream.read(&mut chunk) {
                    Ok(0) => {
                        c.done = true;
                        errors += 1;
                        break;
                    }
                    Ok(n) => {
                        c.inbuf.extend_from_slice(&chunk[..n]);
                        if n < chunk.len() {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.done = true;
                        errors += 1;
                        break;
                    }
                }
            }
            // one reply completes one closed-loop request
            loop {
                let consumed = match wire {
                    Wire::Json => c
                        .inbuf
                        .iter()
                        .position(|&b| b == b'\n')
                        .map(|pos| {
                            (pos + 1, c.inbuf[..pos].windows(7).any(|w| w == b"\"class\""))
                        }),
                    Wire::Binary => frame::decode_reply(&c.inbuf)
                        .expect("reply frame")
                        .map(|(reply, used)| (used, matches!(reply, FrameReply::Ok { .. }))),
                };
                let Some((used, ok)) = consumed else { break };
                c.inbuf.drain(..used);
                if ok {
                    latencies_us.push(c.sent_at.elapsed().as_secs_f64() * 1e6);
                    completed += 1;
                } else {
                    errors += 1;
                }
                if remaining_sends > 0 && !c.done {
                    remaining_sends -= 1;
                    c.outbuf.extend_from_slice(payload);
                    c.sent_at = Instant::now();
                } else {
                    c.done = true;
                }
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    for c in slots.iter() {
        let _ = poller.deregister(c.stream.as_raw_fd());
    }
    drop(slots);

    latencies_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies_us.is_empty() {
            return 0.0;
        }
        let idx = ((latencies_us.len() as f64 * p) as usize).min(latencies_us.len() - 1);
        latencies_us[idx]
    };
    CellResult {
        connections: conns,
        completed,
        errors,
        wall_s,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        truncated,
    }
}

fn env_usize_list(key: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(key) {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .collect(),
        Err(_) => default.to_vec(),
    }
}

fn main() {
    let requested = env_usize_list("HN_SERVE_SCALE_CONNS", &[100, 1000, 10_000]);
    let max_conns = requested.iter().copied().max().unwrap_or(100);
    // both endpoints in this process: ~2 fds per connection + headroom
    let want = (2 * max_conns as u64) + 256;
    let achieved = raise_nofile_limit(want);
    println!("== serve_scale (nofile limit: {achieved}, want {want}) ==");

    let dir = synth_manifest_dir();
    let srv = Server::bind(ServeOptions {
        artifacts_dir: dir.clone(),
        models: vec![ModelConfig::new(ARTIFACT)],
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 4,
        max_wait: Duration::from_micros(500),
        // admission sized for the sweep: this bench measures front-end
        // wire cost, not overload rejection (serve_chaos covers that)
        max_pending: (2 * max_conns).max(1024),
        ..Default::default()
    })
    .expect("bind server");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    let pixels: Vec<f32> = (0..N_IN).map(|i| (i % 255) as f32 / 255.0).collect();
    let mut cells: Vec<Json> = Vec::new();
    for &req_conns in &requested {
        // never silently clamp: derate to the fd limit and say so
        let fd_cap = ((achieved.saturating_sub(64)) / 2) as usize;
        let conns = req_conns.min(fd_cap);
        if conns < req_conns {
            println!("!! {req_conns} connections derated to {conns} (fd limit {achieved})");
        }
        let total_reqs = std::env::var("HN_SERVE_SCALE_REQS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| (2 * conns).clamp(2000, 20_000))
            .max(conns);
        for wire in [Wire::Json, Wire::Binary] {
            let r = run_cell(&addr, wire, conns, total_reqs, &pixels);
            let rps = if r.wall_s > 0.0 { r.completed as f64 / r.wall_s } else { 0.0 };
            println!(
                "{:<7} c{:<6} {:>8.0} req/s   p50 {:>8.0} µs   p99 {:>8.0} µs   ({} ok / {} err{})",
                wire.name(),
                r.connections,
                rps,
                r.p50_us,
                r.p99_us,
                r.completed,
                r.errors,
                if r.truncated { ", TRUNCATED" } else { "" },
            );
            cells.push(obj(vec![
                ("name", Json::Str(format!("{} c{}", wire.name(), r.connections))),
                ("protocol", Json::Str(wire.name().to_string())),
                ("connections", num(r.connections as f64)),
                ("requested_connections", num(req_conns as f64)),
                ("requests", num(r.completed as f64)),
                ("errors", num(r.errors as f64)),
                ("wall_s", num(r.wall_s)),
                ("p50_us", num(r.p50_us)),
                ("p99_us", num(r.p99_us)),
                ("throughput_rps", num(rps)),
                ("truncated", Json::Bool(r.truncated)),
            ]));
        }
    }

    let mut c = Client::connect(&addr).expect("connect for shutdown");
    c.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
    std::fs::remove_dir_all(&dir).ok();

    let doc = obj(vec![
        ("bench", Json::Str("serve_scale".into())),
        ("nofile_limit", num(achieved as f64)),
        ("pixels_per_request", num(N_IN as f64)),
        ("cases", Json::Arr(cells)),
    ]);
    std::fs::write(OUT, doc.to_string()).expect("write bench json");
    println!("wrote {OUT}");
}
