//! Embedding-bag bench: sparse bag lookups over a million-row *virtual*
//! table that is never materialized — resident parameter memory is
//! bounded by K (the hashed bucket count), not by `rows × dim`.
//!
//! Two grids land in `BENCH_embed_bag.json` at the repo root:
//!
//!   * the virtual-table sweep — hashed forward at ≥1M virtual rows,
//!     bag sizes 10/50/200, compression 1/8 and 1/64 (plus one Eq. 12
//!     backward case per compression)
//!   * the roofline grid — at a row count small enough to materialize
//!     (default 100k), the same bag reduction through a dense
//!     `rows × dim` table vs the hashed path, so the price of
//!     hash-on-the-fly lookup is recorded rather than guessed
//!
//! Env knobs (CI smoke uses small values):
//!   HN_EMBED_BENCH_ROWS       virtual rows, default 1000000
//!   HN_EMBED_BENCH_ROOF_ROWS  roofline rows (dense table is
//!                             materialized!), default min(rows, 100000)
//!   HN_EMBED_BENCH_NBAGS      bags per request, default 64
//!
//!     cargo bench --bench embed_bag        # or: make embed-bench

use hashednets::hash::DEFAULT_SEED_BASE;
use hashednets::model::BagMode;
use hashednets::nn::{EmbedBag, TrainOptions};
use hashednets::tensor::Matrix;
use hashednets::util::bench::Bench;
use hashednets::util::rng::Pcg32;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_embed_bag.json");

const DIM: usize = 32;
const BAG_SIZES: [usize; 3] = [10, 50, 200];
const COMPRESSIONS: [usize; 2] = [8, 64];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `n_bags` bags of exactly `bag` random ids each, CSR layout.
fn fixed_bags(rng: &mut Pcg32, nc: usize, n_bags: usize, bag: usize) -> (Vec<u32>, Vec<u32>) {
    let mut indices = Vec::with_capacity(n_bags * bag);
    let mut offsets = Vec::with_capacity(n_bags);
    for _ in 0..n_bags {
        offsets.push(indices.len() as u32);
        for _ in 0..bag {
            indices.push(rng.next_u32() % nc as u32);
        }
    }
    (indices, offsets)
}

/// The roofline: the same sum-mode bag reduction through a fully
/// materialized `rows × dim` table (plain row indexing, no hashing).
fn dense_forward(table: &[f32], dim: usize, indices: &[u32], offsets: &[u32]) -> Vec<f32> {
    let n_bags = offsets.len();
    let mut out = vec![0.0f32; n_bags * dim];
    for b in 0..n_bags {
        let start = offsets[b] as usize;
        let end = offsets.get(b + 1).map(|&o| o as usize).unwrap_or(indices.len());
        let zrow = &mut out[b * dim..(b + 1) * dim];
        for &idx in &indices[start..end] {
            let row = &table[idx as usize * dim..(idx as usize + 1) * dim];
            for (o, &v) in zrow.iter_mut().zip(row) {
                *o += v;
            }
        }
    }
    out
}

fn mb(cells: usize) -> f64 {
    cells as f64 * 4.0 / (1024.0 * 1024.0)
}

fn main() {
    let rows = env_usize("HN_EMBED_BENCH_ROWS", 1_000_000);
    let roof_rows = rows.min(env_usize("HN_EMBED_BENCH_ROOF_ROWS", 100_000));
    let n_bags = env_usize("HN_EMBED_BENCH_NBAGS", 64);
    println!("== embed_bag: {rows} virtual rows x {DIM} dim, {n_bags} bags/request ==");
    let mut b = Bench::new(2, 12);
    let mut rng = Pcg32::new(0xE23A, 5);

    // --- virtual-table sweep: memory bounded by K, never by rows*dim --
    for c in COMPRESSIONS {
        let k = (rows * DIM / c).max(1);
        let mut bag = EmbedBag::new(rows, DIM, k, BagMode::Sum, DEFAULT_SEED_BASE);
        bag.init(&mut rng);
        // the acceptance claim, asserted not narrated: resident
        // parameter memory is exactly K floats
        assert_eq!(bag.w.len(), k);
        println!(
            "resident {:.1} MB (K={k}) for a {:.1} MB virtual table ({rows}x{DIM}, 1/{c})",
            mb(k),
            mb(rows * DIM)
        );
        for bag_size in BAG_SIZES {
            let (indices, offsets) = fixed_bags(&mut rng, rows, n_bags, bag_size);
            b.items_per_iter = Some((n_bags * bag_size) as f64);
            b.run(&format!("hashed fwd rows={rows} 1/{c} bag={bag_size}"), || {
                std::hint::black_box(bag.forward(&indices, &offsets));
            });
        }
        // one Eq. 12 backward case per compression (bag=50, ordered off)
        let (indices, offsets) = fixed_bags(&mut rng, rows, n_bags, 50);
        let delta = Matrix::from_fn(n_bags, DIM, |i, j| ((i * 13 + j) % 7) as f32 * 0.1 - 0.3);
        let opts = TrainOptions::default();
        let mut grad = vec![0.0f32; k];
        b.items_per_iter = Some((n_bags * 50) as f64);
        b.run(&format!("hashed bwd rows={rows} 1/{c} bag=50"), || {
            grad.iter_mut().for_each(|g| *g = 0.0);
            bag.backward(&indices, &offsets, &delta, &mut grad, &opts);
            std::hint::black_box(&grad);
        });
    }

    // --- roofline grid: dense table vs hashed at materializable size --
    println!("\n-- roofline at {roof_rows} rows (dense table {:.1} MB) --", mb(roof_rows * DIM));
    let mut roof_rng = Pcg32::new(0x500F, 9);
    let mut table = vec![0.0f32; roof_rows * DIM];
    for v in &mut table {
        *v = roof_rng.next_f32() - 0.5;
    }
    for bag_size in BAG_SIZES {
        let (indices, offsets) = fixed_bags(&mut rng, roof_rows, n_bags, bag_size);
        b.items_per_iter = Some((n_bags * bag_size) as f64);
        b.run(&format!("dense  fwd rows={roof_rows} bag={bag_size} (roofline)"), || {
            std::hint::black_box(dense_forward(&table, DIM, &indices, &offsets));
        });
        for c in COMPRESSIONS {
            let k = (roof_rows * DIM / c).max(1);
            let mut hb = EmbedBag::new(roof_rows, DIM, k, BagMode::Sum, DEFAULT_SEED_BASE);
            hb.init(&mut rng);
            b.run(&format!("hashed fwd rows={roof_rows} 1/{c} bag={bag_size} (roof)"), || {
                std::hint::black_box(hb.forward(&indices, &offsets));
            });
        }
    }

    // --- summary + JSON -----------------------------------------------
    let find = |needle: &str| {
        b.results().iter().find(|s| s.name.contains(needle)).map(|s| s.mean_ns)
    };
    for c in COMPRESSIONS {
        if let (Some(d), Some(h)) = (
            find(&format!("dense  fwd rows={roof_rows} bag=50")),
            find(&format!("hashed fwd rows={roof_rows} 1/{c} bag=50 (roof)")),
        ) {
            println!("hash-on-the-fly cost vs dense roofline at bag=50 (1/{c}): {:.2}x", h / d);
        }
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
