//! L1 hot-path bench: the hashed forward pass at the paper's layer
//! shape (784→1000 virtual, varying budget), three implementations:
//!
//!   * AOT artifact (Pallas decompress-on-the-fly matmul via PJRT)
//!   * native Rust engine (id-cache gather loop)
//!   * dense matmul of the materialized V (the memory-unconstrained
//!     roofline reference)
//!
//!     cargo bench --bench kernel_forward

use hashednets::coordinator::native;
use hashednets::data::{generate, Kind, Split};
use hashednets::nn::{Layer, LayerKind};
use hashednets::runtime::{Graph, ModelState, Runtime};
use hashednets::util::bench::Bench;
use hashednets::util::rng::Pcg32;

fn main() {
    println!("== kernel_forward (batch 50) ==");
    let mut b = Bench::new(2, 15);
    let ds = generate(Kind::Basic, Split::Test, 50, 1);

    // --- artifact path at two budgets --------------------------------
    if let Ok(rt) = Runtime::open("artifacts") {
        for name in ["hashnet_3l_h100_o10_c1-8", "hashnet_3l_h100_o10_c1-64"] {
            if rt.manifest.get(name).is_none() {
                continue;
            }
            let spec = rt.manifest.get(name).unwrap().clone();
            let state = ModelState::init(&spec, 1);
            let exe = rt.load(name, Graph::Predict).unwrap();
            b.items_per_iter = Some(50.0);
            b.run(&format!("artifact predict {name}"), || {
                std::hint::black_box(exe.predict(&state, &ds.images).unwrap());
            });
            // native twin on identical params
            let mut net = native::network_from_spec(&spec);
            native::load_params(&mut net, &spec, &state);
            net.predict(&ds.images); // build id caches outside the timer
            b.run(&format!("native  predict {name}"), || {
                std::hint::black_box(net.predict(&ds.images));
            });
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT rows)");
    }

    // --- single hashed layer vs dense roofline at paper width ---------
    let (m, n) = (784usize, 1000usize);
    let k = (m + 1) * n / 8;
    let mut rng = Pcg32::new(3, 3);
    let mut layer = Layer::new(m, n, LayerKind::Hashed { k }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    layer.init(&mut rng);
    let x = hashednets::tensor::Matrix::from_fn(50, m, |_, _| rng.normal());
    layer.forward(&x); // warm the id cache
    b.items_per_iter = Some(50.0);
    b.run("native hashed layer 784->1000 (K=98k)", || {
        std::hint::black_box(layer.forward(&x));
    });
    let v = layer.virtual_matrix();
    b.run("dense  matmul same shape (roofline ref)", || {
        std::hint::black_box(x.augment_ones().matmul_nt(&v));
    });
}
