//! L1 hot-path bench: the hashed forward pass at the paper's layer
//! shape (784→1000 virtual), every kernel variant at batch 1 and 50:
//!
//!   * AOT artifact (Pallas decompress-on-the-fly matmul via PJRT)
//!   * `gather`  — legacy per-row gather through the HashPlan
//!   * `scratch` — decompress each virtual row once, dense dot across
//!     the batch (the batch-amortized kernel, pool-parallel on big
//!     layers); also measured `cold-spawn`, i.e. the same partition on
//!     freshly spawned/joined OS threads, so the PoolExec win is
//!     recorded rather than asserted
//!   * `bucket`  — bucket-major accumulation (paper Eq. 10, B=1 small-K)
//!   * `inverse` — the CSR-by-bucket inverse-plan kernel (streams `w`
//!     in order; the B=1 serving default)
//!   * `dense`   — matmul of the materialized V (the roofline reference)
//!
//! Results land in `BENCH_kernel_forward.json` at the repo root.
//!
//!     cargo bench --bench kernel_forward

use hashednets::data::{generate, Kind, Split};
use hashednets::nn::{Layer, LayerKind, Network};
use hashednets::rt::pool;
use hashednets::runtime::{Graph, Runtime};
use hashednets::tensor::{dot_unrolled, Matrix};
use hashednets::util::bench::Bench;
use hashednets::util::rng::Pcg32;
use std::sync::Arc;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernel_forward.json");

/// The scratch-row kernel with the *old* execution strategy: identical
/// row partition, but on freshly spawned OS threads per call (the cost
/// every parallel site used to pay before PoolExec).
fn scratch_cold_spawn(layer: &Arc<Layer>, x: &Arc<Matrix>, threads: usize) -> Vec<f32> {
    let (m, n) = (layer.m, layer.n);
    let m1 = m + 1;
    let rows_b = x.rows;
    let rows_per = n.div_ceil(threads);
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let layer = Arc::clone(layer);
            let x = Arc::clone(x);
            std::thread::spawn(move || {
                let plan = layer.plan().expect("hashed layer").clone();
                let i0 = t * rows_per;
                let i1 = ((t + 1) * rows_per).min(n);
                let mut scratch = vec![0.0f32; m1];
                let mut out = vec![0.0f32; i1.saturating_sub(i0) * rows_b];
                for (r, zrow) in out.chunks_mut(rows_b).enumerate() {
                    plan.decompress_row_into(i0 + r, &layer.params, &mut scratch);
                    let bias = scratch[m];
                    for (bi, zv) in zrow.iter_mut().enumerate() {
                        *zv = bias + dot_unrolled(x.row(bi), &scratch[..m]);
                    }
                }
                out
            })
        })
        .collect();
    handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
}

fn main() {
    println!("== kernel_forward: hashed kernel variants at batch 1 / 50 ==");
    let mut b = Bench::new(2, 15);
    let ds = generate(Kind::Basic, Split::Test, 50, 1);
    pool::run(pool::max_concurrency(), |_| {}); // warm: workers spawned + parked

    // --- artifact path at two budgets (skipped without artifacts) -----
    if let Ok(rt) = Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")) {
        for name in ["hashnet_3l_h100_o10_c1-8", "hashnet_3l_h100_o10_c1-64"] {
            if rt.manifest.get(name).is_none() {
                continue;
            }
            let spec = rt.manifest.get(name).unwrap().clone();
            let state = spec.init_state(1);
            let exe = rt.load(name, Graph::Predict).unwrap();
            b.items_per_iter = Some(50.0);
            b.run(&format!("artifact predict {name}"), || {
                std::hint::black_box(exe.predict(&state, &ds.images).unwrap());
            });
            // native twin on identical params, built through the bundle
            // path (plans built at load time)
            let net = Network::from_bundle(&state.to_bundle(&spec).unwrap()).unwrap();
            b.run(&format!("native  predict {name}"), || {
                std::hint::black_box(net.predict(&ds.images));
            });
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT rows)");
    }

    // --- kernel grid at the paper width (K = virtual/8 ≈ 98k) ---------
    let (m, n) = (784usize, 1000usize);
    let k = (m + 1) * n / 8;
    let mut rng = Pcg32::new(3, 3);
    let mut layer = Layer::new(m, n, LayerKind::Hashed { k }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    layer.init(&mut rng);
    let v = layer.virtual_matrix();
    layer.forward_hashed_inverse(&Matrix::zeros(1, m)); // build + cache the inverse view
    for batch in [1usize, 50] {
        let x = Matrix::from_fn(batch, m, |_, _| rng.normal());
        b.items_per_iter = Some(batch as f64);
        b.run(&format!("gather  b{batch} 784->1000 K=98k"), || {
            std::hint::black_box(layer.forward_hashed_gather(&x));
        });
        b.run(&format!("scratch b{batch} 784->1000 K=98k"), || {
            std::hint::black_box(layer.forward_hashed_scratch(&x));
        });
        b.run(&format!("dense   b{batch} 784->1000 (roofline)"), || {
            std::hint::black_box(x.augment_ones().matmul_nt(&v));
        });
    }
    let x1_big = Matrix::from_fn(1, m, |_, _| rng.normal());
    b.items_per_iter = Some(1.0);
    b.run("inverse b1 784->1000 K=98k", || {
        std::hint::black_box(layer.forward_hashed_inverse(&x1_big));
    });

    // --- pool-warm vs cold-spawn: same partition, different substrate -
    let threads = pool::max_concurrency();
    let arc_layer = Arc::new(layer.clone());
    let arc_x = Arc::new(Matrix::from_fn(50, m, |_, _| rng.normal()));
    b.items_per_iter = Some(50.0);
    b.run(&format!("scratch b50 pool-warm  t{threads}"), || {
        std::hint::black_box(arc_layer.forward_hashed_scratch(&arc_x));
    });
    b.run(&format!("scratch b50 cold-spawn t{threads}"), || {
        std::hint::black_box(scratch_cold_spawn(&arc_layer, &arc_x, threads));
    });

    // --- B=1 small-K regime: gather vs bucket vs inverse --------------
    let k_small = m + 1;
    let mut small = Layer::new(m, n, LayerKind::Hashed { k: k_small }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    small.init(&mut rng);
    let x1 = Matrix::from_fn(1, m, |_, _| rng.normal());
    small.forward_hashed_inverse(&x1); // build + cache
    b.items_per_iter = Some(1.0);
    b.run("gather  b1 784->1000 K=785", || {
        std::hint::black_box(small.forward_hashed_gather(&x1));
    });
    b.run("bucket  b1 784->1000 K=785", || {
        std::hint::black_box(small.forward_hashed_bucket(&x1));
    });
    b.run("inverse b1 784->1000 K=785", || {
        std::hint::black_box(small.forward_hashed_inverse(&x1));
    });

    // --- speedup summary + JSON ---------------------------------------
    let find = |needle: &str| {
        b.results()
            .iter()
            .find(|s| s.name.contains(needle))
            .map(|s| s.mean_ns)
    };
    if let (Some(g), Some(s)) = (find("gather  b50"), find("scratch b50 784")) {
        println!("\nscratch-row speedup over legacy gather at batch 50: {:.2}x", g / s);
    }
    if let (Some(cold), Some(warm)) = (find("cold-spawn"), find("pool-warm")) {
        println!("pool-warm speedup over cold spawn/join at batch 50: {:.2}x", cold / warm);
    }
    for ksz in ["K=98k", "K=785"] {
        if let (Some(g), Some(i)) =
            (find(&format!("gather  b1 784->1000 {ksz}")), find(&format!("inverse b1 784->1000 {ksz}")))
        {
            println!("inverse-plan speedup over gather at batch 1 ({ksz}): {:.2}x", g / i);
        }
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
