//! L1 hot-path bench: the hashed forward pass at the paper's layer
//! shape (784→1000 virtual), every kernel variant at batch 1 and 50:
//!
//!   * AOT artifact (Pallas decompress-on-the-fly matmul via PJRT)
//!   * `gather`  — legacy per-row gather through the HashPlan
//!   * `scratch` — decompress each virtual row once, SIMD dense dot
//!     across the batch (the batch-amortized kernel, pool-parallel on
//!     big layers); also measured `cold-spawn`, i.e. the same partition
//!     on freshly spawned/joined OS threads, so the PoolExec win is
//!     recorded rather than asserted
//!   * `tiled`   — block-structured TilePlan kernel (`hashed_tile`):
//!     tile runs decompress contiguously, padded-activation f32x8 dots
//!   * `bucket`  — bucket-major accumulation (paper Eq. 10, B=1 small-K)
//!   * `inverse` — the CSR-by-bucket inverse-plan kernel (streams `w`
//!     in order; the B=1 serving default)
//!   * `dense`   — matmul of the materialized V (the roofline reference)
//!   * `dot8`    — the explicit-SIMD dot primitive, dispatched vs the
//!     bit-identical scalar twin, at the layer's padded row width
//!
//! Results land in `BENCH_kernel_forward.json` at the repo root as an
//! object: `{"avx2": 0|1, "m": …, "n": …, "k": …, "cases": […]}` —
//! forward cases carry a `gflops` field (2·B·n·(m+1) flops per call)
//! so `tools/bench_diff.py` can gate on compute throughput, not just
//! latency. `HN_KERNEL_BENCH_DIMS=MxN` (e.g. `96x64`) shrinks the
//! layer for CI smoke runs; `HN_KERNEL_BENCH_ITERS` caps samples.
//!
//!     cargo bench --bench kernel_forward

use hashednets::data::{generate, Kind, Split};
use hashednets::nn::{Layer, LayerKind, Network};
use hashednets::rt::pool;
use hashednets::runtime::{Graph, Runtime};
use hashednets::tensor::{dot_unrolled, simd, Matrix};
use hashednets::util::bench::Bench;
use hashednets::util::json::{num, obj, Json};
use hashednets::util::rng::Pcg32;
use std::sync::Arc;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernel_forward.json");

/// `MxN` layer shape override for smoke runs (`HN_KERNEL_BENCH_DIMS`).
fn bench_dims() -> (usize, usize) {
    match std::env::var("HN_KERNEL_BENCH_DIMS") {
        Ok(v) => {
            let parse = |s: &str| s.trim().parse::<usize>().ok().filter(|&d| d > 0);
            match v.split_once('x').and_then(|(a, b)| parse(a).zip(parse(b))) {
                Some(dims) => dims,
                None => {
                    eprintln!("ignoring malformed HN_KERNEL_BENCH_DIMS='{v}' (want MxN)");
                    (784, 1000)
                }
            }
        }
        Err(_) => (784, 1000),
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The scratch-row kernel with the *old* execution strategy: identical
/// row partition, but on freshly spawned OS threads per call (the cost
/// every parallel site used to pay before PoolExec).
fn scratch_cold_spawn(layer: &Arc<Layer>, x: &Arc<Matrix>, threads: usize) -> Vec<f32> {
    let (m, n) = (layer.m, layer.n);
    let m1 = m + 1;
    let rows_b = x.rows;
    let rows_per = n.div_ceil(threads);
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let layer = Arc::clone(layer);
            let x = Arc::clone(x);
            std::thread::spawn(move || {
                let plan = layer.plan().expect("hashed layer").clone();
                let i0 = t * rows_per;
                let i1 = ((t + 1) * rows_per).min(n);
                let mut scratch = vec![0.0f32; m1];
                let mut out = vec![0.0f32; i1.saturating_sub(i0) * rows_b];
                for (r, zrow) in out.chunks_mut(rows_b).enumerate() {
                    plan.decompress_row_into(i0 + r, &layer.params, &mut scratch);
                    let bias = scratch[m];
                    for (bi, zv) in zrow.iter_mut().enumerate() {
                        *zv = bias + dot_unrolled(x.row(bi), &scratch[..m]);
                    }
                }
                out
            })
        })
        .collect();
    handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
}

fn main() {
    let avx2 = simd::avx2();
    println!(
        "== kernel_forward: hashed kernel variants at batch 1 / 50 (avx2: {}) ==",
        if avx2 { "yes" } else { "no (scalar dispatch)" }
    );
    let mut b = Bench::new(2, env_usize("HN_KERNEL_BENCH_ITERS", 15));
    let ds = generate(Kind::Basic, Split::Test, 50, 1);
    pool::run(pool::max_concurrency(), |_| {}); // warm: workers spawned + parked

    // --- artifact path at two budgets (skipped without artifacts) -----
    if let Ok(rt) = Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")) {
        for name in ["hashnet_3l_h100_o10_c1-8", "hashnet_3l_h100_o10_c1-64"] {
            if rt.manifest.get(name).is_none() {
                continue;
            }
            let spec = rt.manifest.get(name).unwrap().clone();
            let state = spec.init_state(1);
            let exe = rt.load(name, Graph::Predict).unwrap();
            b.items_per_iter = Some(50.0);
            b.run(&format!("artifact predict {name}"), || {
                std::hint::black_box(exe.predict(&state, &ds.images).unwrap());
            });
            // native twin on identical params, built through the bundle
            // path (plans built at load time)
            let net = Network::from_bundle(&state.to_bundle(&spec).unwrap()).unwrap();
            b.run(&format!("native  predict {name}"), || {
                std::hint::black_box(net.predict(&ds.images));
            });
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT rows)");
    }

    // --- kernel grid at the paper width (K = virtual/8 ≈ 98k) ---------
    let (m, n) = bench_dims();
    let shape = format!("{m}->{n}");
    let k = ((m + 1) * n / 8).max(64);
    let kk = format!("K={}k", (k as f64 / 1000.0).round() as usize);
    let mut rng = Pcg32::new(3, 3);
    let mut layer = Layer::new(m, n, LayerKind::Hashed { k }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    layer.init(&mut rng);
    let v = layer.virtual_matrix();
    layer.forward_hashed_inverse(&Matrix::zeros(1, m)); // build + cache the inverse view
    // same budget, block-structured: vector rows and square tiles
    let tiled: Vec<(String, Layer)> = [(1usize, 8usize), (8, 8)]
        .iter()
        .map(|&tile| {
            let mut l = Layer::new(
                m,
                n,
                LayerKind::HashedTile { k, tile },
                0,
                hashednets::hash::DEFAULT_SEED_BASE,
            );
            l.init(&mut rng);
            (format!("{}x{}", tile.0, tile.1), l)
        })
        .collect();
    for batch in [1usize, 50] {
        let x = Matrix::from_fn(batch, m, |_, _| rng.normal());
        b.items_per_iter = Some(batch as f64);
        b.run(&format!("gather  b{batch} {shape} {kk}"), || {
            std::hint::black_box(layer.forward_hashed_gather(&x));
        });
        b.run(&format!("scratch b{batch} {shape} {kk}"), || {
            std::hint::black_box(layer.forward_hashed_scratch(&x));
        });
        for (tag, tl) in &tiled {
            b.run(&format!("tiled{tag} b{batch} {shape} {kk}"), || {
                std::hint::black_box(tl.forward_hashed_tiled(&x));
            });
        }
        b.run(&format!("dense   b{batch} {shape} (roofline)"), || {
            std::hint::black_box(x.augment_ones().matmul_nt(&v));
        });
    }
    let x1_big = Matrix::from_fn(1, m, |_, _| rng.normal());
    b.items_per_iter = Some(1.0);
    b.run(&format!("inverse b1 {shape} {kk}"), || {
        std::hint::black_box(layer.forward_hashed_inverse(&x1_big));
    });

    // --- the SIMD primitive itself: dispatched vs scalar twin ---------
    let row_w = m + 1;
    let pa: Vec<f32> = (0..row_w).map(|_| rng.normal()).collect();
    let pb: Vec<f32> = (0..row_w).map(|_| rng.normal()).collect();
    b.items_per_iter = None;
    b.run(&format!("dot8 dispatch m{row_w}"), || {
        std::hint::black_box(simd::dot8(&pa, &pb));
    });
    b.run(&format!("dot8 scalar   m{row_w}"), || {
        std::hint::black_box(simd::dot8_scalar(&pa, &pb));
    });

    // --- pool-warm vs cold-spawn: same partition, different substrate -
    let threads = pool::max_concurrency();
    let arc_layer = Arc::new(layer.clone());
    let arc_x = Arc::new(Matrix::from_fn(50, m, |_, _| rng.normal()));
    b.items_per_iter = Some(50.0);
    b.run(&format!("scratch b50 pool-warm  t{threads}"), || {
        std::hint::black_box(arc_layer.forward_hashed_scratch(&arc_x));
    });
    b.run(&format!("scratch b50 cold-spawn t{threads}"), || {
        std::hint::black_box(scratch_cold_spawn(&arc_layer, &arc_x, threads));
    });

    // --- B=1 small-K regime: gather vs bucket vs inverse --------------
    let k_small = m + 1;
    let mut small = Layer::new(m, n, LayerKind::Hashed { k: k_small }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    small.init(&mut rng);
    let x1 = Matrix::from_fn(1, m, |_, _| rng.normal());
    small.forward_hashed_inverse(&x1); // build + cache
    b.items_per_iter = Some(1.0);
    b.run(&format!("gather  b1 {shape} K={k_small}"), || {
        std::hint::black_box(small.forward_hashed_gather(&x1));
    });
    b.run(&format!("bucket  b1 {shape} K={k_small}"), || {
        std::hint::black_box(small.forward_hashed_bucket(&x1));
    });
    b.run(&format!("inverse b1 {shape} K={k_small}"), || {
        std::hint::black_box(small.forward_hashed_inverse(&x1));
    });

    // --- speedup summary + JSON ---------------------------------------
    let find = |needle: &str| {
        b.results()
            .iter()
            .find(|s| s.name.contains(needle))
            .map(|s| s.mean_ns)
    };
    if let (Some(g), Some(s)) = (find("gather  b50"), find(&format!("scratch b50 {shape}"))) {
        println!("\nscratch-row speedup over legacy gather at batch 50: {:.2}x", g / s);
    }
    for batch in [1usize, 50] {
        if let (Some(s), Some(t)) = (
            find(&format!("scratch b{batch} {shape}")),
            find(&format!("tiled1x8 b{batch}")),
        ) {
            println!("tiled1x8 speedup over per-cell scratch at batch {batch}: {:.2}x", s / t);
        }
    }
    if let (Some(i), Some(t)) = (find("inverse b1"), find("tiled1x8 b1")) {
        println!("tiled1x8 vs inverse-plan at batch 1: {:.2}x", i / t);
    }
    if let (Some(cold), Some(warm)) = (find("cold-spawn"), find("pool-warm")) {
        println!("pool-warm speedup over cold spawn/join at batch 50: {:.2}x", cold / warm);
    }
    let ks_small = format!("K={k_small}");
    for ksz in [kk.as_str(), ks_small.as_str()] {
        if let (Some(g), Some(i)) =
            (find(&format!("gather  b1 {shape} {ksz}")), find(&format!("inverse b1 {shape} {ksz}")))
        {
            println!("inverse-plan speedup over gather at batch 1 ({ksz}): {:.2}x", g / i);
        }
    }

    // Object schema: top-level run metadata + per-case metrics. Forward
    // cases get gflops (2·B·n·(m+1) flops per call) so throughput is
    // comparable across machines that shift latency uniformly.
    let cases = Json::Arr(
        b.results()
            .iter()
            .map(|s| {
                let mut fields = vec![
                    ("name", Json::Str(s.name.clone())),
                    ("iters", num(s.iters as f64)),
                    ("mean_ns", num(s.mean_ns)),
                    ("stddev_ns", num(s.stddev_ns)),
                    ("p50_ns", num(s.p50_ns)),
                    ("p95_ns", num(s.p95_ns)),
                    ("throughput", s.throughput.map(num).unwrap_or(Json::Null)),
                ];
                // only the single-layer kernel-grid rows, where the
                // dense-equivalent flop count is well defined
                if let Some(tp) = s.throughput.filter(|_| s.name.contains(&shape)) {
                    let items = tp * (s.mean_ns / 1e9);
                    let flops = items * 2.0 * (n as f64) * ((m + 1) as f64);
                    fields.push(("gflops", num(flops / s.mean_ns)));
                }
                obj(fields)
            })
            .collect(),
    );
    let doc = obj(vec![
        ("avx2", num(if avx2 { 1.0 } else { 0.0 })),
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("k", num(k as f64)),
        ("cases", cases),
    ]);
    std::fs::write(OUT, doc.to_string()).expect("write bench json");
    println!("wrote {OUT}");
}
