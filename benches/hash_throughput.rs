//! Microbench: xxh32 and virtual-matrix decompression throughput — the
//! scalar cost floor under every hashed layer (L3 native path).
//!
//!     cargo bench --bench hash_throughput

use hashednets::hash::{bucket_sign, layer_seeds, xxh32_bytes, xxh32_u32, DEFAULT_SEED_BASE};
use hashednets::util::bench::Bench;

fn main() {
    println!("== hash_throughput ==");
    let mut b = Bench::new(3, 30);

    // 4-byte key path (the virtual-matrix hot path)
    let n_keys = 1_000_000u32;
    b.items_per_iter = Some(n_keys as f64);
    b.run("xxh32_u32 x 1M keys", || {
        let mut acc = 0u32;
        for k in 0..n_keys {
            acc = acc.wrapping_add(xxh32_u32(k, 0x9E37_79B9));
        }
        std::hint::black_box(acc);
    });

    // bucket + sign (two hashes + mod)
    let (s_h, s_xi) = layer_seeds(0, DEFAULT_SEED_BASE);
    b.items_per_iter = Some(n_keys as f64);
    b.run("bucket_sign x 1M cells (K=9813)", || {
        let mut acc = 0u32;
        let mut sgn = 0.0f32;
        for c in 0..n_keys {
            let (bkt, sg) = bucket_sign(c / 785, c % 785, 785, 9813, s_h, s_xi);
            acc = acc.wrapping_add(bkt);
            sgn += sg;
        }
        std::hint::black_box((acc, sgn));
    });

    // long-input path (spec-complete stripes)
    let blob = vec![0xA5u8; 1 << 20];
    b.items_per_iter = Some((1 << 20) as f64);
    let s = b.run("xxh32 bytes x 1MiB", || {
        std::hint::black_box(xxh32_bytes(&blob, 7));
    });
    println!(
        "   -> {:.2} GB/s on the byte path",
        s.throughput.unwrap_or(0.0) / 1e9
    );

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hash_throughput.json");
    b.write_json(out).expect("write bench json");
    println!("wrote {out}");
}
