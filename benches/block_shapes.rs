//! Perf-pass A/B: L1 tiling variants of the same hashed config
//! (emitted by `python -m compile.perf_variants`). Measures train-step
//! and predict latency per BlockSpec choice.
//!
//!     cargo bench --bench block_shapes

use hashednets::data::{generate, Kind, Split};
use hashednets::runtime::{Graph, Hyper, Runtime};
use hashednets::util::bench::Bench;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_block_shapes.json");

fn main() {
    println!("== block_shapes: L1 tiling A/B (hashnet 3l h100 c1/8) ==");
    let rt = match Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")) {
        Ok(rt) => rt,
        Err(_) => {
            println!("artifacts missing");
            Bench::default().write_json(OUT).expect("write bench json");
            return;
        }
    };
    let ds = generate(Kind::Basic, Split::Train, 64, 1);
    let mut b = Bench::new(3, 20);
    let mut any = false;
    for name in [
        "hashnet_3l_h100_o10_c1-8_b64x128",
        "hashnet_3l_h100_o10_c1-8_b128x256",
        "hashnet_3l_h100_o10_c1-8_b128x785",
        "hashnet_3l_h100_o10_c1-8_b256x256",
        // wide-layer variants (785->800 virtual) where tiling actually binds
        "hashnet_3l_b50_o10_x16_b128x256",
        "hashnet_3l_b50_o10_x16_b256x256",
        "hashnet_3l_b50_o10_x16_b512x785",
    ] {
        let Some(spec) = rt.manifest.get(name).cloned() else { continue };
        any = true;
        let mut state = spec.init_state(1);
        let train = rt.load(name, Graph::Train).unwrap();
        let predict = rt.load(name, Graph::Predict).unwrap();
        let (x, y) = ds.gather_batch(&(0..50u32).collect::<Vec<_>>(), spec.batch);
        let mut seed = 0u32;
        b.run(&format!("train {name}"), || {
            seed += 1;
            std::hint::black_box(
                train.train_step(&mut state, &x, &y, None, &Hyper::default(), seed).unwrap(),
            );
        });
        b.run(&format!("pred  {name}"), || {
            std::hint::black_box(predict.predict(&state, &x).unwrap());
        });
    }
    if !any {
        println!("variants missing — run `cd python && python -m compile.perf_variants`");
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
