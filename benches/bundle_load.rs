//! Bundle load bench: HNMB v1 read-parse-copy vs HNMB v2 mmap.
//!
//! The serve registry keeps every resident model's parameters alive for
//! the life of the process, so *load latency* and *resident heap bytes*
//! are the costs that scale with fleet size. Three load paths over the
//! same trained hashnet ([784,100,10], budgets [9812,126] — the paper's
//! MNIST 1/8 shape):
//!
//!   * `v1-copy`       — `ModelBundle::load` + `Network::from_bundle`:
//!                       read the file, checksum, copy every tensor onto
//!                       the heap (the only path before v2)
//!   * `v2-mmap`       — `BundleMap::open` + `Network::from_bundle_map`:
//!                       map the file, checksum once, borrow f32 tensors
//!                       in place (heap cost ≈ the dense layers only)
//!   * `v2-int8-deq`   — same mmap open over an int8-quantized bundle;
//!                       tensors dequantize onto the heap at load, the
//!                       file on disk stays ~4x smaller
//!
//! Each case loads N models back-to-back and keeps them resident, for N
//! in `HN_BUNDLE_BENCH_MODELS` (default `1,10,50,200`; CI smoke shrinks
//! it). `BENCH_bundle_load.json` lands at the repo root with per-case
//! `mean_ns`/`p50_ns`/`p95_ns` plus `heap_param_bytes` (owned f32 heap
//! across all resident models) and `mapped_file_bytes` (bytes served
//! straight from the page cache).
//!
//! The v2-int8 acceptance claim is asserted here, not narrated: the
//! int8 file must be ≥3.5x smaller than the v1 f32 file.
//!
//!     cargo bench --bench bundle_load      # or: make bundle-bench

use std::path::PathBuf;
use std::sync::Arc;

use hashednets::model::{BundleMap, Method, ModelBundle, ModelSpec, QuantSpec};
use hashednets::nn::Network;
use hashednets::util::bench::Bench;
use hashednets::util::json::{num, obj, Json};
use hashednets::util::rng::Pcg32;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_bundle_load.json");

const DIMS: [usize; 3] = [784, 100, 10];
const BUDGETS: [usize; 2] = [9812, 126];

fn model_counts() -> Vec<usize> {
    let raw = std::env::var("HN_BUNDLE_BENCH_MODELS").unwrap_or_else(|_| "1,10,50,200".into());
    let counts: Vec<usize> = raw.split(',').filter_map(|t| t.trim().parse().ok()).collect();
    if counts.is_empty() {
        vec![1, 10, 50, 200]
    } else {
        counts
    }
}

/// Owned f32 parameter bytes across all resident models — mmap-borrowed
/// stores cost file cache, not heap, and are excluded here.
fn heap_param_bytes(nets: &[Network]) -> usize {
    nets.iter()
        .flat_map(|n| n.layers.iter())
        .filter(|l| !l.params.is_mapped())
        .map(|l| l.params.len() * 4)
        .sum()
}

fn main() {
    let counts = model_counts();
    let dir = std::env::temp_dir().join(format!("hn_bundle_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench temp dir");

    // One trained-shape hashnet, deterministically initialized, written
    // out three ways: legacy v1, v2 f32, v2 int8.
    let spec = ModelSpec::new(
        "bench_hashnet",
        Method::Hashnet,
        DIMS.to_vec(),
        BUDGETS.to_vec(),
        0x9E37_79B9,
        16,
    )
    .expect("bench spec");
    let mut net = Network::from_spec(&spec).expect("skeleton");
    net.init(&mut Pcg32::new(0xB0DE, 7));
    let bundle = net.to_bundle(&spec).expect("to_bundle");

    let v1_path = dir.join("model_v1.hnb");
    let v2_path = dir.join("model_v2.hnb");
    let int8_path = dir.join("model_int8.hnb");
    std::fs::write(&v1_path, bundle.to_bytes_v1().expect("v1 bytes")).expect("write v1");
    bundle.save(&v2_path).expect("save v2");
    bundle.quantize(QuantSpec::Int8).expect("int8").save(&int8_path).expect("save int8");

    let fsize = |p: &PathBuf| std::fs::metadata(p).expect("stat").len() as usize;
    let (v1_bytes, v2_bytes, int8_bytes) = (fsize(&v1_path), fsize(&v2_path), fsize(&int8_path));
    let ratio = v1_bytes as f64 / int8_bytes as f64;
    println!(
        "== bundle_load: v1 {v1_bytes} B, v2 f32 {v2_bytes} B, v2 int8 {int8_bytes} B \
         ({ratio:.2}x vs v1) =="
    );
    // the acceptance claim, asserted not narrated
    assert!(ratio >= 3.5, "int8 bundle only {ratio:.2}x smaller than v1 (need >=3.5x)");

    let mut b = Bench::new(1, 5);
    let mut cells: Vec<Json> = Vec::new();
    for &m in &counts {
        // -- v1: read + checksum + copy every tensor onto the heap ------
        let mut nets: Vec<Network> = Vec::new();
        b.items_per_iter = Some(m as f64);
        let s = b.run(&format!("v1-copy models={m}"), || {
            nets.clear();
            for _ in 0..m {
                let bundle = ModelBundle::load(&v1_path).expect("load v1");
                nets.push(Network::from_bundle(&bundle).expect("from_bundle"));
            }
        });
        cells.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("models", num(m as f64)),
            ("mean_ns", num(s.mean_ns)),
            ("p50_ns", num(s.p50_ns)),
            ("p95_ns", num(s.p95_ns)),
            ("throughput", s.throughput.map(num).unwrap_or(Json::Null)),
            ("heap_param_bytes", num(heap_param_bytes(&nets) as f64)),
            ("mapped_file_bytes", num(0.0)),
        ]));

        // -- v2 f32: mmap + checksum, hashed tensors borrowed in place --
        let s = b.run(&format!("v2-mmap models={m}"), || {
            nets.clear();
            for _ in 0..m {
                let map = Arc::new(BundleMap::open(&v2_path).expect("open v2"));
                nets.push(Network::from_bundle_map(&map).expect("from_bundle_map"));
            }
        });
        let mapped = nets
            .iter()
            .flat_map(|n| n.layers.iter())
            .filter(|l| l.params.is_mapped())
            .count();
        cells.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("models", num(m as f64)),
            ("mean_ns", num(s.mean_ns)),
            ("p50_ns", num(s.p50_ns)),
            ("p95_ns", num(s.p95_ns)),
            ("throughput", s.throughput.map(num).unwrap_or(Json::Null)),
            ("heap_param_bytes", num(heap_param_bytes(&nets) as f64)),
            ("mapped_file_bytes", num((v2_bytes * m) as f64)),
        ]));
        if m == counts[0] {
            println!("   ({mapped} of {} layer stores borrow from the mapping)", nets.len() * 2);
        }

        // -- v2 int8: mmap + checksum, dequantize-on-load ---------------
        let s = b.run(&format!("v2-int8-deq models={m}"), || {
            nets.clear();
            for _ in 0..m {
                let map = Arc::new(BundleMap::open(&int8_path).expect("open int8"));
                nets.push(Network::from_bundle_map(&map).expect("from_bundle_map int8"));
            }
        });
        cells.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("models", num(m as f64)),
            ("mean_ns", num(s.mean_ns)),
            ("p50_ns", num(s.p50_ns)),
            ("p95_ns", num(s.p95_ns)),
            ("throughput", s.throughput.map(num).unwrap_or(Json::Null)),
            ("heap_param_bytes", num(heap_param_bytes(&nets) as f64)),
            ("mapped_file_bytes", num((int8_bytes * m) as f64)),
        ]));
    }

    let doc = obj(vec![
        ("bench", Json::Str("bundle_load".into())),
        ("v1_file_bytes", num(v1_bytes as f64)),
        ("v2_file_bytes", num(v2_bytes as f64)),
        ("v2_int8_file_bytes", num(int8_bytes as f64)),
        ("int8_size_ratio", num(ratio)),
        ("cases", Json::Arr(cells)),
    ]);
    std::fs::write(OUT, doc.to_string()).expect("write bench json");
    println!("wrote {OUT}");
    std::fs::remove_dir_all(&dir).ok();
}
