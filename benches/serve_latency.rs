//! Serving bench: request latency and throughput through the dynamic
//! batcher, sweeping the two backends and the native worker count —
//! the scaling evidence for the shared-batcher multi-worker design
//! (N threads × one model), not an assertion.
//!
//!     cargo bench --bench serve_latency     (or `make serve-bench`)
//!
//! Cases: native backend at 1/2/4 workers, runtime (PJRT) backend at
//! its pinned 1 worker when artifacts are available. The native engine
//! needs only `manifest.json` — when `make artifacts` has not run, a
//! manifest for the paper's 784-100-10 HashNet at 1/8 compression is
//! synthesized so the native sweep always measures something.

use hashednets::data::{generate, Dataset, Kind, Split};
use hashednets::serve::{Backend, Client, ModelConfig, ServeOptions, Server};
use hashednets::util::bench::{Bench, BenchStats};
use std::path::PathBuf;
use std::time::{Duration, Instant};

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_latency.json");
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
const ARTIFACT: &str = "hashnet_3l_h100_o10_c1-8";

/// Write a minimal manifest for the 784-100-10 HashNet at 1/8
/// compression: enough for the native backend (which never touches the
/// HLO graph files).
fn synth_manifest_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hn_serve_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp manifest dir");
    let manifest = format!(
        r#"{{
  "n_in": 784,
  "artifacts": [{{
    "name": "{ARTIFACT}", "method": "hashnet",
    "dims": [784, 100, 10], "budgets": [9812, 126], "batch": 32,
    "seed_base": 2654435769, "uses_soft_targets": false,
    "compression": 0.125, "virtual_params": 79510, "stored_params": 9938,
    "params": [
      {{"name": "w0", "shape": [9812], "init_std": 0.0504}},
      {{"name": "w1", "shape": [126], "init_std": 0.1405}}
    ],
    "graphs": {{"train": "absent.train.hlo.txt", "predict": "absent.predict.hlo.txt"}}
  }}]
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).expect("write manifest");
    dir
}

fn run_case(
    b: &mut Bench,
    dir: &std::path::Path,
    backend: Backend,
    workers: usize,
    ds: &Dataset,
    label: &str,
) -> bool {
    let opts = ServeOptions {
        artifacts_dir: dir.to_path_buf(),
        models: vec![ModelConfig::new(ARTIFACT)],
        addr: "127.0.0.1:0".into(),
        backend,
        workers,
        max_wait: Duration::from_micros(500),
        ..Default::default()
    };
    let srv = match Server::bind(opts) {
        Ok(s) => s,
        Err(e) => {
            println!("{label}: skipped ({e:#})");
            return false;
        }
    };
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    let n_clients = 8usize;
    let reqs_per_client = 40usize;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let rows: Vec<Vec<f32>> =
            (0..reqs_per_client).map(|i| ds.images.row((c + i) % 64).to_vec()).collect();
        handles.push(std::thread::spawn(move || -> Vec<u64> {
            let mut client = Client::connect(&addr).expect("connect");
            rows.iter().map(|r| client.classify(r).expect("classify").2).collect()
        }));
    }
    let mut lat: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    lat.sort_unstable();
    let total = (n_clients * reqs_per_client) as f64;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{label:<14} {:>7.0} req/s   p50 {:>6} µs   p95 {:>6} µs   p99 {:>6} µs",
        total / wall,
        lat[lat.len() / 2],
        lat[lat.len() * 95 / 100],
        lat[(lat.len() * 99 / 100).min(lat.len() - 1)],
    );
    let mean_us = lat.iter().sum::<u64>() as f64 / lat.len() as f64;
    let var_us = lat
        .iter()
        .map(|&l| (l as f64 - mean_us) * (l as f64 - mean_us))
        .sum::<f64>()
        / (lat.len().saturating_sub(1).max(1)) as f64;
    b.push(BenchStats {
        name: label.to_string(),
        iters: lat.len(),
        mean_ns: mean_us * 1e3,
        stddev_ns: var_us.sqrt() * 1e3,
        p50_ns: lat[lat.len() / 2] as f64 * 1e3,
        p95_ns: lat[lat.len() * 95 / 100] as f64 * 1e3,
        throughput: Some(total / wall),
    });

    let mut c = Client::connect(&addr).expect("connect for shutdown");
    c.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
    true
}

fn main() {
    println!("== serve_latency ({ARTIFACT}, 8 clients x 40 reqs) ==");
    let mut b = Bench::default();

    // Prefer the real manifest; synthesize one for the native sweep
    // when `make artifacts` has not run.
    let real = PathBuf::from(ARTIFACTS);
    let have_real = hashednets::runtime::Manifest::load(&real.join("manifest.json"))
        .map(|m| m.get(ARTIFACT).is_some())
        .unwrap_or(false);
    let native_dir = if have_real { real.clone() } else { synth_manifest_dir() };

    let ds = generate(Kind::Basic, Split::Test, 64, 2);

    for workers in [1usize, 2, 4] {
        run_case(
            &mut b,
            &native_dir,
            Backend::Native,
            workers,
            &ds,
            &format!("native w{workers}"),
        );
    }
    // The runtime backend is pinned to one worker (PJRT handles are not
    // Send); Server::bind reports why when PJRT is unavailable.
    if have_real {
        run_case(&mut b, &real, Backend::Runtime, 1, &ds, "runtime w1");
    } else {
        println!("runtime w1    : skipped (no artifacts/manifest.json — run `make artifacts`)");
    }

    if !have_real {
        std::fs::remove_dir_all(&native_dir).ok();
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
