//! Serving bench: request latency and throughput through the dynamic
//! batcher + PJRT predict path, at several concurrency levels — the
//! deployment cost story behind the paper's mobile-inference motivation.
//!
//!     cargo bench --bench serve_latency

use hashednets::data::{generate, Kind, Split};
use hashednets::serve::{serve, Client, ServeOptions};
use hashednets::util::bench::{Bench, BenchStats};
use std::time::{Duration, Instant};

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_latency.json");

fn main() {
    println!("== serve_latency (hashnet_3l_h100_o10_c1-8) ==");
    let mut b = Bench::default();
    if hashednets::runtime::Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")).is_err() {
        println!("artifacts missing — run `make artifacts` first");
        b.write_json(OUT).expect("write bench json");
        return;
    }
    let addr = "127.0.0.1:47955";
    let opts = ServeOptions {
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts").into(),
        artifact: "hashnet_3l_h100_o10_c1-8".into(),
        addr: addr.into(),
        max_wait: Duration::from_micros(500),
        ..Default::default()
    };
    let server = std::thread::spawn(move || serve(opts));
    std::thread::sleep(Duration::from_millis(1500));
    let ds = generate(Kind::Basic, Split::Test, 64, 2);

    for n_clients in [1usize, 4, 16] {
        let reqs_per_client = 40;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let addr = addr.to_string();
            let rows: Vec<Vec<f32>> =
                (0..reqs_per_client).map(|i| ds.images.row((c + i) % 64).to_vec()).collect();
            handles.push(std::thread::spawn(move || -> Vec<u64> {
                let mut client = Client::connect(&addr).expect("connect");
                rows.iter()
                    .map(|r| client.classify(r).expect("classify").2)
                    .collect()
            }));
        }
        let mut lat: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        lat.sort_unstable();
        let total = (n_clients * reqs_per_client) as f64;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:>3} clients: {:>7.0} req/s   p50 {:>6} µs   p95 {:>6} µs   p99 {:>6} µs",
            n_clients,
            total / wall,
            lat[lat.len() / 2],
            lat[lat.len() * 95 / 100],
            lat[(lat.len() * 99 / 100).min(lat.len() - 1)],
        );
        let mean_us = lat.iter().sum::<u64>() as f64 / lat.len() as f64;
        let var_us = lat
            .iter()
            .map(|&l| (l as f64 - mean_us) * (l as f64 - mean_us))
            .sum::<f64>()
            / (lat.len().saturating_sub(1).max(1)) as f64;
        b.push(BenchStats {
            name: format!("serve {n_clients} clients"),
            iters: lat.len(),
            mean_ns: mean_us * 1e3,
            stddev_ns: var_us.sqrt() * 1e3,
            p50_ns: lat[lat.len() / 2] as f64 * 1e3,
            p95_ns: lat[lat.len() * 95 / 100] as f64 * 1e3,
            throughput: Some(total / wall),
        });
    }
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    server.join().unwrap().unwrap();
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
