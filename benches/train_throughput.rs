//! Training-throughput bench: the threaded backward at the paper's
//! layer shape (784→1000 virtual, K = virtual/8 ≈ 98k) and the full
//! `Network::train_step`, swept over 1 / 2 / 4 backward workers at the
//! paper's minibatch of 50 against the single-thread baseline:
//!
//!   * `hashed bwd`  — `Layer::backward` on the hashed layer alone:
//!     Eq. 12 through the inverse plan (scatter-free, no ∂w partials)
//!     plus the block-partial ∂a accumulation, on the shared PoolExec
//!   * `hashed bwd scatter` — the legacy fused row loop that scatters
//!     one random write per virtual cell (serial baseline), so the
//!     inverse-vs-scatter win is measured, not asserted
//!   * `hashed bwd ordered` — the fixed-order deterministic reduction,
//!     so the cost of the reproducibility contract is measured, not
//!     guessed
//!   * `dense bwd`   — the dense transpose-matmul backward
//!     (row-parallel `matmul_tn_par` / `matmul_par`)
//!   * `train step`  — forward + loss + backward + SGD update on a
//!     784-1000-10 HashedNet (what `hashednets train --threads` runs)
//!
//! Results land in `BENCH_train_throughput.json` at the repo root.
//!
//!     cargo bench --bench train_throughput   (or `make train-bench`)

use hashednets::data::{generate, Kind, Split};
use hashednets::nn::{Layer, LayerKind, Network, TrainHyper, TrainOptions};
use hashednets::tensor::Matrix;
use hashednets::util::bench::Bench;
use hashednets::util::rng::Pcg32;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_train_throughput.json");
const BATCH: usize = 50;
const THREAD_SWEEP: [usize; 3] = [1, 2, 4];

fn main() {
    println!("== train_throughput: threaded backward at batch {BATCH}, 784->1000 ==");
    let mut b = Bench::new(2, 12);
    b.items_per_iter = Some(BATCH as f64);
    let mut rng = Pcg32::new(7, 7);

    // --- hashed backward at the paper width (K = virtual/8 ≈ 98k) -----
    let (m, n) = (784usize, 1000usize);
    let k = (m + 1) * n / 8;
    let mut hashed = Layer::new(m, n, LayerKind::Hashed { k }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    hashed.init(&mut rng);
    let a = Matrix::from_fn(BATCH, m, |_, _| rng.normal());
    let delta = Matrix::from_fn(BATCH, n, |_, _| rng.normal());
    hashednets::rt::pool::run(hashednets::rt::pool::max_concurrency(), |_| {}); // warm pool
    {
        let mut grad = vec![0.0f32; k];
        hashed.backward(&a, &delta, &mut grad, &TrainOptions::default()); // build inverse view
    }
    for threads in THREAD_SWEEP {
        let opts = TrainOptions::with_threads(threads);
        b.run(&format!("hashed bwd b{BATCH} 784->1000 K=98k t{threads}"), || {
            let mut grad = vec![0.0f32; k];
            std::hint::black_box(hashed.backward(&a, &delta, &mut grad, &opts));
        });
    }
    // the legacy Eq. 12 scatter (one random write per virtual cell),
    // serial — the baseline the inverse-plan gradient replaces
    b.run(&format!("hashed bwd scatter b{BATCH} 784->1000 K=98k serial"), || {
        let mut grad = vec![0.0f32; k];
        std::hint::black_box(hashed.backward_hashed_scatter(&a, &delta, &mut grad));
    });
    let ordered = TrainOptions::with_threads(4).ordered();
    b.run(&format!("hashed bwd ordered b{BATCH} 784->1000 K=98k t4"), || {
        let mut grad = vec![0.0f32; k];
        std::hint::black_box(hashed.backward(&a, &delta, &mut grad, &ordered));
    });

    // --- dense backward (the matmul transpose paths) ------------------
    let mut dense = Layer::new(m, n, LayerKind::Dense, 0, hashednets::hash::DEFAULT_SEED_BASE);
    dense.init(&mut rng);
    for threads in THREAD_SWEEP {
        let opts = TrainOptions::with_threads(threads);
        b.run(&format!("dense bwd b{BATCH} 784->1000 t{threads}"), || {
            let mut grad = vec![0.0f32; dense.params.len()];
            std::hint::black_box(dense.backward(&a, &delta, &mut grad, &opts));
        });
    }

    // --- end-to-end train_step on a 784-1000-10 HashedNet -------------
    let ds = generate(Kind::Basic, Split::Train, BATCH, 3);
    let x = ds.images.clone();
    let y: Vec<i32> = ds.labels.iter().map(|&l| l as i32).collect();
    let hyper = TrainHyper { lr: 0.01, keep_prob: 1.0, ..Default::default() };
    for threads in THREAD_SWEEP {
        let opts = TrainOptions::with_threads(threads);
        let mut net = Network::from_dims(
            &[784, 1000, 10],
            vec![LayerKind::Hashed { k }, LayerKind::Hashed { k: 10 * 1001 / 8 }],
            hashednets::hash::DEFAULT_SEED_BASE,
        );
        net.init(&mut Pcg32::new(1, 1));
        let mut step_rng = Pcg32::new(2, 2);
        b.run(&format!("train step b{BATCH} 784-1000-10 t{threads}"), || {
            std::hint::black_box(net.train_step(&x, &y, None, &hyper, &opts, &mut step_rng));
        });
    }

    // --- speedup summary + JSON ---------------------------------------
    let find = |needle: &str| {
        b.results().iter().find(|s| s.name.contains(needle)).map(|s| s.mean_ns)
    };
    for (label, t1, t4) in [
        (
            "hashed backward",
            find("hashed bwd b50 784->1000 K=98k t1"),
            find("hashed bwd b50 784->1000 K=98k t4"),
        ),
        ("dense backward", find("dense bwd b50 784->1000 t1"), find("dense bwd b50 784->1000 t4")),
        ("train step", find("train step b50 784-1000-10 t1"), find("train step b50 784-1000-10 t4")),
    ] {
        if let (Some(t1), Some(t4)) = (t1, t4) {
            println!("\n{label} speedup at 4 threads over 1: {:.2}x", t1 / t4);
        }
    }
    if let (Some(fast), Some(ord)) =
        (find("hashed bwd b50 784->1000 K=98k t4"), find("hashed bwd ordered b50"))
    {
        println!("ordered-mode overhead at 4 threads: {:.2}x", ord / fast);
    }
    if let (Some(scatter), Some(inv1)) =
        (find("hashed bwd scatter b50"), find("hashed bwd b50 784->1000 K=98k t1"))
    {
        println!("inverse-plan speedup over legacy scatter (serial): {:.2}x", scatter / inv1);
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
