//! Pool dispatch overhead: what a fork/join costs on the parked
//! [`hashednets::rt::PoolExec`] versus spawning and joining fresh OS
//! threads per call — the tax every parallel kernel site used to pay
//! on every layer invocation.
//!
//! Three rungs, each at the pool's lane count:
//!
//!   * `noop`  — empty tasks: pure dispatch/join cost
//!   * `small` — ~16k integer ops per task: a kernel far below the
//!     `PAR_WORK_THRESHOLD`, where dispatch overhead decides whether
//!     threading is worth it at all
//!   * `slice` — each task fills a disjoint 16 KiB chunk of one shared
//!     buffer: the `chunks_mut` pattern the matmul/backward sites use
//!
//! Results land in `BENCH_pool_overhead.json` at the repo root.
//!
//!     cargo bench --bench pool_overhead   (or `make pool-bench`)

use hashednets::rt::pool;
use hashednets::util::bench::Bench;
use std::sync::atomic::{AtomicU64, Ordering};

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pool_overhead.json");

/// ~16k integer ops of un-elidable work, keyed by the task index.
fn small_work(t: usize) -> u64 {
    let mut acc = t as u64;
    for i in 0..16_384u64 {
        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    acc
}

fn main() {
    let lanes = pool::max_concurrency();
    println!("== pool_overhead: PoolExec vs cold spawn/join at {lanes} lanes ==");
    let mut b = Bench::new(5, 40);
    pool::run(lanes, |_| {}); // warm: workers spawned + parked

    // --- pure dispatch ------------------------------------------------
    b.run(&format!("noop pool-warm x{lanes}"), || {
        pool::run(lanes, |_| {});
    });
    b.run(&format!("noop cold-spawn x{lanes}"), || {
        let handles: Vec<_> = (0..lanes).map(|_| std::thread::spawn(|| {})).collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    // --- small per-task work ------------------------------------------
    let sink = AtomicU64::new(0);
    b.run(&format!("small pool-warm x{lanes}"), || {
        pool::run(lanes, |t| {
            sink.fetch_add(small_work(t), Ordering::Relaxed);
        });
    });
    b.run(&format!("small cold-spawn x{lanes}"), || {
        let handles: Vec<_> =
            (0..lanes).map(|t| std::thread::spawn(move || small_work(t))).collect();
        let mut total = 0u64;
        for h in handles {
            total = total.wrapping_add(h.join().unwrap());
        }
        std::hint::black_box(total);
    });
    std::hint::black_box(sink.load(Ordering::Relaxed));

    // --- disjoint-chunk fill (the kernels' chunks_mut pattern) --------
    let chunk = 4096usize; // 16 KiB of f32 per task
    let mut buf = vec![0.0f32; chunk * lanes];
    b.run(&format!("slice pool-warm x{lanes}"), || {
        pool::run_parts(buf.chunks_mut(chunk).collect(), |t, part: &mut [f32]| {
            for (i, v) in part.iter_mut().enumerate() {
                *v = (t * chunk + i) as f32;
            }
        });
    });
    std::hint::black_box(&buf);

    // --- summary + JSON -----------------------------------------------
    let find = |needle: &str| b.results().iter().find(|s| s.name.contains(needle)).map(|s| s.mean_ns);
    for rung in ["noop", "small"] {
        if let (Some(cold), Some(warm)) =
            (find(&format!("{rung} cold-spawn")), find(&format!("{rung} pool-warm")))
        {
            println!("\n{rung}: pool-warm is {:.2}x faster than cold spawn/join", cold / warm);
        }
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
