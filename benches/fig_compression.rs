//! Systems-cost side of Figures 2–3: how train-step latency, inference
//! throughput and storage scale across the compression sweep for
//! HashNet vs. the equivalent-size NN (the two series whose *accuracy*
//! crossover the figures show; regenerate that side with
//! `hashednets repro --experiment fig2|fig3`).
//!
//!     cargo bench --bench fig_compression

use hashednets::data::{generate, Kind, Split};
use hashednets::runtime::{Graph, Hyper, Runtime};
use hashednets::util::bench::Bench;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fig_compression.json");

fn main() {
    println!("== fig_compression: cost vs compression factor ==");
    let rt = match Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")) {
        Ok(rt) => rt,
        Err(_) => {
            println!("artifacts missing — run `make artifacts` first");
            Bench::default().write_json(OUT).expect("write bench json");
            return;
        }
    };
    let ds = generate(Kind::Basic, Split::Train, 64, 1);
    let mut b = Bench::new(2, 10);
    println!(
        "{:<10} {:>14} {:>14} {:>12} {:>12}",
        "compress", "hashnet step", "nn step", "hash B", "nn B"
    );
    for comp in ["1-1", "1-2", "1-4", "1-8", "1-16", "1-32", "1-64"] {
        let mut cells: Vec<String> = vec![format!("{comp:<10}")];
        let mut bytes = Vec::new();
        for method in ["hashnet", "nn"] {
            let name = format!("{method}_3l_h100_o10_c{comp}");
            let Some(spec) = rt.manifest.get(&name).cloned() else { continue };
            let mut state = spec.init_state(1);
            let train = rt.load(&name, Graph::Train).unwrap();
            let (x, y) = ds.gather_batch(&(0..50u32).collect::<Vec<_>>(), spec.batch);
            let mut seed = 0u32;
            let hyper = Hyper::default();
            let s = b.run(&format!("train_step {name}"), || {
                seed += 1;
                std::hint::black_box(
                    train.train_step(&mut state, &x, &y, None, &hyper, seed).unwrap(),
                );
            });
            cells.push(format!("{:>12.2}ms", s.mean_ns / 1e6));
            bytes.push(4 * spec.stored_params);
        }
        for by in bytes {
            cells.push(format!("{by:>12}"));
        }
        println!("{}", cells.join(" "));
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
