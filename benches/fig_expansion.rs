//! Systems-cost side of Figure 4: compute cost of "virtual inflation".
//! Storage is constant across the row — the whole point — while the
//! FLOPs (and so train-step latency) grow with the virtual width.
//! Accuracy side: `hashednets repro --experiment fig4`.
//!
//!     cargo bench --bench fig_expansion

use hashednets::data::{generate, Kind, Split};
use hashednets::runtime::{Graph, Hyper, Runtime};
use hashednets::util::bench::Bench;

const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fig_expansion.json");

fn main() {
    println!("== fig_expansion: cost vs expansion factor (storage fixed) ==");
    let rt = match Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts")) {
        Ok(rt) => rt,
        Err(_) => {
            println!("artifacts missing — run `make artifacts` first");
            Bench::default().write_json(OUT).expect("write bench json");
            return;
        }
    };
    let ds = generate(Kind::Basic, Split::Train, 64, 1);
    let mut b = Bench::new(2, 10);
    println!(
        "{:>5} {:>9} {:>9} {:>14} {:>14}",
        "x", "virtual", "stored", "train_step", "predict"
    );
    for factor in [1usize, 2, 4, 8, 16] {
        let name = format!("hashnet_3l_b50_o10_x{factor}");
        let Some(spec) = rt.manifest.get(&name).cloned() else { continue };
        let mut state = spec.init_state(1);
        let train = rt.load(&name, Graph::Train).unwrap();
        let predict = rt.load(&name, Graph::Predict).unwrap();
        let (x, y) = ds.gather_batch(&(0..50u32).collect::<Vec<_>>(), spec.batch);
        let mut seed = 0u32;
        let hyper = Hyper::default();
        let st = b.run(&format!("train_step {name}"), || {
            seed += 1;
            std::hint::black_box(
                train.train_step(&mut state, &x, &y, None, &hyper, seed).unwrap(),
            );
        });
        let sp = b.run(&format!("predict    {name}"), || {
            std::hint::black_box(predict.predict(&state, &x).unwrap());
        });
        println!(
            "{:>5} {:>9} {:>9} {:>12.2}ms {:>12.2}ms",
            factor,
            spec.virtual_params,
            spec.stored_params,
            st.mean_ns / 1e6,
            sp.mean_ns / 1e6
        );
    }
    b.write_json(OUT).expect("write bench json");
    println!("wrote {OUT}");
}
