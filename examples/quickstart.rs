//! Quickstart: train a HashedNet on the synthetic digit corpus, compare
//! it to the equivalent-size dense baseline, save a checkpoint.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What it shows: at a 1/8 storage budget the hashed parameterization
//! (virtual 784-100-10 network) beats a dense net shrunk to the same
//! number of stored floats — the paper's core claim.

use anyhow::Result;
use hashednets::coordinator::trainer::{run, TrainConfig};
use hashednets::data::Kind;
use hashednets::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;

    let mut cfg = TrainConfig {
        artifact: "hashnet_3l_h100_o10_c1-8".into(),
        dataset: Kind::Basic,
        n_train: 3000,
        n_test: 2000,
        epochs: 10,
        ..Default::default()
    };

    println!("== HashedNet (virtual 784-100-10, budget 1/8) ==");
    let hashed = run(&rt, &cfg, None)?;
    println!(
        "   test error {:.2}%  ({} stored / {} virtual params, {:.0} steps/s)",
        hashed.test_error * 100.0,
        hashed.stored_params,
        hashed.virtual_params,
        hashed.steps_per_s
    );

    println!("== Equivalent-size dense NN (same stored bytes) ==");
    cfg.artifact = "nn_3l_h100_o10_c1-8".into();
    let dense = run(&rt, &cfg, None)?;
    println!(
        "   test error {:.2}%  ({} stored params)",
        dense.test_error * 100.0,
        dense.stored_params
    );

    println!();
    println!(
        "HashedNet {:.2}% vs equivalent NN {:.2}% at the same memory budget",
        hashed.test_error * 100.0,
        dense.test_error * 100.0
    );

    let path = std::path::Path::new("quickstart_hashnet.hnb");
    let bundle = hashed.bundle()?;
    bundle.save(path)?;
    println!(
        "model bundle saved to {} ({} param bytes — the entire model, spec included)",
        path.display(),
        bundle.param_bytes()
    );
    Ok(())
}
