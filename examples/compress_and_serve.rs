//! Deployment workflow the paper's introduction motivates: a full dense
//! model is trained server-side, compressed to a memory budget with the
//! hashing trick **in one call** (`compress::compress_network`),
//! fine-tuned briefly, packaged as a self-describing `ModelBundle`,
//! then served on a batched TCP endpoint whose resident model is the
//! *compressed* parameter vector.
//!
//!     make artifacts && cargo run --release --example compress_and_serve
//!
//! Steps:
//!   1. train dense 784-100-10 (`nn` at compression 1) — the "cloud" model
//!   2. `compress_network(dense, budgets)` → hashed `ModelBundle` (1/8)
//!   3. measure error: dense / compressed / compressed+fine-tuned
//!   4. save the bundle and serve it; classify live requests

use anyhow::Result;
use hashednets::compress;
use hashednets::coordinator::trainer;
use hashednets::data::{generate, Kind, Split};
use hashednets::nn::{Network, TrainHyper, TrainOptions};
use hashednets::runtime::{ModelState, Runtime};
use hashednets::serve::{serve, Backend, Client, ModelConfig, ServeOptions};
use hashednets::util::rng::Pcg32;

const DENSE: &str = "nn_3l_h100_o10_c1-1";
const HASHED: &str = "hashnet_3l_h100_o10_c1-8";

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    let train = generate(Kind::Basic, Split::Train, 3000, 7);
    let test = generate(Kind::Basic, Split::Test, 2000, 7);

    // 1. dense teacher ---------------------------------------------------
    println!("[1/4] training dense model ({DENSE})...");
    let cfg = trainer::TrainConfig {
        artifact: DENSE.into(),
        dataset: Kind::Basic,
        n_train: 3000,
        n_test: 2000,
        epochs: 10,
        seed: 7,
        ..Default::default()
    };
    let dense = trainer::run_with_data(&rt, &cfg, &train, Some(&test), None)?;
    println!(
        "      dense test error {:.2}% ({} params)",
        dense.test_error * 100.0,
        dense.stored_params
    );

    // 2. post-hoc compression: dense → hashed bundle, one call -----------
    println!("[2/4] compressing 8x with the hashing trick...");
    let hspec = rt.manifest.get(HASHED).unwrap().clone();
    let dnet = Network::from_bundle(&dense.bundle()?)?;
    let mut bundle = compress::compress_network(&dnet, &hspec.budgets, hspec.name.clone())?;
    bundle.spec.batch = hspec.batch.max(1);
    for (l, err) in compress::reconstruction_report(&dnet, &bundle)?.iter().enumerate() {
        println!("      layer {l}: -> {} weights (recon err {err:.3})", hspec.budgets[l]);
    }
    let e_comp = trainer::evaluate(&rt, HASHED, &ModelState::from_bundle(&bundle), &test)?;
    println!("      compressed (no fine-tune) test error {:.2}%", e_comp * 100.0);

    // 3. brief fine-tune in the native engine ----------------------------
    println!("[3/4] fine-tuning the compressed model (3 epochs, native engine)...");
    let mut hnet = Network::from_bundle(&bundle)?;
    let hyper = TrainHyper { lr: 0.02, keep_prob: 1.0, ..Default::default() };
    let mut rng = Pcg32::new(17, 0);
    // auto-threaded backward: the fine-tune uses every core
    hnet.fit(&train.images, &train.labels, 50, 3, &hyper, &TrainOptions::with_threads(0), None, &mut rng);
    bundle = hnet.to_bundle(&bundle.spec.clone())?;
    let e_ft = trainer::evaluate(&rt, HASHED, &ModelState::from_bundle(&bundle), &test)?;
    println!("      fine-tuned test error {:.2}%", e_ft * 100.0);
    println!(
        "      summary: dense {:.2}% | 8x-compressed {:.2}% | +fine-tune {:.2}%",
        dense.test_error * 100.0,
        e_comp * 100.0,
        e_ft * 100.0
    );

    // 4. serve it ---------------------------------------------------------
    // The bundle is the entire deployable model: spec + compressed
    // params, one file. Serving needs nothing else — two native
    // workers share the decompression plan.
    println!("[4/4] serving the compressed bundle on 127.0.0.1:47912...");
    let hnb = std::env::temp_dir().join("hn_compressed.hnb");
    bundle.save(&hnb)?;
    let opts = ServeOptions {
        models: vec![ModelConfig::bundle(&hnb)],
        addr: "127.0.0.1:47912".into(),
        backend: Backend::Native,
        workers: 2,
        ..Default::default()
    };
    let server = std::thread::spawn(move || serve(opts));
    std::thread::sleep(std::time::Duration::from_millis(1500));
    let mut client = Client::connect("127.0.0.1:47912")?;
    let mut correct = 0;
    let n_req = 64;
    for i in 0..n_req {
        let (class, _probs, latency_us) = client.classify(test.images.row(i))?;
        if class == test.labels[i] as usize {
            correct += 1;
        }
        if i < 3 {
            println!(
                "      request {i}: true {}, predicted {class} ({latency_us} µs)",
                test.labels[i]
            );
        }
    }
    println!("      live accuracy {}/{} over TCP", correct, n_req);
    client.shutdown()?;
    server.join().unwrap()?;
    std::fs::remove_file(&hnb).ok();
    Ok(())
}
