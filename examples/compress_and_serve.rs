//! Deployment workflow the paper's introduction motivates: a full dense
//! model is trained server-side, compressed to a memory budget with the
//! hashing trick, fine-tuned briefly, then served on a batched TCP
//! endpoint whose resident model is the *compressed* parameter vector.
//!
//!     make artifacts && cargo run --release --example compress_and_serve
//!
//! Steps:
//!   1. train dense 784-100-10 (`nn` at compression 1) — the "cloud" model
//!   2. bucket-average its weights into the hashnet 1/8 layout (post-hoc
//!      compression, `compress::compress_dense`)
//!   3. measure error: dense / compressed / compressed+fine-tuned
//!   4. serve the fine-tuned compressed model; classify live requests

use anyhow::Result;
use hashednets::compress;
use hashednets::coordinator::{native, trainer};
use hashednets::data::{generate, Kind, Split};
use hashednets::nn::TrainHyper;
use hashednets::runtime::{ModelState, Runtime};
use hashednets::serve::{serve, Backend, Client, ModelConfig, ServeOptions};
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;

const DENSE: &str = "nn_3l_h100_o10_c1-1";
const HASHED: &str = "hashnet_3l_h100_o10_c1-8";

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    let train = generate(Kind::Basic, Split::Train, 3000, 7);
    let test = generate(Kind::Basic, Split::Test, 2000, 7);

    // 1. dense teacher ---------------------------------------------------
    println!("[1/4] training dense model ({DENSE})...");
    let cfg = trainer::TrainConfig {
        artifact: DENSE.into(),
        dataset: Kind::Basic,
        n_train: 3000,
        n_test: 2000,
        epochs: 10,
        seed: 7,
        ..Default::default()
    };
    let dense = trainer::run_with_data(&rt, &cfg, &train, Some(&test), None)?;
    println!(
        "      dense test error {:.2}% ({} params)",
        dense.test_error * 100.0,
        dense.stored_params
    );

    // 2. post-hoc compression -------------------------------------------
    println!("[2/4] compressing 8x with the hashing trick...");
    let dspec = rt.manifest.get(DENSE).unwrap().clone();
    let hspec = rt.manifest.get(HASHED).unwrap().clone();
    let mut dnet = native::network_from_spec(&dspec);
    native::load_params(&mut dnet, &dspec, &dense.state);
    let mut hstate = ModelState::init(&hspec, 0);
    for (l, layer) in dnet.layers.iter().enumerate() {
        let v = layer.virtual_matrix(); // dense W (n×m)
        let nm = layer.n * layer.m;
        let bias = layer.params[nm..].to_vec();
        let mut vb = Matrix::zeros(layer.n, layer.m + 1);
        for i in 0..layer.n {
            vb.row_mut(i)[..layer.m].copy_from_slice(v.row(i));
            vb.row_mut(i)[layer.m] = bias[i];
        }
        let k = hspec.budgets[l];
        let err = compress::reconstruction_error(&vb, k, l as u32, hspec.seed_base);
        hstate.params[l] = compress::compress_dense(&vb, k, l as u32, hspec.seed_base);
        println!("      layer {l}: {} → {k} weights (recon err {err:.3})", vb.data.len());
    }
    let e_comp = trainer::evaluate(&rt, HASHED, &hstate, &test)?;
    println!("      compressed (no fine-tune) test error {:.2}%", e_comp * 100.0);

    // 3. brief fine-tune in the native engine ----------------------------
    println!("[3/4] fine-tuning the compressed model (3 epochs, native engine)...");
    let mut hnet = native::network_from_spec(&hspec);
    native::load_params(&mut hnet, &hspec, &hstate);
    let hyper = TrainHyper { lr: 0.02, keep_prob: 1.0, ..Default::default() };
    let mut rng = Pcg32::new(17, 0);
    hnet.fit(&train.images, &train.labels, 50, 3, &hyper, None, &mut rng);
    native::store_params(&hnet, &hspec, &mut hstate);
    let e_ft = trainer::evaluate(&rt, HASHED, &hstate, &test)?;
    println!("      fine-tuned test error {:.2}%", e_ft * 100.0);
    println!(
        "      summary: dense {:.2}% | 8x-compressed {:.2}% | +fine-tune {:.2}%",
        dense.test_error * 100.0,
        e_comp * 100.0,
        e_ft * 100.0
    );

    // 4. serve it ---------------------------------------------------------
    // `auto` picks the PJRT artifact runtime when it loads, otherwise
    // the native HashPlan engine — where two workers share the model.
    println!("[4/4] serving the compressed model on 127.0.0.1:47912...");
    let ckpt = std::env::temp_dir().join("hn_compressed.ckpt");
    hstate.save(&ckpt)?;
    let opts = ServeOptions {
        artifacts_dir: "artifacts".into(),
        models: vec![ModelConfig::new(HASHED).with_checkpoint(ckpt.clone())],
        addr: "127.0.0.1:47912".into(),
        backend: Backend::Auto,
        workers: 2,
        ..Default::default()
    };
    let server = std::thread::spawn(move || serve(opts));
    std::thread::sleep(std::time::Duration::from_millis(1500));
    let mut client = Client::connect("127.0.0.1:47912")?;
    let mut correct = 0;
    let n_req = 64;
    for i in 0..n_req {
        let (class, _probs, latency_us) = client.classify(test.images.row(i))?;
        if class == test.labels[i] as usize {
            correct += 1;
        }
        if i < 3 {
            println!(
                "      request {i}: true {}, predicted {class} ({latency_us} µs)",
                test.labels[i]
            );
        }
    }
    println!("      live accuracy {}/{} over TCP", correct, n_req);
    client.shutdown()?;
    server.join().unwrap()?;
    std::fs::remove_file(&ckpt).ok();
    Ok(())
}
