//! End-to-end driver: exercises the full three-layer system on a real
//! small workload and logs the loss curves (recorded in EXPERIMENTS.md).
//!
//!     make artifacts && cargo run --release --example e2e
//!
//! Pipeline per method (HashNet, HashNet_DK, NN, DK, RER, LRD):
//!   synthetic ROT corpus → AOT train_step artifact (Pallas hashed
//!   matmul inside) driven by the Rust coordinator → validation-selected
//!   checkpoint → test error + throughput. A teacher is trained first
//!   for the dark-knowledge runs. Loss curves land in
//!   `results/e2e_loss.csv`, the summary table in `results/e2e.md`.

use anyhow::Result;
use hashednets::coordinator::metrics::Table;
use hashednets::coordinator::repro::default_hyper;
use hashednets::coordinator::trainer::{self, TrainConfig};
use hashednets::data::{generate, Kind, Split};
use hashednets::runtime::Runtime;

const DATASET: Kind = Kind::Rot;
const N_TRAIN: usize = 4000;
const N_TEST: usize = 3000;
const EPOCHS: usize = 15;
const COMPRESSION: &str = "1-8";

fn main() -> Result<()> {
    let t0 = std::time::Instant::now();
    let rt = Runtime::open("artifacts")?;
    let train = generate(DATASET, Split::Train, N_TRAIN, 0x5EED);
    println!(
        "workload: {} ({} train / {} test), 3-layer nets, budget {COMPRESSION}",
        DATASET.name(),
        N_TRAIN,
        N_TEST
    );

    // teacher for the DK runs
    println!("[teacher] nn_3l_h100_o10_c1-1 ...");
    let teacher = "nn_3l_h100_o10_c1-1";
    let tstate = trainer::train_teacher(&rt, teacher, &train, EPOCHS, 0x5EED, &Default::default())?;

    let mut table = Table::new(
        &format!("e2e: {} @ {} (3-layer)", DATASET.name(), COMPRESSION),
        "method",
        &["test error %", "stored", "virtual", "steps/s", "wall s"],
    );
    let mut loss_csv = String::from("method,epoch,loss\n");

    for method in hashednets::coordinator::repro::METHODS {
        let artifact = format!("{method}_3l_h100_o10_c{COMPRESSION}");
        let hyper = default_hyper(method);
        let needs_teacher = method.uses_soft_targets();
        let soft = if needs_teacher {
            Some(trainer::soft_targets(&rt, teacher, &tstate, &train.images, hyper.temp)?)
        } else {
            None
        };
        let cfg = TrainConfig {
            artifact: artifact.clone(),
            dataset: DATASET,
            n_train: N_TRAIN,
            n_test: N_TEST,
            epochs: EPOCHS,
            hyper,
            seed: 0x5EED,
            teacher: needs_teacher.then(|| teacher.to_string()),
            ..Default::default()
        };
        let res = trainer::run(&rt, &cfg, soft.as_ref())?;
        println!(
            "[{method:<10}] test {:.2}%  ({} stored, {:.0} steps/s, {:.1}s)",
            res.test_error * 100.0,
            res.stored_params,
            res.steps_per_s,
            res.wall_s
        );
        table.set_err(method.as_str(), "test error %", res.test_error);
        table.set(method.as_str(), "stored", res.stored_params.to_string());
        table.set(method.as_str(), "virtual", res.virtual_params.to_string());
        table.set(method.as_str(), "steps/s", format!("{:.0}", res.steps_per_s));
        table.set(method.as_str(), "wall s", format!("{:.1}", res.wall_s));
        for (e, l) in res.train_losses.iter().enumerate() {
            loss_csv.push_str(&format!("{method},{e},{l}\n"));
        }
    }

    std::fs::create_dir_all("results")?;
    std::fs::write("results/e2e_loss.csv", loss_csv)?;
    table.save(std::path::Path::new("results"), "e2e")?;
    println!("\n{}", table.to_markdown());
    println!("loss curves -> results/e2e_loss.csv");
    println!("total wall time {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
