//! Figure-4-in-miniature: with the storage budget pinned to a 50-unit
//! dense net, "inflate" the virtual architecture and watch test error
//! drop — extra hidden units cost *nothing* in memory.
//!
//!     make artifacts && cargo run --release --example expansion_sweep

use anyhow::Result;
use hashednets::coordinator::trainer::{run, TrainConfig};
use hashednets::data::Kind;
use hashednets::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    println!("storage fixed to a 784-50-10 dense net; virtual width grows:");
    println!("{:<12} {:>14} {:>10} {:>12}", "expansion", "virtual units", "stored", "test error");
    let mut cfg = TrainConfig {
        dataset: Kind::Rot, // rotation needs capacity — expansion shines
        n_train: 3000,
        n_test: 2000,
        epochs: 10,
        ..Default::default()
    };
    // dense reference (dashed line in the paper's figure)
    cfg.artifact = "nn_3l_b50_o10_x1".into();
    let base = run(&rt, &cfg, None)?;
    println!(
        "{:<12} {:>14} {:>10} {:>11.2}%  <- dense reference",
        "1 (dense)", 50, base.stored_params, base.test_error * 100.0
    );
    for factor in [1usize, 2, 4, 8, 16] {
        cfg.artifact = format!("hashnet_3l_b50_o10_x{factor}");
        let res = run(&rt, &cfg, None)?;
        println!(
            "{:<12} {:>14} {:>10} {:>11.2}%",
            factor,
            50 * factor,
            res.stored_params,
            res.test_error * 100.0
        );
    }
    println!("\n(the sweet-spot the paper reports is 8-16x; storage never grows)");
    Ok(())
}
