//! Bundle-format torture tests: a seeded mutation corpus over valid v1
//! and v2 bundles for every [`Method`] × codec.
//!
//! The properties, per ISSUE acceptance:
//!
//! * `ModelBundle::from_bytes` / `::load` / `BundleMap::open` return a
//!   typed [`ModelError`] on every corrupted mutant — they never panic
//!   and never allocate beyond the actual file size (every length field
//!   is bounds-checked against the buffer before any allocation, so an
//!   oversize header errors instead of OOMing).
//! * `save → load → save` is **byte-exact** for every method × codec:
//!   v2 enforces canonical packing (exactly one valid serialization per
//!   bundle), and lossy codecs persist their authoritative codes rather
//!   than re-encoding floats.
//! * the v2 int8 bundle is ≥3.5× smaller than its v1 f32 equivalent at
//!   the paper's MNIST 1/8 shape, with argmax (indeed bit-equal)
//!   predictions on an eval grid.

use hashednets::hash::xxh32_bytes;
use hashednets::model::{
    BagMode, BundleMap, Method, ModelBundle, ModelError, ModelSpec, QuantSpec,
};
use hashednets::nn::{EmbedBag, Network};
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

/// The trailing-word checksum seed — a pinned format constant ("MB"),
/// duplicated here on purpose so a silent change in the writer shows up
/// as a golden-format break, not a self-consistent refactor.
const CHECKSUM_SEED: u32 = 0x4D42;

const CODECS: [QuantSpec; 3] = [QuantSpec::F32, QuantSpec::Int8, QuantSpec::Codebook(16)];

fn spec_for(method: Method) -> ModelSpec {
    ModelSpec::new(
        format!("fz_{method}"),
        method,
        vec![9, 7, 4],
        vec![21, 14],
        hashednets::hash::DEFAULT_SEED_BASE,
        5,
    )
    .expect("valid spec")
}

/// Every valid serialized bundle the mutators chew on: all six methods
/// × three codecs as v2, the f32 ones as v1 too, plus one quantized
/// embedding-bag bundle.
fn corpus() -> Vec<(String, Vec<u8>)> {
    let mut out = Vec::new();
    for method in Method::ALL {
        let spec = spec_for(method);
        let mut net = Network::from_spec(&spec).expect("from_spec");
        net.init(&mut Pcg32::new(0xF1_22, 7));
        let bundle = net.to_bundle(&spec).expect("to_bundle");
        out.push((format!("{method}/v1"), bundle.to_bytes_v1().expect("v1 writer")));
        for codec in CODECS {
            let q = bundle.quantize(codec).expect("quantize");
            out.push((format!("{method}/v2/{}", codec.name()), q.to_bytes()));
        }
    }
    let espec = ModelSpec::embedding("fz_embed", 50, 8, 32, BagMode::Sum, 0x9E37_79B9, 4)
        .expect("embedding spec");
    let mut bag = EmbedBag::new(50, 8, 32, BagMode::Sum, 0x9E37_79B9);
    bag.init(&mut Pcg32::new(3, 9));
    let ebundle = bag.to_bundle(&espec).expect("embed to_bundle");
    out.push(("embed/v2/int8".into(), ebundle.quantize(QuantSpec::Int8).unwrap().to_bytes()));
    out
}

fn fix_checksum(bytes: &mut [u8]) {
    let n = bytes.len();
    let sum = xxh32_bytes(&bytes[..n - 4], CHECKSUM_SEED);
    bytes[n - 4..].copy_from_slice(&sum.to_le_bytes());
}

fn spec_len(bytes: &[u8]) -> usize {
    u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize
}

fn is_v2(bytes: &[u8]) -> bool {
    u32::from_le_bytes(bytes[4..8].try_into().unwrap()) == 2
}

/// Parse a mutant, asserting it cannot panic. Returns the typed result.
fn parse_mutant(tag: &str, mutant: &[u8]) -> Result<ModelBundle, ModelError> {
    catch_unwind(AssertUnwindSafe(|| ModelBundle::from_bytes(mutant)))
        .unwrap_or_else(|_| panic!("{tag}: ModelBundle::from_bytes PANICKED on corrupt input"))
}

#[test]
fn truncations_at_every_depth_are_typed_errors() {
    for (name, bytes) in corpus() {
        let mut rng = Pcg32::new(0x7_2C, 1);
        let mut cuts: Vec<usize> =
            (0..12).map(|_| rng.next_u32() as usize % bytes.len()).collect();
        // structured depths: mid-magic, mid-header, mid-spec, mid-table,
        // last payload byte, missing checksum tail
        cuts.extend([2, 6, 10, 14 + spec_len(&bytes) / 2, bytes.len() - 1, bytes.len() - 4]);
        for cut in cuts {
            let err = parse_mutant(&name, &bytes[..cut])
                .expect_err("a strict prefix of a valid bundle must never load");
            assert!(
                matches!(
                    err,
                    ModelError::Truncated(_)
                        | ModelError::BadChecksum { .. }
                        | ModelError::BadMagic
                        | ModelError::BadSection(_)
                ),
                "{name} cut at {cut}/{}: unexpected {err:?}",
                bytes.len()
            );
        }
    }
}

#[test]
fn bit_flips_without_checksum_repair_never_load_and_never_panic() {
    for (name, bytes) in corpus() {
        let mut rng = Pcg32::new(0xF11_B, 2);
        for _ in 0..40 {
            let at = rng.next_u32() as usize % bytes.len();
            let bit = rng.next_u32() % 8;
            let mut mutant = bytes.clone();
            mutant[at] ^= 1 << bit;
            let err = parse_mutant(&name, &mutant).expect_err(
                "any single flipped bit must fail magic, version, structure or checksum",
            );
            // every failure is a typed ModelError; which one depends on
            // where the flip landed — Io is the only impossible variant
            assert!(
                !matches!(err, ModelError::Io(_)),
                "{name} flip at {at}.{bit}: {err:?}"
            );
        }
    }
}

#[test]
fn bit_flips_with_repaired_checksum_never_panic() {
    // With the checksum made consistent again, deeper validation layers
    // (section table, codec tags, spec JSON, shape checks) take over.
    // Some mutants legitimately load (e.g. a flipped payload bit is
    // just different weights) — the property is typed-or-valid, not
    // always-rejected.
    for (name, bytes) in corpus() {
        let mut rng = Pcg32::new(0x0DD_B175, 3);
        for _ in 0..40 {
            let at = rng.next_u32() as usize % (bytes.len() - 4);
            let bit = rng.next_u32() % 8;
            let mut mutant = bytes.clone();
            mutant[at] ^= 1 << bit;
            fix_checksum(&mut mutant);
            if let Ok(b) = parse_mutant(&name, &mutant) {
                // anything that loads must be internally consistent
                b.check_shapes().expect("loaded mutants must pass shape checks");
            }
        }
    }
}

/// Forward-compat: a structurally valid bundle whose spec names a
/// method this build doesn't know (e.g. written by a newer build) must
/// fail with the typed [`ModelError::UnknownMethod`] naming the
/// offending string — not a panic, not an untyped spec error. Every
/// corpus entry is patched in place: the spec JSON's `"method"` value
/// gets its first letter bumped (`hashnet` → `iashnet`, `nn` → `on`,
/// …), the checksum is repaired so the deeper spec layer is what
/// rejects it.
#[test]
fn unknown_method_strings_fail_with_typed_unknown_method() {
    let needle = b"\"method\":\"";
    for (name, bytes) in corpus() {
        // spec JSON lives at [12, 12 + spec_len) in both v1 and v2
        let spec_end = 12 + spec_len(&bytes);
        let at = bytes[12..spec_end]
            .windows(needle.len())
            .position(|w| w == needle)
            .unwrap_or_else(|| panic!("{name}: spec JSON must carry a method field"))
            + 12
            + needle.len();
        let end = at + bytes[at..spec_end].iter().position(|&b| b == b'"').unwrap();
        let mut mutant = bytes.clone();
        mutant[at] += 1; // same-length, guaranteed-unknown method name
        fix_checksum(&mut mutant);
        let want = std::str::from_utf8(&mutant[at..end]).unwrap().to_string();
        match parse_mutant(&name, &mutant) {
            Err(ModelError::UnknownMethod(s)) => {
                assert_eq!(s, want, "{name}: error must name the unknown method")
            }
            other => panic!("{name}: expected UnknownMethod({want:?}), got {other:?}"),
        }
    }
}

#[test]
fn oversize_length_fields_error_without_allocating() {
    for (name, bytes) in corpus() {
        // spec_len lies: u32::MAX, just past the file, exactly the file
        for lie in [u32::MAX, bytes.len() as u32, bytes.len() as u32 + 1] {
            let mut mutant = bytes.clone();
            mutant[8..12].copy_from_slice(&lie.to_le_bytes());
            fix_checksum(&mut mutant);
            parse_mutant(&name, &mutant).expect_err("oversize spec_len must fail");
        }
        // tensor count lies — the classic OOM-allocation vector
        let at = 12 + spec_len(&bytes);
        for lie in [u32::MAX, 0x00FF_FFFF] {
            let mut mutant = bytes.clone();
            mutant[at..at + 4].copy_from_slice(&lie.to_le_bytes());
            fix_checksum(&mut mutant);
            parse_mutant(&name, &mutant)
                .expect_err("a tensor count larger than the file could hold must fail");
        }
        // v2 only: per-section enc_len lies
        if is_v2(&bytes) {
            let entry = 16 + spec_len(&bytes); // first section entry
            for (field, lie) in [(3usize, u32::MAX), (3, 0), (1, u32::MAX)] {
                let at = entry + 4 * field;
                let mut mutant = bytes.clone();
                mutant[at..at + 4].copy_from_slice(&lie.to_le_bytes());
                fix_checksum(&mut mutant);
                parse_mutant(&name, &mutant)
                    .expect_err("lying n_elems/enc_len section fields must fail");
            }
        }
    }
}

#[test]
fn misaligned_or_reordered_section_offsets_are_rejected() {
    for (name, bytes) in corpus().into_iter().filter(|(_, b)| is_v2(b)) {
        let entry = 16 + spec_len(&bytes);
        let off_at = entry + 8; // offset field of section 0
        let real = u32::from_le_bytes(bytes[off_at..off_at + 4].try_into().unwrap());
        // +1 (misaligned), +64 (aligned but overlapping the next
        // section's slot), -64 (aligned but inside the header)
        for lie in [real + 1, real + 64, real.saturating_sub(64)] {
            let mut mutant = bytes.clone();
            mutant[off_at..off_at + 4].copy_from_slice(&lie.to_le_bytes());
            fix_checksum(&mut mutant);
            let err = parse_mutant(&name, &mutant)
                .expect_err("non-canonical section offsets must fail");
            assert!(
                matches!(err, ModelError::BadSection(_) | ModelError::Truncated(_)),
                "{name} offset {real}->{lie}: unexpected {err:?}"
            );
        }
    }
}

#[test]
fn file_loaders_reject_what_the_byte_parser_rejects() {
    // ModelBundle::load and BundleMap::open share parse() with
    // from_bytes — spot-check the file-shaped trust boundary agrees,
    // including the mmap path.
    let path: PathBuf =
        std::env::temp_dir().join(format!("hn_fuzz_{}.hnb", std::process::id()));
    let mutators: [fn(&[u8]) -> Vec<u8>; 3] = [
        |b| b[..b.len() * 2 / 3].to_vec(), // truncated
        |b| {
            let mut m = b.to_vec();
            m[b.len() / 2] ^= 0x40; // flipped bit, stale checksum
            m
        },
        |b| {
            let mut m = b.to_vec();
            m[8..12].copy_from_slice(&u32::MAX.to_le_bytes()); // hostile spec_len
            m
        },
    ];
    for (name, bytes) in corpus() {
        for mutate in mutators {
            let mutant = mutate(&bytes);
            std::fs::write(&path, &mutant).unwrap();
            let parse_err = ModelBundle::from_bytes(&mutant).expect_err("mutant parses?");
            let load_err = ModelBundle::load(&path).expect_err("load must agree with from_bytes");
            assert_eq!(
                std::mem::discriminant(&parse_err),
                std::mem::discriminant(&load_err),
                "{name}: load ({load_err:?}) and from_bytes ({parse_err:?}) disagree"
            );
            let map_err = catch_unwind(|| BundleMap::open(&path))
                .unwrap_or_else(|_| panic!("{name}: BundleMap::open PANICKED"))
                .expect_err("BundleMap::open must reject what from_bytes rejects");
            assert_eq!(
                std::mem::discriminant(&parse_err),
                std::mem::discriminant(&map_err),
                "{name}: BundleMap::open ({map_err:?}) and from_bytes ({parse_err:?}) disagree"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn save_load_save_is_byte_exact_for_every_method_and_codec() {
    for (name, bytes) in corpus() {
        let loaded = ModelBundle::from_bytes(&bytes).expect("corpus entries are valid");
        if loaded.version == 1 {
            // v1 fixtures re-save through the legacy writer
            assert_eq!(
                loaded.to_bytes_v1().expect("f32 stays v1-expressible"),
                bytes,
                "{name}: v1 round trip must be byte-exact"
            );
        } else {
            assert_eq!(loaded.to_bytes(), bytes, "{name}: v2 round trip must be byte-exact");
        }
        // and once more through the struct (save→load→save)
        let again = ModelBundle::from_bytes(&loaded.to_bytes()).unwrap();
        assert_eq!(again.to_bytes(), loaded.to_bytes(), "{name}: second trip drifted");
    }
}

/// ISSUE acceptance: at the paper's MNIST 1/8 shape ([784,100,10],
/// budgets [9812,126]) the int8 v2 bundle is ≥3.5× smaller than the v1
/// f32 file, and predictions match f32 on an eval grid. The weights are
/// placed on the int8 reconstruction grid (`k/128` for k in 0..=255,
/// with 0 and 255 present in every tensor), making the dequantized
/// values — and therefore every argmax — *bit*-equal, so the parity
/// assertion is deterministic rather than margin-dependent.
#[test]
fn int8_bundle_meets_size_and_argmax_acceptance() {
    let spec = ModelSpec::new(
        "accept_1_8",
        Method::Hashnet,
        vec![784, 100, 10],
        vec![9812, 126],
        0x9E37_79B9,
        16,
    )
    .unwrap();
    let mut net = Network::from_spec(&spec).unwrap();
    const STEP: f32 = 1.0 / 128.0; // max = 255/128; scale = max/255 = STEP exactly
    for (l, layer) in net.layers.iter_mut().enumerate() {
        for (i, w) in layer.params.iter_mut().enumerate() {
            let code = match i {
                0 => 0,
                1 => 255,
                _ => (i * 37 + l * 91) % 256,
            };
            *w = code as f32 * STEP;
        }
    }
    let bundle = net.to_bundle(&spec).unwrap();
    let v1 = bundle.to_bytes_v1().unwrap();
    let int8 = bundle.quantize(QuantSpec::Int8).unwrap();
    let v2 = int8.to_bytes();
    let ratio = v1.len() as f64 / v2.len() as f64;
    assert!(
        ratio >= 3.5,
        "int8 bundle must be ≥3.5x smaller than v1 f32: {} B vs {} B ({ratio:.2}x)",
        v2.len(),
        v1.len()
    );

    let x = Matrix::from_fn(64, 784, |i, j| ((i * 31 + j * 7) % 97) as f32 / 96.0);
    let want = net.predict(&x);
    let qnet = Network::from_bundle(&ModelBundle::from_bytes(&v2).unwrap()).unwrap();
    let got = qnet.predict(&x);
    assert_eq!(
        got.argmax_rows(),
        want.argmax_rows(),
        "int8 argmax must match f32 on the eval grid"
    );
    // stronger: on the reconstruction grid the round trip is bit-exact
    assert_eq!(got.data, want.data, "grid-valued weights must dequantize bit-exactly");
}
