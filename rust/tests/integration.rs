//! Integration tests over the full stack: AOT artifacts (Pallas/JAX →
//! HLO text) executed through the PJRT runtime, cross-validated against
//! the native engine, driven by the coordinator.
//!
//! These tests require `make artifacts` (the `core` set) to have run;
//! they skip gracefully when artifacts are absent so `cargo test` works
//! on a fresh checkout.

use hashednets::coordinator::{native, trainer};
use hashednets::data::{generate, Kind, Split};
use hashednets::runtime::{Graph, Hyper, ModelState, Runtime};
use hashednets::tensor::Matrix;

const TINY_HASHNET: &str = "hashnet_3l_h32_o10_c1-4";
const TINY_HASHNET_DK: &str = "hashnet_dk_3l_h32_o10_c1-4";
const TINY_TEACHER: &str = "nn_3l_h32_o10_c1-1";

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
    match Runtime::open(dir) {
        Ok(rt) if rt.manifest.get(TINY_HASHNET).is_some() => Some(rt),
        _ => {
            eprintln!("artifacts missing — run `make artifacts`; skipping");
            None
        }
    }
}

#[test]
fn artifact_predict_matches_native_engine() {
    let Some(rt) = runtime() else { return };
    for name in [TINY_HASHNET, TINY_TEACHER] {
        let spec = rt.manifest.get(name).unwrap().clone();
        let state = spec.init_state(11);
        let exe = rt.load(name, Graph::Predict).unwrap();
        let ds = generate(Kind::Basic, Split::Test, spec.batch, 5);
        let got = exe.predict(&state, &ds.images).unwrap();
        let net = native::try_build(&spec, &state).unwrap();
        let want = net.predict(&ds.images);
        let max_d = got
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_d < 1e-3, "{name}: artifact vs native max diff {max_d}");
    }
}

#[test]
fn artifact_train_step_reduces_loss_and_matches_native_math() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get(TINY_HASHNET).unwrap().clone();
    let exe = rt.load(TINY_HASHNET, Graph::Train).unwrap();
    let mut state = spec.init_state(3);
    let ds = generate(Kind::Basic, Split::Train, 400, 3);
    let hyper = Hyper { lr: 0.1, momentum: 0.9, keep_prob: 1.0, ..Hyper::default() };
    let mut losses = Vec::new();
    let mut rng = hashednets::util::rng::Pcg32::new(1, 1);
    for step in 0..40 {
        let idx: Vec<u32> = (0..spec.batch).map(|_| rng.below(400)).collect();
        let (x, y) = ds.gather_batch(&idx, spec.batch);
        let loss = exe.train_step(&mut state, &x, &y, None, &hyper, step).unwrap();
        losses.push(loss);
        assert!(loss.is_finite(), "step {step}: loss {loss}");
    }
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[35..].iter().sum::<f32>() / 5.0;
    assert!(tail < head * 0.8, "loss did not decrease: {head} -> {tail}");
}

#[test]
fn momentum_buffers_change_during_training() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get(TINY_HASHNET).unwrap().clone();
    let exe = rt.load(TINY_HASHNET, Graph::Train).unwrap();
    let mut state = spec.init_state(3);
    let ds = generate(Kind::Basic, Split::Train, 100, 3);
    let (x, y) = ds.gather_batch(&(0..spec.batch as u32).collect::<Vec<_>>(), spec.batch);
    let before = state.momenta.clone();
    exe.train_step(&mut state, &x, &y, None, &Hyper::default(), 0).unwrap();
    assert_ne!(before, state.momenta);
}

#[test]
fn dropout_seed_changes_training_noise() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get(TINY_HASHNET).unwrap().clone();
    let exe = rt.load(TINY_HASHNET, Graph::Train).unwrap();
    let ds = generate(Kind::Basic, Split::Train, 100, 3);
    let (x, y) = ds.gather_batch(&(0..spec.batch as u32).collect::<Vec<_>>(), spec.batch);
    let hyper = Hyper { keep_prob: 0.5, ..Hyper::default() };
    let run = |seed: u32| {
        let mut st = spec.init_state(9);
        exe.train_step(&mut st, &x, &y, None, &hyper, seed).unwrap();
        st.params[0].clone()
    };
    // same seed -> identical update; different seed -> different update
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn predict_all_pads_tail_batches_correctly() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get(TINY_HASHNET).unwrap().clone();
    let exe = rt.load(TINY_HASHNET, Graph::Predict).unwrap();
    let state = spec.init_state(2);
    let n = spec.batch + 7; // forces a padded tail
    let ds = generate(Kind::Basic, Split::Test, n, 8);
    let all = exe.predict_all(&state, &ds.images).unwrap();
    assert_eq!(all.rows, n);
    // row i must equal a fresh single-batch prediction of the same row
    let mut one = Matrix::zeros(spec.batch, ds.images.cols);
    for b in 0..spec.batch {
        one.row_mut(b).copy_from_slice(ds.images.row(n - 1));
    }
    let single = exe.predict(&state, &one).unwrap();
    for (a, b) in all.row(n - 1).iter().zip(single.row(0)) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn dk_training_runs_with_teacher_soft_targets() {
    let Some(rt) = runtime() else { return };
    let train = generate(Kind::Basic, Split::Train, 300, 5);
    let tstate = trainer::train_teacher(&rt, TINY_TEACHER, &train, 2, 5, &Default::default()).unwrap();
    let soft =
        trainer::soft_targets(&rt, TINY_TEACHER, &tstate, &train.images, 4.0).unwrap();
    // rows are probability distributions
    for r in 0..soft.probs.rows {
        let s: f32 = soft.probs.row(r).iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }
    let cfg = trainer::TrainConfig {
        artifact: TINY_HASHNET_DK.into(),
        dataset: Kind::Basic,
        n_train: 300,
        n_test: 200,
        epochs: 2,
        hyper: Hyper { lam: 0.7, temp: 4.0, ..Hyper::default() },
        seed: 5,
        teacher: Some(TINY_TEACHER.into()),
        ..Default::default()
    };
    let res = trainer::run_with_data(&rt, &cfg, &train, None, Some(&soft)).unwrap();
    assert!(res.train_losses.iter().all(|l| l.is_finite()));
    assert!(res.val_error < 0.95);
}

#[test]
fn trained_state_roundtrips_through_checkpoint() {
    let Some(rt) = runtime() else { return };
    let cfg = trainer::TrainConfig {
        artifact: TINY_HASHNET.into(),
        dataset: Kind::Basic,
        n_train: 400,
        n_test: 300,
        epochs: 3,
        ..Default::default()
    };
    let res = trainer::run(&rt, &cfg, None).unwrap();
    let path = std::env::temp_dir().join(format!("hn_int_{}.ckpt", std::process::id()));
    res.state.save(&path).unwrap();
    let loaded = ModelState::load(&path).unwrap();
    let test = generate(Kind::Basic, Split::Test, 300, cfg.seed);
    let e1 = trainer::evaluate(&rt, TINY_HASHNET, &res.state, &test).unwrap();
    let e2 = trainer::evaluate(&rt, TINY_HASHNET, &loaded, &test).unwrap();
    assert_eq!(e1, e2);
    assert_eq!(e1, res.test_error);
    std::fs::remove_file(&path).ok();
}

#[test]
fn hashnet_beats_equivalent_nn_at_small_budget() {
    // the paper's core claim, tiny-scale: same stored parameter count,
    // HashNet generalizes better than the width-shrunk dense net
    let Some(rt) = runtime() else { return };
    let run = |artifact: &str| {
        let cfg = trainer::TrainConfig {
            artifact: artifact.into(),
            dataset: Kind::Rot,
            n_train: 1500,
            n_test: 1000,
            epochs: 8,
            ..Default::default()
        };
        trainer::run(&rt, &cfg, None).unwrap().test_error
    };
    let hash_err = run("hashnet_3l_h100_o10_c1-64");
    let nn_err = run("nn_3l_h100_o10_c1-64");
    assert!(
        hash_err < nn_err - 0.05,
        "HashNet {hash_err} should clearly beat equivalent NN {nn_err} at 1/64"
    );
}

#[test]
fn serve_end_to_end_over_tcp() {
    use hashednets::serve::{Client, ModelConfig, ServeOptions, Server};
    let Some(_) = runtime() else { return };
    // backend auto: runtime when the artifacts load, native otherwise —
    // either way this exercises the full TCP → batcher → engine path
    let opts = ServeOptions {
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts").into(),
        models: vec![ModelConfig::new(TINY_HASHNET)],
        addr: "127.0.0.1:0".into(),
        max_requests: 0,
        ..Default::default()
    };
    let srv = Server::bind(opts).expect("bind");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());
    let mut client = Client::connect(&addr).expect("connect");
    let ds = generate(Kind::Basic, Split::Test, 3, 1);
    for i in 0..3 {
        let (class, probs, latency) = client.classify(ds.images.row(i)).expect("classify");
        assert!(class < 10);
        assert_eq!(probs.len(), 10);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-3);
        // latency can legitimately round to 0 µs with condvar wakeups;
        // only sanity-bound it from above
        assert!(latency < 10_000_000, "absurd latency {latency}");
    }
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
