//! End-to-end serving over the **native** backend: no PJRT, no HLO
//! artifacts — only a `manifest.json` (synthesized per test) and
//! checkpoints. This is the acceptance path for the engine-registry
//! server: multiple named models, N workers sharing one model, and
//! explicit JSON errors for bad input and failing executors.

use hashednets::coordinator::native;
use hashednets::model::{Method, ModelSpec, QuantSpec, BUNDLE_VERSION};
use hashednets::nn::Network;
use hashednets::runtime::Manifest;
use hashednets::serve::{
    Backend, Client, InferenceEngine, ModelConfig, ServeOptions, Server,
};
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const N_IN: usize = 12;
const N_OUT: usize = 4;
const MANIFEST: &str = r#"{
  "n_in": 12,
  "artifacts": [
    {"name":"hash_a","method":"hashnet","dims":[12,8,4],"budgets":[40,9],
     "batch":4,"seed_base":2654435769,"uses_soft_targets":false,
     "compression":0.35,"virtual_params":140,"stored_params":49,
     "params":[{"name":"w0","shape":[40],"init_std":0.4},
               {"name":"w1","shape":[9],"init_std":0.5}],
     "graphs":{"train":"absent.train.hlo.txt","predict":"absent.predict.hlo.txt"}},
    {"name":"dense_b","method":"nn","dims":[12,6,4],"budgets":[78,28],
     "batch":4,"seed_base":2654435769,"uses_soft_targets":false,
     "compression":1.0,"virtual_params":106,"stored_params":106,
     "params":[{"name":"W0","shape":[6,12],"init_std":0.4},
               {"name":"b0","shape":[6],"init_std":0.0},
               {"name":"W1","shape":[4,6],"init_std":0.5},
               {"name":"b1","shape":[4],"init_std":0.0}],
     "graphs":{"train":"absent.train.hlo.txt","predict":"absent.predict.hlo.txt"}}
  ]
}"#;

/// Temp artifact dir (manifest only — the native backend never reads
/// HLO) + per-model checkpoints + reference networks built from the
/// very same states the server will load.
struct Fixture {
    dir: PathBuf,
    models: Vec<ModelConfig>,
    nets: Vec<(String, Network)>,
}

impl Fixture {
    fn new(tag: &str) -> Fixture {
        let dir =
            std::env::temp_dir().join(format!("hn_serve_native_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("temp dir");
        std::fs::write(dir.join("manifest.json"), MANIFEST).expect("write manifest");
        let manifest = Manifest::parse(MANIFEST).expect("parse manifest");
        let mut models = Vec::new();
        let mut nets = Vec::new();
        for (i, name) in ["hash_a", "dense_b"].iter().enumerate() {
            let spec = manifest.get(name).expect("spec");
            let state = spec.init_state(21 + i as u64);
            let ckpt = dir.join(format!("{name}.ckpt"));
            state.save(&ckpt).expect("save ckpt");
            models.push(ModelConfig::new(*name).with_checkpoint(ckpt));
            nets.push((name.to_string(), native::try_build(spec, &state).expect("build")));
        }
        Fixture { dir, models, nets }
    }

    fn options(&self, workers: usize) -> ServeOptions {
        ServeOptions {
            artifacts_dir: self.dir.clone(),
            models: self.models.clone(),
            addr: "127.0.0.1:0".into(),
            backend: Backend::Native,
            workers,
            ..Default::default()
        }
    }

    fn net(&self, name: &str) -> &Network {
        &self.nets.iter().find(|(n, _)| n == name).expect("net").1
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

/// A deterministic, distinct input row per (client, request).
fn input_row(client: usize, req: usize) -> Vec<f32> {
    (0..N_IN)
        .map(|j| ((client * 131 + req * 17 + j * 7) % 23) as f32 * 0.11 - 1.2)
        .collect()
}

#[test]
fn concurrent_clients_multi_model_match_direct_predict() {
    let fx = Fixture::new("e2e");
    let srv = Server::bind(fx.options(2)).expect("bind native server");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    let names = ["hash_a", "dense_b"];
    // concurrent clients ride the shared PoolExec — the same substrate
    // the kernels use (on a 1-lane machine this degrades to serial
    // clients, which the stats assertions below tolerate)
    hashednets::rt::pool::run(4, |c| {
        let mut client = Client::connect(&addr).expect("connect");
        for r in 0..10 {
            let model = names[(c + r) % 2];
            let pixels = input_row(c, r);
            let x = Matrix::from_vec(1, N_IN, pixels.clone());
            let want_logits = fx.net(model).predict(&x);
            // reference probs through the production softmax
            let want_probs = want_logits.softmax_rows().row(0).to_vec();
            let (class, probs, _lat) = client
                .classify_model(Some(model), &pixels)
                .expect("classify");
            assert_eq!(probs.len(), N_OUT);
            for (a, b) in probs.iter().zip(&want_probs) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "{model} c{c} r{r}: probs {probs:?} vs {want_probs:?}"
                );
            }
            // only pin the class when the reference isn't a
            // near-tie (kernel variants may round differently)
            let mut sorted = want_probs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            if sorted[0] - sorted[1] > 1e-3 {
                let want_class = want_logits.argmax_rows()[0];
                assert_eq!(class, want_class, "{model} c{c} r{r}");
            }
        }
    });

    // default-model routing: no "model" field → first configured model
    let mut client = Client::connect(&addr).expect("connect");
    let pixels = input_row(9, 9);
    let x = Matrix::from_vec(1, N_IN, pixels.clone());
    let want = fx.net("hash_a").predict(&x).softmax_rows();
    let (_, probs, _) = client.classify(&pixels).expect("default model");
    for (a, b) in probs.iter().zip(want.row(0)) {
        assert!((a - b).abs() < 1e-3, "default routing should hit hash_a");
    }

    // per-model stats: 20 + 20 concurrent + 1 default, native backend, 2 workers
    let stats = client.stats().expect("stats");
    let models = stats.get("models").expect("models object");
    let mut total = 0.0;
    for name in names {
        let m = models.get(name).unwrap_or_else(|| panic!("stats for {name}"));
        assert_eq!(m.req_str("backend").unwrap(), "native");
        assert_eq!(m.req_f64("workers").unwrap() as usize, 2);
        assert_eq!(m.req_f64("errors").unwrap(), 0.0);
        assert!(m.req_f64("batches").unwrap() >= 1.0);
        total += m.req_f64("served").unwrap();
    }
    assert_eq!(total as u64, 41);
    // counter consistency: each top-level aggregate equals the sum of
    // the per-model counters
    assert_eq!(stats.req_f64("served").unwrap() as u64, 41, "top-level == sum per-model");
    assert_eq!(stats.req_f64("errors").unwrap(), 0.0);
    assert_eq!(stats.req_f64("rejected").unwrap(), 0.0);
    assert_eq!(stats.req_f64("expired").unwrap(), 0.0);

    // health: all workers live, queues drained
    let health = client.health().expect("health");
    assert_eq!(health.get("ok").and_then(|v| v.as_bool()), Some(true));
    for name in names {
        let h = health.get("models").and_then(|ms| ms.get(name)).expect("health entry");
        assert_eq!(h.req_f64("live_workers").unwrap() as usize, 2, "{name}");
        assert_eq!(h.req_f64("queue_depth").unwrap(), 0.0, "{name}");
    }

    client.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

#[test]
fn wrong_pixel_count_is_explicit_json_error() {
    let fx = Fixture::new("badlen");
    let srv = Server::bind(fx.options(1)).expect("bind");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    let mut client = Client::connect(&addr).expect("connect");
    let err = client.classify(&[0.5f32; 5]).expect_err("short input must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("expects 12 pixels"), "{msg}");
    assert!(msg.contains("got 5"), "{msg}");

    // the connection and the model still work after a rejected request
    let (_, probs, _) = client.classify(&input_row(0, 0)).expect("valid request");
    assert_eq!(probs.len(), N_OUT);

    let stats = client.stats().expect("stats");
    let m = stats.get("models").and_then(|ms| ms.get("hash_a")).expect("hash_a stats");
    assert_eq!(m.req_f64("errors").unwrap(), 1.0);
    // the per-model error rolls up into the top-level aggregate
    assert_eq!(stats.req_f64("errors").unwrap(), 1.0);

    client.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

#[test]
fn unknown_model_is_explicit_json_error() {
    let fx = Fixture::new("nomodel");
    let srv = Server::bind(fx.options(1)).expect("bind");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    let mut client = Client::connect(&addr).expect("connect");
    let err = client
        .classify_model(Some("no_such_model"), &input_row(0, 0))
        .expect_err("unknown model must fail");
    assert!(format!("{err:#}").contains("unknown model"), "{err:#}");

    client.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// The acceptance path for hot-(re)loadable serving: a bundle "trained"
/// **after** the server is up is pushed into the running registry via
/// `{"cmd":"load"}` and served correctly, while existing connections to
/// the other models keep classifying uninterrupted. Then `reload`
/// rebuilds every model from disk and `unload` removes one, without
/// disturbing the rest.
#[test]
fn hot_load_serves_new_bundle_while_old_connections_continue() {
    // the checkers must run *while* the admin issues load/reload, so
    // they live on dedicated threads (Arc'd fixture), not pool tasks —
    // a pool `run` would block this thread until they finished
    let fx = Arc::new(Fixture::new("hotload"));
    let srv = Server::bind(fx.options(2)).expect("bind");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    // A model the server has never heard of, created post-startup.
    let spec_c = ModelSpec::new(
        "hash_c",
        Method::Hashnet,
        vec![N_IN, 10, N_OUT],
        vec![50, 11],
        hashednets::hash::DEFAULT_SEED_BASE,
        4,
    )
    .expect("spec_c");
    let mut cnet = Network::from_spec(&spec_c).expect("net_c");
    cnet.init(&mut Pcg32::new(77, 0));
    let bundle_c = cnet.to_bundle(&spec_c).expect("bundle_c");
    let path_c = fx.dir.join("hash_c.hnb");
    bundle_c.save(&path_c).expect("save bundle_c");

    let stop = Arc::new(AtomicBool::new(false));
    // Existing connections: hammer the pre-loaded models throughout
    // the {"cmd":"load"} and verify every reply against the local
    // reference network — any interruption fails the expect.
    let checkers: Vec<std::thread::JoinHandle<usize>> = (0..2)
        .map(|c| {
            let addr = addr.clone();
            let fx = Arc::clone(&fx);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let mut served = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let model = if c == 0 { "hash_a" } else { "dense_b" };
                    let pixels = input_row(c, served);
                    let x = Matrix::from_vec(1, N_IN, pixels.clone());
                    let want = fx.net(model).predict(&x).softmax_rows();
                    let (_cl, probs, _) = client
                        .classify_model(Some(model), &pixels)
                        .expect("existing connection must stay uninterrupted");
                    for (a, b) in probs.iter().zip(want.row(0)) {
                        assert!((a - b).abs() < 1e-3, "{model} drifted during hot-load");
                    }
                    served += 1;
                }
                served
            })
        })
        .collect();

    {
        let mut admin = Client::connect(&addr).expect("admin connect");
        // give the checkers time to get traffic flowing first
        std::thread::sleep(std::time::Duration::from_millis(100));

        // load the new bundle into the running server…
        let reply = admin.load_model(path_c.to_str().unwrap()).expect("load");
        assert_eq!(reply.req_str("model").unwrap(), "hash_c");
        // …and it serves correctly immediately
        for r in 0..5 {
            let pixels = input_row(9, r);
            let x = Matrix::from_vec(1, N_IN, pixels.clone());
            let want = cnet.predict(&x).softmax_rows();
            let (_cl, probs, _) = admin
                .classify_model(Some("hash_c"), &pixels)
                .expect("hot-loaded model classify");
            assert_eq!(probs.len(), N_OUT);
            for (a, b) in probs.iter().zip(want.row(0)) {
                assert!((a - b).abs() < 1e-3, "hash_c reply diverges from its bundle");
            }
        }
        // registry metadata reflects the new model
        let models = admin.models().expect("models cmd");
        let mc = models.get("models").and_then(|m| m.get("hash_c")).expect("hash_c listed");
        assert_eq!(mc.req_str("method").unwrap(), "hashnet");
        assert_eq!(mc.req_f64("bundle_version").unwrap() as u32, BUNDLE_VERSION);
        assert_eq!(mc.req_f64("stored_params").unwrap() as usize, 61);

        // let the uninterrupted-traffic claim accumulate some evidence,
        // then stop the checkers before reload (a swap may fail the
        // handful of requests already queued on a displaced handle —
        // that is the documented drain behavior, not an interruption
        // of *other* models)
        std::thread::sleep(std::time::Duration::from_millis(100));
        stop.store(true, Ordering::Relaxed);
        let total: usize = checkers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total >= 10, "checkers only served {total} requests");

        // reload: every model rebuilt from its source, still serving
        let r = admin.reload().expect("reload");
        assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
        admin.classify_model(Some("hash_c"), &input_row(2, 1)).expect("hash_c after reload");
        admin.classify_model(Some("hash_a"), &input_row(2, 2)).expect("hash_a after reload");

        // unload: gone afterwards, the others unaffected
        admin.unload_model("hash_c").expect("unload");
        let err = admin
            .classify_model(Some("hash_c"), &input_row(2, 3))
            .expect_err("unloaded model must not serve");
        assert!(format!("{err:#}").contains("unknown model"), "{err:#}");
        admin.classify_model(Some("dense_b"), &input_row(2, 4)).expect("dense_b after unload");

        admin.shutdown().expect("shutdown");
    }
    server.join().unwrap().expect("server run");
}

/// Hot-**swap** with a *quantized* v2 bundle while traffic is in
/// flight: `{"cmd":"load"}` replaces the serving `hash_a` with an int8
/// bundle of the same name (mmap + checksum + dequantize-once on the
/// server side). Every request issued across the swap must get exactly
/// one explicit reply — a classification, or the typed `unloaded` drain
/// error for requests already queued on the displaced handle — and
/// post-swap replies must match the quantized network bit-for-bit at
/// the softmax tolerance.
#[test]
fn hot_swap_to_quantized_bundle_drains_inflight_with_one_reply_each() {
    let fx = Arc::new(Fixture::new("hotquant"));
    let srv = Server::bind(fx.options(2)).expect("bind");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    // Same name, same shape, fresh weights — then int8-quantized. The
    // reference network is built from the *quantized* bundle, so the
    // expectation includes the dequantization error by construction.
    let spec_q = ModelSpec::new(
        "hash_a",
        Method::Hashnet,
        vec![N_IN, 8, N_OUT],
        vec![40, 9],
        hashednets::hash::DEFAULT_SEED_BASE,
        4,
    )
    .expect("spec_q");
    let mut qsrc = Network::from_spec(&spec_q).expect("net_q");
    qsrc.init(&mut Pcg32::new(0xA11CE, 3));
    let qbundle = qsrc
        .to_bundle(&spec_q)
        .expect("bundle_q")
        .quantize(QuantSpec::Int8)
        .expect("int8 quantize");
    assert!(qbundle.is_quantized());
    let qnet = Network::from_bundle(&qbundle).expect("dequantized reference");
    let path_q = fx.dir.join("hash_a_int8.hnb");
    qbundle.save(&path_q).expect("save quantized bundle");

    // Checkers hammer hash_a straight through the swap. Mid-swap a
    // reply may come from the old weights, the new weights, or be the
    // typed drain error — but it must always be exactly ONE reply per
    // request (classify_raw panics on transport error or timeout).
    let stop = Arc::new(AtomicBool::new(false));
    let checkers: Vec<std::thread::JoinHandle<(usize, usize)>> = (0..2)
        .map(|c| {
            let addr = addr.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                client
                    .set_read_timeout(Some(std::time::Duration::from_secs(15)))
                    .expect("read timeout");
                let (mut answered, mut drained) = (0usize, 0usize);
                while !stop.load(Ordering::Relaxed) {
                    let reply = client
                        .classify_raw(Some("hash_a"), &input_row(c, answered + drained), Some(8_000))
                        .expect("exactly one reply per request, never a hang");
                    if reply.get("class").is_some() {
                        answered += 1;
                    } else {
                        // only the documented drain error is acceptable
                        let code =
                            reply.get("code").and_then(|v| v.as_str()).unwrap_or("").to_string();
                        assert_eq!(code, "unloaded", "unexpected reply {reply:?}");
                        drained += 1;
                    }
                }
                (answered, drained)
            })
        })
        .collect();

    let mut admin = Client::connect(&addr).expect("admin connect");
    // let traffic build up, then swap under load
    std::thread::sleep(std::time::Duration::from_millis(100));
    let reply = admin.load_model(path_q.to_str().unwrap()).expect("hot-swap load");
    assert_eq!(reply.req_str("model").unwrap(), "hash_a");
    assert_eq!(reply.get("swapped").and_then(|v| v.as_bool()), Some(true));

    // post-swap: replies come from the quantized weights
    for r in 0..5 {
        let pixels = input_row(7, r);
        let x = Matrix::from_vec(1, N_IN, pixels.clone());
        let want = qnet.predict(&x).softmax_rows();
        let (_cl, probs, _) = admin
            .classify_model(Some("hash_a"), &pixels)
            .expect("quantized model classify");
        for (a, b) in probs.iter().zip(want.row(0)) {
            assert!((a - b).abs() < 1e-3, "post-swap reply is not the quantized model");
        }
    }
    // registry metadata reflects the v2 quantized bundle
    let models = admin.models().expect("models cmd");
    let mc = models.get("models").and_then(|m| m.get("hash_a")).expect("hash_a listed");
    assert_eq!(mc.req_f64("bundle_version").unwrap() as u32, BUNDLE_VERSION);

    // keep traffic flowing on the new engine a moment, then tally
    std::thread::sleep(std::time::Duration::from_millis(100));
    stop.store(true, Ordering::Relaxed);
    let (mut answered, mut drained) = (0usize, 0usize);
    for handle in checkers {
        let (a, d) = handle.join().expect("checker thread");
        answered += a;
        drained += d;
    }
    assert!(answered >= 10, "checkers only got {answered} classifications");
    // drained may be 0 (fast swap) — but whatever was displaced must
    // have been answered, which the per-request expect already proved
    let _ = drained;

    // the other model was untouched throughout
    let pixels = input_row(5, 5);
    let x = Matrix::from_vec(1, N_IN, pixels.clone());
    let want = fx.net("dense_b").predict(&x).softmax_rows();
    let (_cl, probs, _) = admin.classify_model(Some("dense_b"), &pixels).expect("dense_b");
    for (a, b) in probs.iter().zip(want.row(0)) {
        assert!((a - b).abs() < 1e-3, "dense_b drifted during the quantized swap");
    }

    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// An engine whose executor always fails — exercises the
/// dispatch-error path end to end: the client gets the error string
/// immediately instead of waiting out a receive timeout.
struct FailingEngine;

impl InferenceEngine for FailingEngine {
    fn predict(&self, _x: &Matrix) -> anyhow::Result<Matrix> {
        Err(anyhow::anyhow!("injected backend failure"))
    }

    fn n_in(&self) -> usize {
        N_IN
    }

    fn n_out(&self) -> usize {
        N_OUT
    }

    fn max_batch(&self) -> usize {
        4
    }

    fn name(&self) -> &'static str {
        "failing"
    }
}

#[test]
fn executor_failure_reaches_client_as_json_error() {
    let opts = ServeOptions {
        artifacts_dir: std::env::temp_dir().join("hn_serve_no_artifacts"),
        models: Vec::new(), // registry comes entirely from the injected engine
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 2,
        ..Default::default()
    };
    let engines: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)> =
        vec![("boom".to_string(), Arc::new(FailingEngine))];
    let srv = Server::bind_with_engines(opts, engines).expect("bind with injected engine");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    let mut client = Client::connect(&addr).expect("connect");
    let t0 = std::time::Instant::now();
    let err = client.classify(&[0.0f32; N_IN]).expect_err("failing engine");
    assert!(
        format!("{err:#}").contains("injected backend failure"),
        "{err:#}"
    );
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "error must fail fast, not ride the recv timeout"
    );

    let stats = client.stats().expect("stats");
    let m = stats.get("models").and_then(|ms| ms.get("boom")).expect("boom stats");
    assert_eq!(m.req_str("backend").unwrap(), "failing");
    assert_eq!(m.req_f64("errors").unwrap(), 1.0);
    assert_eq!(m.req_f64("served").unwrap(), 0.0);

    client.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// An engine that blocks in `predict` until its gate opens — lets a
/// test pin requests in flight / in queue at a chosen moment.
struct GatedEngine {
    gate: Arc<AtomicBool>,
}

impl InferenceEngine for GatedEngine {
    fn predict(&self, x: &Matrix) -> anyhow::Result<Matrix> {
        let t0 = std::time::Instant::now();
        while !self.gate.load(Ordering::Relaxed) {
            if t0.elapsed() > std::time::Duration::from_secs(10) {
                anyhow::bail!("gate never opened");
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        Ok(Matrix::zeros(x.rows, N_OUT))
    }

    fn n_in(&self) -> usize {
        N_IN
    }

    fn n_out(&self) -> usize {
        N_OUT
    }

    fn max_batch(&self) -> usize {
        1 // one request per dispatch, so the rest stay visibly queued
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

/// The unload-vs-inflight race: a model unloaded while requests are
/// queued must answer **every one** of them explicitly — served, or a
/// typed `unloaded` error — within the deadline. The retire/close/
/// drain dance in `server.rs` claims this; here it runs under real
/// concurrency: one worker pinned mid-predict, five requests queued,
/// unload racing the release.
#[test]
fn unload_with_inflight_requests_answers_every_one_explicitly() {
    let gate = Arc::new(AtomicBool::new(false));
    let opts = ServeOptions {
        artifacts_dir: std::env::temp_dir().join("hn_serve_no_artifacts"),
        models: Vec::new(),
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 1,
        ..Default::default()
    };
    let engines: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)> =
        vec![("victim".to_string(), Arc::new(GatedEngine { gate: Arc::clone(&gate) }))];
    let srv = Server::bind_with_engines(opts, engines).expect("bind");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    // 6 concurrent requests: the worker pins the first mid-predict
    // (gate closed), the rest queue behind it.
    const N_REQS: usize = 6;
    let clients: Vec<std::thread::JoinHandle<hashednets::util::json::Json>> = (0..N_REQS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                client
                    .set_read_timeout(Some(std::time::Duration::from_secs(15)))
                    .expect("read timeout");
                client
                    .classify_raw(Some("victim"), &input_row(c, 0), Some(8_000))
                    .expect("every request must get an explicit reply, not a hang")
            })
        })
        .collect();

    // wait (via health) until the requests are demonstrably queued
    let mut admin = Client::connect(&addr).expect("admin connect");
    let t0 = std::time::Instant::now();
    loop {
        let health = admin.health().expect("health");
        let depth = health
            .get("models")
            .and_then(|ms| ms.get("victim"))
            .map(|h| h.req_f64("queue_depth").unwrap())
            .unwrap_or(0.0);
        if depth >= (N_REQS - 2) as f64 {
            break; // one in flight, the rest pending
        }
        assert!(t0.elapsed() < std::time::Duration::from_secs(10), "requests never queued");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    // unload races the gate: retire() blocks joining the pinned worker
    // until the gate opens, then must fail every queued request fast
    let unloader = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut admin2 = Client::connect(&addr).expect("unloader connect");
            admin2.unload_model("victim").expect("unload")
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(150));
    gate.store(true, Ordering::Relaxed);
    unloader.join().expect("unloader thread").req_str("model").map(drop).expect("unload ok");

    // exactly one explicit outcome per request: served (the in-flight
    // one, plus any batch the worker grabbed before observing stop) or
    // a typed "unloaded" error — and the retire path must produce at
    // least one of the latter for the provably-queued requests
    let mut served = 0usize;
    let mut unloaded = 0usize;
    for handle in clients {
        let reply = handle.join().expect("client thread");
        if reply.get("class").is_some() {
            served += 1;
        } else {
            let code = reply.get("code").and_then(|c| c.as_str()).unwrap_or("").to_string();
            assert_eq!(code, "unloaded", "unexpected reply {reply:?}");
            unloaded += 1;
        }
    }
    assert_eq!(served + unloaded, N_REQS);
    assert!(unloaded >= 1, "retire must fail the queued requests explicitly");

    // the model is gone; the server is otherwise healthy
    let reply = admin
        .classify_raw(Some("victim"), &input_row(0, 1), Some(1_000))
        .expect("transport ok");
    assert_eq!(reply.get("code").and_then(|c| c.as_str()), Some("unknown_model"));

    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

#[test]
fn runtime_backend_fails_eagerly_without_pjrt_but_auto_falls_back() {
    let fx = Fixture::new("backends");
    // explicit runtime backend: bind must fail eagerly when PJRT (or
    // the HLO files) are unavailable…
    let mut opt = fx.options(1);
    opt.backend = Backend::Runtime;
    match Server::bind(opt) {
        Err(_) => {} // expected offline (xla stub / no HLO files)
        Ok(srv) => {
            // …with a real PJRT toolchain this config would be valid;
            // shut it down cleanly so the test passes either way.
            let addr = srv.local_addr().to_string();
            let server = std::thread::spawn(move || srv.run());
            Client::connect(&addr).expect("connect").shutdown().ok();
            server.join().unwrap().ok();
        }
    }
    // …while auto silently degrades to the native engine.
    let mut opt = fx.options(2);
    opt.backend = Backend::Auto;
    let srv = Server::bind(opt).expect("auto must fall back to native");
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());
    let mut client = Client::connect(&addr).expect("connect");
    let (_, probs, _) = client.classify(&input_row(3, 3)).expect("native fallback");
    assert_eq!(probs.len(), N_OUT);
    client.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}
