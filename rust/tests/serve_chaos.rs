//! Resilience acceptance: the serving stack under injected faults and
//! overload, end to end over the wire.
//!
//! Fast tests pin each mechanism in isolation — panic containment,
//! O(1) overload rejection + recovery, retry backoff, and the
//! deadline/timeout error codes. The `#[ignore]`d soak (`make soak`)
//! then runs them all at once: N concurrent clients × seeded
//! [`ChaosEngine`] models (errors + latency spikes + panics) ×
//! concurrent hot-load/unload/reload churn, asserting the invariant
//! the whole layer exists for — **every submitted request receives
//! exactly one explicit reply, no worker dies permanently, and the
//! server drains to a clean shutdown**.

use hashednets::model::{Method, ModelSpec};
use hashednets::nn::{LayerKind, Network};
use hashednets::serve::{
    Backend, ChaosConfig, ChaosEngine, Client, InferenceEngine, ServeOptions, Server,
};
use hashednets::tensor::Matrix;
use hashednets::util::json::Json;
use hashednets::util::rng::Pcg32;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_IN: usize = 8;
const N_OUT: usize = 3;

/// A small healthy native engine for the chaos wrapper to decorate.
fn tiny_native(seed: u64) -> Arc<dyn InferenceEngine + Send + Sync> {
    let mut net = Network::from_dims(
        &[N_IN, 6, N_OUT],
        vec![LayerKind::Hashed { k: 16 }, LayerKind::Dense],
        hashednets::hash::DEFAULT_SEED_BASE,
    );
    net.init(&mut Pcg32::new(seed, 5));
    Arc::new(hashednets::serve::NativeEngine::from_network(net, 4))
}

fn input_row(client: usize, req: usize) -> Vec<f32> {
    (0..N_IN)
        .map(|j| ((client * 97 + req * 13 + j * 5) % 19) as f32 * 0.13 - 1.1)
        .collect()
}

fn base_options() -> ServeOptions {
    ServeOptions {
        artifacts_dir: std::env::temp_dir().join("hn_serve_chaos_no_artifacts"),
        models: Vec::new(),
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 2,
        ..Default::default()
    }
}

fn bind_with(
    opts: ServeOptions,
    engines: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)>,
) -> (std::thread::JoinHandle<anyhow::Result<()>>, String) {
    let srv = Server::bind_with_engines(opts, engines).expect("bind");
    let addr = srv.local_addr().to_string();
    (std::thread::spawn(move || srv.run()), addr)
}

/// An engine that blocks in `predict` until its gate opens — used to
/// pin workers and fill queues at a chosen moment.
struct GatedEngine {
    gate: Arc<AtomicBool>,
}

impl InferenceEngine for GatedEngine {
    fn predict(&self, x: &Matrix) -> anyhow::Result<Matrix> {
        let t0 = Instant::now();
        while !self.gate.load(Ordering::Relaxed) {
            if t0.elapsed() > Duration::from_secs(10) {
                anyhow::bail!("gate never opened");
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(Matrix::zeros(x.rows, N_OUT))
    }

    fn n_in(&self) -> usize {
        N_IN
    }

    fn n_out(&self) -> usize {
        N_OUT
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

fn queue_depth(admin: &mut Client, model: &str) -> f64 {
    admin
        .health()
        .expect("health")
        .get("models")
        .and_then(|ms| ms.get(model))
        .map(|h| h.req_f64("queue_depth").unwrap())
        .unwrap_or(0.0)
}

/// A panicking engine must fail each batch with an explicit typed
/// reply while its workers stay alive and the server shuts down clean.
#[test]
fn engine_panic_is_contained_and_reported() {
    let chaos = Arc::new(ChaosEngine::new(
        tiny_native(11),
        ChaosConfig { seed: 11, panic_rate: 1.0, ..ChaosConfig::default() },
    ));
    let (server, addr) = bind_with(base_options(), vec![("chaos".into(), chaos.clone())]);

    let mut client = Client::connect(&addr).expect("connect");
    client.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    for r in 0..6 {
        let reply = client
            .classify_raw(Some("chaos"), &input_row(0, r), Some(5_000))
            .expect("explicit reply, not a hang");
        assert_eq!(reply.get("code").and_then(|c| c.as_str()), Some("engine"), "{reply:?}");
        assert!(
            reply.req_str("error").unwrap().contains("injected panic"),
            "{reply:?}"
        );
    }
    assert_eq!(chaos.stats().panics_injected, 6);

    // every panic was contained: both workers still live, queue empty
    let health = client.health().expect("health");
    assert_eq!(health.get("ok").and_then(|v| v.as_bool()), Some(true));
    let h = health.get("models").and_then(|ms| ms.get("chaos")).expect("chaos health");
    assert_eq!(h.req_f64("live_workers").unwrap() as usize, 2);
    assert_eq!(h.req_f64("queue_depth").unwrap(), 0.0);
    assert!(h.req_f64("panics_contained").unwrap() >= 1.0);

    client.shutdown().expect("shutdown");
    server.join().unwrap().expect("clean shutdown after panics");
}

/// A full queue rejects new work immediately (O(1), explicit
/// `overloaded` + `retry_after_ms`) and recovers once it drains.
#[test]
fn full_queue_overloads_immediately_and_recovers() {
    let gate = Arc::new(AtomicBool::new(false));
    let mut opts = base_options();
    opts.workers = 1;
    opts.max_pending = 2;
    let (server, addr) =
        bind_with(opts, vec![("gated".into(), Arc::new(GatedEngine { gate: gate.clone() }))]);

    // pin the single worker first (give it time to pull the request
    // off the queue), then fill the 2-slot queue behind it — the
    // stagger keeps the fillers themselves out of rejection range
    let spawn_blocked = |c: usize| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            client.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
            client
                .classify_raw(Some("gated"), &input_row(c, 0), Some(8_000))
                .expect("explicit reply")
        })
    };
    let mut blocked = vec![spawn_blocked(0)];
    std::thread::sleep(Duration::from_millis(200));
    blocked.push(spawn_blocked(1));
    blocked.push(spawn_blocked(2));
    let mut admin = Client::connect(&addr).expect("admin");
    admin.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let t0 = Instant::now();
    while queue_depth(&mut admin, "gated") < 2.0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "queue never filled");
        std::thread::sleep(Duration::from_millis(5));
    }

    // 4th request: immediate rejection, not a blocked connection thread
    let t0 = Instant::now();
    let reply = admin
        .classify_raw(Some("gated"), &input_row(9, 0), Some(8_000))
        .expect("transport ok");
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "overload rejection must be O(1), took {:?}",
        t0.elapsed()
    );
    assert_eq!(reply.get("code").and_then(|c| c.as_str()), Some("overloaded"), "{reply:?}");
    assert!(reply.req_f64("retry_after_ms").unwrap() >= 1.0, "{reply:?}");

    // release: the pinned + queued requests all serve
    gate.store(true, Ordering::Relaxed);
    for b in blocked {
        let reply = b.join().expect("client thread");
        assert!(reply.get("class").is_some(), "queued request must serve: {reply:?}");
    }
    // and capacity is back
    let reply = admin.classify_raw(Some("gated"), &input_row(9, 1), Some(8_000)).unwrap();
    assert!(reply.get("class").is_some(), "{reply:?}");

    // the rejection is counted per-model and aggregated at top level
    let stats = admin.stats().expect("stats");
    assert_eq!(stats.req_f64("rejected").unwrap(), 1.0);
    let m = stats.get("models").and_then(|ms| ms.get("gated")).expect("gated stats");
    assert_eq!(m.req_f64("rejected").unwrap(), 1.0);

    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// `classify_retry` turns transient overload into eventual success by
/// backing off on the server's hint.
#[test]
fn classify_retry_backs_off_through_transient_overload() {
    let gate = Arc::new(AtomicBool::new(false));
    let mut opts = base_options();
    opts.workers = 1;
    opts.max_pending = 1;
    let (server, addr) =
        bind_with(opts, vec![("gated".into(), Arc::new(GatedEngine { gate: gate.clone() }))]);

    // pin the worker first, then fill the single queue slot (staggered
    // so the filler itself is admitted, not rejected)
    let spawn_blocked = |c: usize| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            client.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
            client
                .classify_raw(Some("gated"), &input_row(c, 0), Some(8_000))
                .expect("explicit reply")
        })
    };
    let mut blocked = vec![spawn_blocked(0)];
    std::thread::sleep(Duration::from_millis(200));
    blocked.push(spawn_blocked(1));
    let mut admin = Client::connect(&addr).expect("admin");
    admin.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let t0 = Instant::now();
    while queue_depth(&mut admin, "gated") < 1.0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "queue never filled");
        std::thread::sleep(Duration::from_millis(5));
    }

    // open the gate shortly after the retry loop starts: the first
    // attempt sees `overloaded`, a backed-off retry finds capacity
    let opener = {
        let gate = gate.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            gate.store(true, Ordering::Relaxed);
        })
    };
    let reply = admin
        .classify_retry(Some("gated"), &input_row(9, 0), Some(8_000), 10)
        .expect("transport ok");
    assert!(reply.get("class").is_some(), "retry must land: {reply:?}");
    opener.join().unwrap();
    for b in blocked {
        assert!(b.join().expect("client").get("class").is_some());
    }
    let stats = admin.stats().expect("stats");
    assert!(stats.req_f64("rejected").unwrap() >= 1.0, "the first attempt was rejected");

    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// The hardcoded 10 s receive timeout is gone: a request with a small
/// `timeout_ms` fails within ~its own deadline, with a typed code —
/// `deadline` when the batcher expired it at batch formation, or
/// `timeout` when the reply never arrived — and the server counts the
/// expiry. `timeout` is also asserted distinct from `overloaded`: the
/// queue had room, so no rejection was involved.
#[test]
fn small_deadline_fails_fast_with_typed_code() {
    let gate = Arc::new(AtomicBool::new(false));
    let mut opts = base_options();
    opts.workers = 1;
    let (server, addr) =
        bind_with(opts, vec![("gated".into(), Arc::new(GatedEngine { gate: gate.clone() }))]);

    // pin the worker with a long-deadline request…
    let pinned = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            client.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
            client
                .classify_raw(Some("gated"), &input_row(0, 0), Some(8_000))
                .expect("explicit reply")
        })
    };
    // …and give the worker time to pull it off the queue. With
    // max_batch 1 a later request sits behind it either way; the sleep
    // only makes the "behind a busy worker" shape typical.
    std::thread::sleep(Duration::from_millis(250));
    let mut admin = Client::connect(&addr).expect("admin");
    admin.set_read_timeout(Some(Duration::from_secs(15))).unwrap();

    // …then a 500 ms request that can only sit behind the pinned one
    let opener = {
        let gate = gate.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(650));
            gate.store(true, Ordering::Relaxed);
        })
    };
    let t0 = Instant::now();
    let reply = admin.classify_raw(Some("gated"), &input_row(1, 0), Some(500)).expect("transport");
    let elapsed = t0.elapsed();
    let code = reply.get("code").and_then(|c| c.as_str()).unwrap_or("").to_string();
    assert!(
        code == "deadline" || code == "timeout",
        "expected a deadline-family failure, got {reply:?}"
    );
    assert_ne!(code, "overloaded", "deadline failures must be distinguishable from overload");
    assert!(
        elapsed < Duration::from_secs(3),
        "a 500 ms budget must not ride a 10 s timeout: {elapsed:?}"
    );

    opener.join().unwrap();
    let _ = pinned.join().expect("pinned client");
    // the batcher (not just the connection backstop) saw the expiry
    let t0 = Instant::now();
    loop {
        let stats = admin.stats().expect("stats");
        if stats.req_f64("expired").unwrap() >= 1.0 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "batcher never expired the dead request: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// The full chaos soak (run via `make soak`; `#[ignore]`d so tier-1
/// stays fast): concurrent clients × seeded chaos models × bundle
/// churn. Asserts the layer's invariant end to end.
#[test]
#[ignore]
fn chaos_soak_every_request_gets_exactly_one_explicit_reply() {
    const CLIENTS: usize = 6;
    const REQS_PER_CLIENT: usize = 150;
    const MODELS: usize = 3;

    // three chaos models with distinct seeds and the full fault menu
    let chaos: Vec<Arc<ChaosEngine>> = (0..MODELS as u64)
        .map(|i| {
            Arc::new(ChaosEngine::new(
                tiny_native(100 + i),
                ChaosConfig {
                    seed: 1 + i,
                    error_rate: 0.05,
                    panic_rate: 0.02,
                    latency_rate: 0.05,
                    latency: Duration::from_millis(3),
                },
            ))
        })
        .collect();
    let engines: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)> = chaos
        .iter()
        .enumerate()
        .map(|(i, e)| {
            (format!("chaos_{i}"), e.clone() as Arc<dyn InferenceEngine + Send + Sync>)
        })
        .collect();
    let mut opts = base_options();
    opts.workers = 2;
    opts.max_pending = 64;
    opts.default_timeout = Duration::from_secs(2);
    let (server, addr) = bind_with(opts, engines);

    // churn thread: hot-load a real bundle, reload everything, unload —
    // ~30 full cycles racing the classify traffic
    let churn_dir = std::env::temp_dir().join(format!("hn_chaos_churn_{}", std::process::id()));
    std::fs::create_dir_all(&churn_dir).expect("churn dir");
    let spec = ModelSpec::new(
        "extra",
        Method::Hashnet,
        vec![N_IN, 6, N_OUT],
        vec![24, 10],
        hashednets::hash::DEFAULT_SEED_BASE,
        4,
    )
    .expect("spec");
    let mut enet = Network::from_spec(&spec).expect("net");
    enet.init(&mut Pcg32::new(55, 0));
    let bundle_path = churn_dir.join("extra.hnb");
    enet.to_bundle(&spec).expect("bundle").save(&bundle_path).expect("save");
    let churn = {
        let addr = addr.clone();
        let path = bundle_path.to_str().unwrap().to_string();
        std::thread::spawn(move || {
            let mut admin = Client::connect(&addr).expect("churn connect");
            admin.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
            for _ in 0..30 {
                admin.load_model(&path).expect("load");
                let r = admin.reload().expect("reload");
                assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true), "{r:?}");
                admin.unload_model("extra").expect("unload");
            }
        })
    };

    // client fleet: every request must produce exactly one explicit
    // outcome — a class or a typed error code — never a hang or a
    // transport failure
    let clients: Vec<std::thread::JoinHandle<(usize, Vec<String>)>> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                let mut ok = 0usize;
                let mut codes = Vec::new();
                for r in 0..REQS_PER_CLIENT {
                    let model = format!("chaos_{}", (c + r) % MODELS);
                    let reply = client
                        .classify_retry(Some(&model), &input_row(c, r), Some(1_500), 4)
                        .unwrap_or_else(|e| {
                            panic!("c{c} r{r}: transport failure instead of explicit reply: {e:#}")
                        });
                    if reply.get("class").is_some() {
                        ok += 1;
                    } else {
                        let code = reply
                            .get("code")
                            .and_then(|v| v.as_str())
                            .unwrap_or_else(|| panic!("c{c} r{r}: untyped error {reply:?}"))
                            .to_string();
                        assert!(
                            matches!(
                                code.as_str(),
                                "overloaded" | "deadline" | "timeout" | "engine" | "unloaded"
                                    | "unknown_model" | "bad_input"
                            ),
                            "c{c} r{r}: unexpected code {code}"
                        );
                        codes.push(code);
                    }
                }
                (ok, codes)
            })
        })
        .collect();

    let mut total_ok = 0usize;
    let mut total_failed = 0usize;
    let mut engine_errors = 0usize;
    for c in clients {
        let (ok, codes) = c.join().expect("client thread must not die");
        total_ok += ok;
        total_failed += codes.len();
        engine_errors += codes.iter().filter(|s| s.as_str() == "engine").count();
    }
    churn.join().expect("churn thread must not die");
    std::fs::remove_dir_all(&churn_dir).ok();

    // exactly one explicit outcome per request
    assert_eq!(total_ok + total_failed, CLIENTS * REQS_PER_CLIENT);
    // the soak genuinely exercised the fault paths: the chaos layer
    // injected faults and clients saw some typed engine failures
    let injected: u64 = chaos
        .iter()
        .map(|e| {
            let s = e.stats();
            s.errors_injected + s.panics_injected
        })
        .sum();
    assert!(injected > 0, "chaos layer never fired — soak proved nothing");
    assert!(engine_errors > 0, "no injected fault ever reached a client as a typed error");
    assert!(total_ok > 0, "nothing served — the fleet only saw errors");

    // no worker died permanently despite the injected panics
    let mut admin = Client::connect(&addr).expect("admin");
    admin.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let health = admin.health().expect("health");
    assert_eq!(health.get("ok").and_then(|v| v.as_bool()), Some(true), "{health:?}");
    for i in 0..MODELS {
        let h = health
            .get("models")
            .and_then(|ms| ms.get(&format!("chaos_{i}")))
            .expect("chaos health");
        assert_eq!(h.req_f64("live_workers").unwrap() as usize, 2, "chaos_{i} lost a worker");
    }

    // counter consistency under churn: top-level == sum of per-model
    let stats = admin.stats().expect("stats");
    let models = stats.get("models").expect("models");
    let mut errors = 0.0;
    let mut rejected = 0.0;
    let mut expired = 0.0;
    for i in 0..MODELS {
        let m = models.get(&format!("chaos_{i}")).expect("model stats");
        errors += m.req_f64("errors").unwrap();
        rejected += m.req_f64("rejected").unwrap();
        expired += m.req_f64("expired").unwrap();
    }
    assert_eq!(stats.req_f64("errors").unwrap(), errors);
    assert_eq!(stats.req_f64("rejected").unwrap(), rejected);
    assert_eq!(stats.req_f64("expired").unwrap(), expired);

    // and the server drains to a clean shutdown
    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("clean shutdown after the soak");
}
