//! Wire-protocol acceptance for the event-loop front end: the binary
//! frame format and JSON must be interchangeable on one port (same
//! semantics, same error taxonomy), binary must do strictly less
//! per-request allocation work than JSON (asserted structurally here,
//! measured in `benches/serve_scale.rs`), and protocol violations must
//! produce typed errors, not hangs or misrouted replies.

use hashednets::serve::frame::{self, FrameReply};
use hashednets::serve::{
    Backend, Client, FrameClient, InferenceEngine, ServeOptions, Server,
};
use hashednets::tensor::Matrix;
use hashednets::util::json::Json;
use hashednets::util::rng::Pcg32;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---- counting allocator: the structural "binary < JSON" assertion ----

/// Counts heap allocations per thread. Const-initialized `Cell<usize>`
/// TLS has no destructor and no lazy init, so the allocator never
/// recurses into itself and never touches torn-down TLS.
struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<usize> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.with(|c| c.get())
}

// ---- server scaffolding (same idiom as serve_chaos.rs) ----

const N_IN: usize = 8;
const N_OUT: usize = 3;

fn tiny_native(seed: u64) -> Arc<dyn InferenceEngine + Send + Sync> {
    use hashednets::nn::{LayerKind, Network};
    let mut net = Network::from_dims(
        &[N_IN, 6, N_OUT],
        vec![LayerKind::Hashed { k: 16 }, LayerKind::Dense],
        hashednets::hash::DEFAULT_SEED_BASE,
    );
    net.init(&mut Pcg32::new(seed, 5));
    Arc::new(hashednets::serve::NativeEngine::from_network(net, 4))
}

fn base_options() -> ServeOptions {
    ServeOptions {
        artifacts_dir: std::env::temp_dir().join("hn_serve_wire_no_artifacts"),
        models: Vec::new(),
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 2,
        ..Default::default()
    }
}

fn bind_with(
    opts: ServeOptions,
    engines: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)>,
) -> (std::thread::JoinHandle<anyhow::Result<()>>, String) {
    let srv = Server::bind_with_engines(opts, engines).expect("bind");
    let addr = srv.local_addr().to_string();
    (std::thread::spawn(move || srv.run()), addr)
}

fn input_row(req: usize) -> Vec<f32> {
    (0..N_IN).map(|j| ((req * 13 + j * 5) % 19) as f32 * 0.13 - 1.1).collect()
}

/// An engine that blocks in `predict` until its gate opens — pins the
/// worker so overload and deadline paths trigger deterministically.
struct GatedEngine {
    gate: Arc<AtomicBool>,
}

impl InferenceEngine for GatedEngine {
    fn predict(&self, x: &Matrix) -> anyhow::Result<Matrix> {
        let t0 = Instant::now();
        while !self.gate.load(Ordering::Relaxed) {
            if t0.elapsed() > Duration::from_secs(10) {
                anyhow::bail!("gate never opened");
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(Matrix::zeros(x.rows, N_OUT))
    }
    fn n_in(&self) -> usize {
        N_IN
    }
    fn n_out(&self) -> usize {
        N_OUT
    }
    fn max_batch(&self) -> usize {
        1
    }
    fn name(&self) -> &'static str {
        "gated"
    }
}

fn queue_depth(admin: &mut Client, model: &str) -> f64 {
    admin
        .health()
        .expect("health")
        .get("models")
        .and_then(|ms| ms.get(model))
        .map(|h| h.req_f64("queue_depth").unwrap())
        .unwrap_or(0.0)
}

// ---- round trips ----

/// The two protocols must agree end to end: same class, same probs
/// (modulo the JSON f64 text round trip), against the same live model.
#[test]
fn binary_and_json_replies_agree_through_the_real_server() {
    let (server, addr) = bind_with(base_options(), vec![("m".into(), tiny_native(7))]);
    let mut json = Client::connect(&addr).expect("json connect");
    let mut bin = FrameClient::connect(&addr).expect("bin connect");
    for req in 0..20 {
        let pixels = input_row(req);
        let (jclass, jprobs, _) = json.classify(&pixels).expect("json classify");
        match bin.classify(&pixels).expect("bin classify") {
            FrameReply::Ok { class, probs, latency_us, .. } => {
                assert_eq!(class as usize, jclass, "class parity at req {req}");
                assert_eq!(probs.len(), jprobs.len());
                for (b, j) in probs.iter().zip(&jprobs) {
                    assert!((b - j).abs() < 1e-5, "probs parity: {b} vs {j}");
                }
                let _ = latency_us; // measured server-side; may round to 0 µs
            }
            other => panic!("expected Ok frame, got {other:?}"),
        }
    }
    json.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// Protocol detection is per message, not per connection: one socket
/// can interleave JSON lines and binary frames and each request gets
/// its reply in its own protocol, in order.
#[test]
fn one_connection_interleaves_json_and_binary_messages() {
    let (server, addr) = bind_with(base_options(), vec![("m".into(), tiny_native(9))]);
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.set_nodelay(true).ok();

    // JSON request first
    let pixels = input_row(1);
    let arr: Vec<String> = pixels.iter().map(|p| format!("{p}")).collect();
    let line = format!("{{\"pixels\": [{}]}}\n", arr.join(", "));
    stream.write_all(line.as_bytes()).expect("write json");
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let nl = loop {
        if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            break pos;
        }
        let n = stream.read(&mut chunk).expect("read");
        assert!(n > 0, "server closed early");
        buf.extend_from_slice(&chunk[..n]);
    };
    let reply = Json::parse(std::str::from_utf8(&buf[..nl]).unwrap()).expect("json reply");
    let jclass = reply.req_f64("class").expect("class") as u32;
    buf.drain(..=nl);

    // then a binary frame on the same socket
    let mut req = Vec::new();
    frame::encode_request(&mut req, 42, "", 0, &pixels);
    stream.write_all(&req).expect("write frame");
    let frame_reply = loop {
        match frame::decode_reply(&buf).expect("decode") {
            Some((reply, used)) => {
                buf.drain(..used);
                break reply;
            }
            None => {
                let n = stream.read(&mut chunk).expect("read");
                assert!(n > 0, "server closed early");
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    };
    match frame_reply {
        FrameReply::Ok { req_id, class, .. } => {
            assert_eq!(req_id, 42);
            assert_eq!(class, jclass, "same input, same class, both protocols");
        }
        other => panic!("expected Ok frame, got {other:?}"),
    }

    let mut admin = Client::connect(&addr).expect("admin");
    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

// ---- error-code parity ----

/// `bad_input` and `unknown_model` must carry the same code over both
/// protocols (numeric codes map through `frame::num_to_code`).
#[test]
fn validation_error_codes_match_across_protocols() {
    let (server, addr) = bind_with(base_options(), vec![("m".into(), tiny_native(3))]);
    let mut json = Client::connect(&addr).expect("json");
    let mut bin = FrameClient::connect(&addr).expect("bin");

    // wrong pixel count
    let v = json.classify_raw(None, &[1.0, 2.0], None).expect("raw");
    assert_eq!(v.get("code").and_then(Json::as_str), Some("bad_input"));
    match bin.classify(&[1.0, 2.0]).expect("bin") {
        FrameReply::Err { code, message, .. } => {
            assert_eq!(frame::num_to_code(code), "bad_input");
            assert!(message.contains("expects"), "diagnostic message: {message}");
        }
        other => panic!("expected Err frame, got {other:?}"),
    }

    // unknown model
    let v = json.classify_raw(Some("nope"), &input_row(0), None).expect("raw");
    assert_eq!(v.get("code").and_then(Json::as_str), Some("unknown_model"));
    match bin.classify_model("nope", &input_row(0), 0).expect("bin") {
        FrameReply::Err { code, message, .. } => {
            assert_eq!(frame::num_to_code(code), "unknown_model");
            assert!(message.contains("nope"));
        }
        other => panic!("expected Err frame, got {other:?}"),
    }

    // error counters accrued identically (one bad_input per protocol;
    // unknown_model is uncounted on both paths)
    let stats = json.stats().expect("stats");
    let errs = stats
        .get("models")
        .and_then(|m| m.get("m"))
        .and_then(|m| m.get("errors"))
        .and_then(Json::as_f64)
        .unwrap();
    assert_eq!(errs, 2.0, "one counted bad_input per protocol");

    json.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// `{"cmd":"stats"}` breaks classify counts down per wire protocol and
/// per model; validation failures still count toward the protocol they
/// arrived on.
#[test]
fn stats_counts_requests_per_protocol() {
    let (server, addr) = bind_with(base_options(), vec![("m".into(), tiny_native(11))]);
    let mut json = Client::connect(&addr).expect("json");
    let mut bin = FrameClient::connect(&addr).expect("bin");
    for req in 0..3 {
        json.classify(&input_row(req)).expect("json classify");
    }
    for req in 0..2 {
        match bin.classify(&input_row(req)).expect("bin classify") {
            FrameReply::Ok { .. } => {}
            other => panic!("expected Ok frame, got {other:?}"),
        }
    }
    // a validation failure (wrong pixel count) still counts as a JSON
    // request against the model it resolved to
    let v = json.classify_raw(None, &[1.0], None).expect("raw");
    assert_eq!(v.get("code").and_then(Json::as_str), Some("bad_input"));

    let stats = json.stats().expect("stats");
    let m = stats.get("models").and_then(|ms| ms.get("m")).expect("per-model stats");
    assert_eq!(m.get("json_requests").and_then(Json::as_f64), Some(4.0));
    assert_eq!(m.get("binary_requests").and_then(Json::as_f64), Some(2.0));

    json.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// Overload rejection (with a retry hint) and deadline expiry must
/// surface identically over both protocols. The two queue slots are
/// filled by the deadline-parity requests themselves: while they wait
/// behind the pinned worker the queue is full (→ overload checks),
/// and once the gate opens their lapsed deadlines expire (→ deadline
/// checks).
#[test]
fn overload_and_deadline_codes_match_across_protocols() {
    let gate = Arc::new(AtomicBool::new(false));
    let engine = Arc::new(GatedEngine { gate: gate.clone() });
    let opts = ServeOptions { workers: 1, max_pending: 2, ..base_options() };
    let (server, addr) = bind_with(opts, vec![("gated".into(), engine)]);
    let mut admin = Client::connect(&addr).expect("admin");
    let pixels = input_row(0);

    // Pin the single worker with a request on a throwaway connection.
    let mut pin_conn = TcpStream::connect(&addr).expect("pin conn");
    let arr: Vec<String> = pixels.iter().map(|p| format!("{p}")).collect();
    let line = format!("{{\"pixels\": [{}]}}\n", arr.join(", "));
    pin_conn.write_all(line.as_bytes()).unwrap();
    let t0 = Instant::now();
    while queue_depth(&mut admin, "gated") > 0.0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never picked up");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Fill both queue slots with the deadline-parity requests (40 ms
    // budgets that will lapse while the worker stays pinned).
    let jh = {
        let addr = addr.clone();
        let pixels = pixels.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).expect("json deadline conn");
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            c.classify_raw(None, &pixels, Some(40)).expect("raw")
        })
    };
    let bh = {
        let addr = addr.clone();
        let pixels = pixels.clone();
        std::thread::spawn(move || {
            let mut c = FrameClient::connect(&addr).expect("bin deadline conn");
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            c.classify_model("", &pixels, 40).expect("bin")
        })
    };
    let t0 = Instant::now();
    while queue_depth(&mut admin, "gated") < 2.0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "queue never filled");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Queue full → both protocols get an immediate overload rejection.
    let mut json = Client::connect(&addr).expect("json");
    json.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let v = json.classify_raw(None, &pixels, None).expect("raw");
    assert_eq!(v.get("code").and_then(Json::as_str), Some("overloaded"));
    assert!(v.get("retry_after_ms").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0);

    let mut bin = FrameClient::connect(&addr).expect("bin");
    bin.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match bin.classify(&pixels).expect("bin") {
        FrameReply::Err { code, .. } => {
            assert_eq!(frame::num_to_code(code), "overloaded");
        }
        other => panic!("expected overloaded frame, got {other:?}"),
    }

    // Let the queued requests' deadlines lapse, then release the
    // worker: its next batch-formation pass expires both with the
    // typed deadline code.
    std::thread::sleep(Duration::from_millis(150));
    gate.store(true, Ordering::Relaxed);

    let v = jh.join().expect("json deadline thread");
    assert_eq!(
        v.get("code").and_then(Json::as_str),
        Some("deadline"),
        "json deadline reply: {v:?}"
    );
    match bh.join().expect("bin deadline thread") {
        FrameReply::Err { code, .. } => assert_eq!(frame::num_to_code(code), "deadline"),
        other => panic!("expected deadline frame, got {other:?}"),
    }
    drop(pin_conn); // the pin reply, if unread, dies with the socket

    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

// ---- protocol violations ----

/// A malformed frame cannot be resynced: the server answers with one
/// typed `bad_frame` error frame and closes the connection.
#[test]
fn malformed_frame_gets_bad_frame_reply_then_close() {
    let (server, addr) = bind_with(base_options(), vec![("m".into(), tiny_native(5))]);
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    // valid magic, unsupported opcode
    stream.write_all(&[frame::MAGIC, 0x7f, 0, 0]).expect("write");
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    let reply = loop {
        match frame::decode_reply(&buf).expect("decode") {
            Some((r, used)) => {
                buf.drain(..used);
                break r;
            }
            None => {
                let n = stream.read(&mut chunk).expect("read");
                assert!(n > 0, "closed before replying");
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    };
    match reply {
        FrameReply::Err { code, .. } => assert_eq!(frame::num_to_code(code), "bad_frame"),
        other => panic!("expected bad_frame, got {other:?}"),
    }
    // ... and then EOF
    let n = stream.read(&mut chunk).expect("read after error");
    assert_eq!(n, 0, "connection stays open after an unresyncable frame");

    let mut admin = Client::connect(&addr).expect("admin");
    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

/// Pipelined frames (many requests written before any reply is read)
/// come back in request order with matching ids.
#[test]
fn pipelined_binary_requests_are_answered_in_order() {
    let (server, addr) = bind_with(base_options(), vec![("m".into(), tiny_native(13))]);
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.set_nodelay(true).ok();
    let mut out = Vec::new();
    for id in 0..32u32 {
        frame::encode_request(&mut out, id, "", 0, &input_row(id as usize));
    }
    stream.write_all(&out).expect("write burst");
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut next_id = 0u32;
    while next_id < 32 {
        match frame::decode_reply(&buf).expect("decode") {
            Some((FrameReply::Ok { req_id, .. }, used)) => {
                assert_eq!(req_id, next_id, "FIFO reply order");
                next_id += 1;
                buf.drain(..used);
            }
            Some((other, _)) => panic!("unexpected error frame: {other:?}"),
            None => {
                let n = stream.read(&mut chunk).expect("read");
                assert!(n > 0, "server closed mid-burst");
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    }
    let mut admin = Client::connect(&addr).expect("admin");
    admin.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

// ---- the structural allocation claim ----

/// Decoding a binary classify request must allocate strictly less —
/// by an order of magnitude — than parsing the equivalent JSON text.
/// This is the structural half of the "binary does less work per
/// request" acceptance criterion; `benches/serve_scale.rs` measures
/// the wall-clock half.
#[test]
fn binary_decode_allocates_order_of_magnitude_less_than_json_parse() {
    let pixels: Vec<f32> = (0..784).map(|i| (i % 255) as f32 / 255.0).collect();

    // binary: one frame decode
    let mut buf = Vec::new();
    frame::encode_request(&mut buf, 1, "mnist", 0, &pixels);
    let before = allocs();
    let decoded = frame::decode_request(&buf).unwrap().expect("complete");
    let bin_allocs = allocs() - before;
    let frame::FramePayload::Dense(decoded_pixels) = &decoded.0.payload else {
        panic!("expected a dense payload");
    };
    assert_eq!(decoded_pixels.len(), 784);

    // JSON: parse + the pixel extraction the server does per request
    let arr: Vec<String> = pixels.iter().map(|p| format!("{p}")).collect();
    let line = format!("{{\"model\": \"mnist\", \"pixels\": [{}]}}", arr.join(", "));
    let before = allocs();
    let parsed = Json::parse(&line).expect("parse");
    let extracted: Vec<f32> = parsed
        .get("pixels")
        .and_then(Json::as_arr)
        .expect("pixels")
        .iter()
        .filter_map(|v| v.as_f64())
        .map(|v| v as f32)
        .collect();
    let json_allocs = allocs() - before;
    assert_eq!(extracted.len(), 784);

    assert!(
        bin_allocs * 10 <= json_allocs,
        "binary decode should allocate ≥10x less: binary={bin_allocs} json={json_allocs}"
    );
    // and the reply path: raw f32 frame vs JSON float formatting
    let probs: Vec<f32> = (0..10).map(|i| i as f32 / 10.0).collect();
    let mut reply_buf = Vec::with_capacity(256);
    let before = allocs();
    frame::encode_reply_ok(&mut reply_buf, 1, 3, 250, &probs);
    let bin_reply_allocs = allocs() - before;
    assert!(
        bin_reply_allocs <= 1,
        "encoding into a pre-sized buffer should not allocate (got {bin_reply_allocs})"
    );
}
