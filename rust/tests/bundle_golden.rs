//! Golden-fixture compatibility: the committed HNMB **v1** bundle (and
//! its legacy `HNCK` twin) under `tests/data/` were written by an
//! independent Python byte-layout implementation
//! (`python/tools/make_golden_bundle.py`), never by the Rust writer —
//! so these tests pin the *format*, not the serializer. A v2-era
//! reader must keep loading them bit-equal-predicting forever.
//!
//! Fixture model: hashnet dims [6,5,4], budgets [10,8], tensor `t`
//! element `i` = `((t*31 + i*7) % 13) * 0.125 - 0.75` (eighths — exact
//! in f32, so "bit-equal" is well-defined across platforms).

use hashednets::model::{BundleMap, Method, ModelBundle, ModelSpec, BUNDLE_VERSION};
use hashednets::nn::Network;
use hashednets::runtime::{ArtifactSpec, ModelState, ParamInfo};
use hashednets::tensor::Matrix;
use std::path::PathBuf;
use std::sync::Arc;

const GOLDEN_V1: &[u8] = include_bytes!("data/golden_v1.hnb");
const GOLDEN_CKPT: &[u8] = include_bytes!("data/golden_v1.ckpt");

fn golden_spec() -> ModelSpec {
    ModelSpec::new("golden_v1", Method::Hashnet, vec![6, 5, 4], vec![10, 8], 0x9E37_79B9, 4)
        .expect("golden spec")
}

/// The fixture's parameter formula, reproduced independently of any
/// file parsing.
fn golden_params() -> Vec<Vec<f32>> {
    [10usize, 8]
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            (0..n).map(|i| ((t * 31 + i * 7) % 13) as f32 * 0.125 - 0.75).collect()
        })
        .collect()
}

/// The hand-built reference network every load path must match.
fn golden_net() -> Network {
    let spec = golden_spec();
    let mut net = Network::from_spec(&spec).expect("skeleton");
    for (layer, p) in net.layers.iter_mut().zip(golden_params()) {
        layer.params[..].copy_from_slice(&p);
    }
    net
}

fn eval_grid() -> Matrix {
    Matrix::from_fn(7, 6, |i, j| ((i * 5 + j * 3) % 11) as f32 * 0.2 - 1.0)
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hn_golden_{tag}_{}.bin", std::process::id()))
}

#[test]
fn golden_v1_loads_and_predicts_bit_equal() {
    let bundle = ModelBundle::from_bytes(GOLDEN_V1).expect("golden v1 must stay loadable");
    assert_eq!(bundle.version, 1, "fixture is format v1");
    assert_eq!(bundle.spec, golden_spec(), "spec JSON round-trip");
    assert_eq!(bundle.params, golden_params(), "tensor values bit-exact");

    let x = eval_grid();
    let want = golden_net().predict(&x);
    let got = Network::from_bundle(&bundle).expect("from_bundle").predict(&x);
    assert_eq!(got.data, want.data, "v1 golden predictions must be bit-identical");
}

#[test]
fn golden_v1_through_the_mmap_path_is_bit_equal_too() {
    let path = tmp("map");
    std::fs::write(&path, GOLDEN_V1).unwrap();
    let map = Arc::new(BundleMap::open(&path).expect("BundleMap must accept v1"));
    assert_eq!(map.version(), 1);
    let net = Network::from_bundle_map(&map).expect("from_bundle_map");
    let x = eval_grid();
    assert_eq!(net.predict(&x).data, golden_net().predict(&x).data);
    std::fs::remove_file(&path).ok();
}

#[test]
fn golden_v1_resaves_as_v2_without_changing_the_model() {
    // migration path: load the v1 fixture, save with the v2 writer,
    // load again — same spec, same tensors, version bumped
    let v1 = ModelBundle::from_bytes(GOLDEN_V1).unwrap();
    let v2 = ModelBundle::from_bytes(&v1.to_bytes()).expect("re-read own v2 bytes");
    assert_eq!(v2.version, BUNDLE_VERSION);
    assert_eq!(v2.spec, v1.spec);
    assert_eq!(v2.params, v1.params);
    // and the legacy writer reproduces a v1-readable file with the
    // same tensors (spec JSON may re-serialize, bytes need not match)
    let v1_again = ModelBundle::from_bytes(&v1.to_bytes_v1().expect("v1 writer")).unwrap();
    assert_eq!(v1_again.version, 1);
    assert_eq!(v1_again.params, v1.params);
}

#[test]
fn load_any_accepts_both_golden_formats() {
    let hnb = tmp("any_hnb");
    let ckpt = tmp("any_ckpt");
    std::fs::write(&hnb, GOLDEN_V1).unwrap();
    std::fs::write(&ckpt, GOLDEN_CKPT).unwrap();
    let from_bundle = ModelState::load_any(&hnb).expect("load_any .hnb");
    let from_ckpt = ModelState::load_any(&ckpt).expect("load_any HNCK");
    assert_eq!(from_bundle.params, golden_params());
    assert_eq!(from_ckpt.params, golden_params(), "legacy HNCK checkpoints must keep working");
    std::fs::remove_file(&hnb).ok();
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn resolve_bundle_accepts_the_legacy_checkpoint() {
    // the artifact path (serve --config + --checkpoint) resolves params
    // through ArtifactSpec::resolve_bundle — HNCK must still flow
    let art = ArtifactSpec {
        name: "golden_v1".into(),
        method: Method::Hashnet,
        dims: vec![6, 5, 4],
        budgets: vec![10, 8],
        batch: 4,
        seed_base: 0x9E37_79B9,
        uses_soft_targets: false,
        params: vec![
            ParamInfo { name: "w0".into(), shape: vec![10], init_std: 0.5 },
            ParamInfo { name: "w1".into(), shape: vec![8], init_std: 0.5 },
        ],
        stored_params: 18,
        virtual_params: 59, // 5*(6+1) + 4*(5+1)
        graphs: ("fwd".into(), "bwd".into()),
        compression: 18.0 / 59.0,
        expansion: None,
        hidden_equivalent: None,
    };
    let ckpt = tmp("resolve");
    std::fs::write(&ckpt, GOLDEN_CKPT).unwrap();
    let bundle = art.resolve_bundle(Some(ckpt.as_path()), 0x5EED).expect("resolve_bundle");
    assert_eq!(bundle.params, golden_params());
    let x = eval_grid();
    let got = Network::from_bundle(&bundle).unwrap().predict(&x);
    assert_eq!(got.data, golden_net().predict(&x).data);
    std::fs::remove_file(&ckpt).ok();
}
