//! Property-style coverage of the hashed-layer kernel variants: every
//! kernel (legacy gather, scratch-row, bucket-major, the inverse-plan
//! kernel, and the dispatch heuristic in `forward`) must match the
//! materialized virtual-matrix reference over a sweep of shapes,
//! including the degenerate corners `k = 1`, `k ≥ n·(m+1)` and batch 1;
//! the inverse plan itself must be an exact permutation of the forward
//! plan; plus a finite-difference check on the batch-amortized hashed
//! backward. These tests need no artifacts — they run on a fresh
//! checkout.

use hashednets::hash::{bucket_sign, layer_seeds, HashPlan, DEFAULT_SEED_BASE};
use hashednets::nn::{Layer, LayerKind, TrainOptions};
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;

fn hashed_layer(m: usize, n: usize, k: usize, seed: u64) -> Layer {
    let mut layer = Layer::new(m, n, LayerKind::Hashed { k }, 0, DEFAULT_SEED_BASE);
    let mut rng = Pcg32::new(seed, seed ^ 0xA5A5);
    layer.init(&mut rng);
    layer
}

fn reference_forward(layer: &Layer, a: &Matrix) -> Matrix {
    a.augment_ones().matmul_nt(&layer.virtual_matrix())
}

fn assert_close(name: &str, shape: (usize, usize, usize, usize), got: &Matrix, want: &Matrix) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{name} {shape:?}: shape");
    for (idx, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (g - w).abs() < 1e-5 * (1.0 + w.abs()),
            "{name} (m,n,k,b)={shape:?} cell {idx}: {g} vs {w}"
        );
    }
}

#[test]
fn every_kernel_matches_reference_across_shapes() {
    // (m, n, k, batch) — corners: k=1 (all cells share one weight),
    // k = n·(m+1) and k > n·(m+1) (near-injective plan), batch 1
    // (serving), batch 50 (the paper's minibatch).
    let shapes: &[(usize, usize, usize, usize)] = &[
        (1, 1, 1, 1),
        (5, 3, 1, 4),
        (7, 5, 11, 1),
        (10, 6, 13, 4),
        (6, 4, 40, 3),    // k > n·(m+1) = 28
        (17, 9, 162, 2),  // k = n·(m+1) exactly
        (12, 8, 6, 50),
        (3, 16, 25, 2),
    ];
    for &(m, n, k, batch) in shapes {
        let layer = hashed_layer(m, n, k, (m * 131 + n * 17 + k) as u64);
        let mut rng = Pcg32::new(batch as u64 + 1, k as u64);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let want = reference_forward(&layer, &a);
        let shape = (m, n, k, batch);
        assert_close("gather", shape, &layer.forward_hashed_gather(&a), &want);
        assert_close("scratch", shape, &layer.forward_hashed_scratch(&a), &want);
        assert_close("bucket", shape, &layer.forward_hashed_bucket(&a), &want);
        assert_close("inverse", shape, &layer.forward_hashed_inverse(&a), &want);
        assert_close("dispatch", shape, &layer.forward(&a), &want);
    }
}

/// The inverse plan is an exact permutation of the forward plan: every
/// virtual cell `(i, j)` appears in `cells` exactly once, under the
/// bucket the forward plan assigns it, carrying the same ξ sign as
/// `bucket_sign` — and the bucket ranges tile `cells` exactly.
#[test]
fn inverse_plan_is_an_exact_permutation_with_agreeing_signs() {
    for (n, m1, k, layer_index) in
        [(40usize, 31usize, 64usize, 0u32), (7, 5, 1, 1), (16, 9, 500, 2), (1, 1, 3, 3)]
    {
        let plan = HashPlan::build(n, m1, k, layer_index, DEFAULT_SEED_BASE);
        let inv = plan.inverse();
        let (s_h, s_xi) = layer_seeds(layer_index, DEFAULT_SEED_BASE);
        assert_eq!(inv.n_buckets(), k);
        assert_eq!(inv.cells.len(), n * m1);
        assert_eq!(inv.bucket_offsets.len(), k + 1);
        let mut seen = vec![false; n * m1];
        for b in 0..k {
            for &cell in inv.cells_of(b) {
                let idx = (cell & HashPlan::BUCKET_MASK) as usize;
                assert!(idx < n * m1, "cell index {idx} out of range");
                assert!(!seen[idx], "cell {idx} appears twice");
                seen[idx] = true;
                let (i, j) = (idx / m1, idx % m1);
                // bucket and sign agree with the ground-truth hash pair
                let (want_b, want_sign) =
                    bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
                assert_eq!(b, want_b as usize, "bucket at ({i},{j})");
                let applied = HashPlan::apply_sign(cell, 2.0);
                assert_eq!(applied, 2.0 * want_sign, "sign at ({i},{j})");
            }
        }
        assert!(seen.iter().all(|&s| s), "every forward cell appears exactly once");
    }
}

/// Decompressing through the inverse view reproduces Eq. 7: scattering
/// `ξ·w_k` over bucket `k`'s cells rebuilds the same virtual matrix as
/// the forward plan's row decompression.
#[test]
fn inverse_plan_rebuilds_the_virtual_matrix() {
    let layer = hashed_layer(10, 8, 13, 5);
    let v = layer.virtual_matrix(); // forward-plan decompression
    let plan = layer.plan().expect("hashed layer has a plan");
    let inv = plan.inverse();
    let mut rebuilt = Matrix::zeros(v.rows, v.cols);
    for (k, &w) in layer.params.iter().enumerate() {
        for &cell in inv.cells_of(k) {
            let idx = (cell & HashPlan::BUCKET_MASK) as usize;
            rebuilt.data[idx] = HashPlan::apply_sign(cell, w);
        }
    }
    assert_eq!(rebuilt.data, v.data, "bit-identical virtual matrices");
}

#[test]
fn scratch_kernel_parallel_path_matches_reference() {
    // large enough that forward_hashed_scratch crosses its
    // multi-threading threshold (n·(m+1)·(B+1) > 2^21)
    let (m, n, k, batch) = (300usize, 128usize, 4800usize, 64usize);
    let layer = hashed_layer(m, n, k, 99);
    let mut rng = Pcg32::new(4, 4);
    let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
    let want = reference_forward(&layer, &a);
    assert_close("scratch-par", (m, n, k, batch), &layer.forward_hashed_scratch(&a), &want);
}

#[test]
fn hashed_backward_matches_finite_difference() {
    for &(m, n, k, batch) in &[(9usize, 7usize, 12usize, 3usize), (6, 5, 4, 1), (5, 3, 1, 2)] {
        let mut layer = hashed_layer(m, n, k, (k * 7 + batch) as u64);
        let mut rng = Pcg32::new(batch as u64, 2);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let co = Matrix::from_fn(batch, n, |_, _| rng.normal()); // cotangent
        let loss = |l: &Layer| -> f32 {
            let z = l.forward(&a);
            z.data.iter().zip(&co.data).map(|(z, c)| z * c).sum()
        };
        let mut grad = vec![0.0f32; layer.params.len()];
        let da = layer.backward(&a, &co, &mut grad, &TrainOptions::default());
        let eps = 1e-2f32;
        for p in 0..layer.params.len() {
            let orig = layer.params[p];
            layer.params[p] = orig + eps;
            let lp = loss(&layer);
            layer.params[p] = orig - eps;
            let lm = loss(&layer);
            layer.params[p] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[p]).abs() < 2e-2 * (1.0 + fd.abs()),
                "(m,n,k,b)=({m},{n},{k},{batch}) param {p}: fd {fd} vs ad {}",
                grad[p]
            );
        }
        // spot-check the input gradient against the reference chain rule
        let v = layer.virtual_matrix();
        let da_ref = co.matmul(&v).drop_last_col();
        for (x, y) in da.data.iter().zip(&da_ref.data) {
            assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "da {x} vs {y}");
        }
    }
}

#[test]
fn backward_skips_zero_delta_columns_correctly() {
    // delta with entire zero columns exercises the early-skip path
    let layer = hashed_layer(8, 6, 10, 77);
    let mut rng = Pcg32::new(6, 6);
    let a = Matrix::from_fn(4, 8, |_, _| rng.normal());
    let mut delta = Matrix::zeros(4, 6);
    for b in 0..4 {
        delta.row_mut(b)[1] = rng.normal();
        delta.row_mut(b)[4] = rng.normal();
    }
    let mut grad = vec![0.0f32; layer.params.len()];
    let da = layer.backward(&a, &delta, &mut grad, &TrainOptions::default());
    let v = layer.virtual_matrix();
    let da_ref = delta.matmul(&v).drop_last_col();
    for (x, y) in da.data.iter().zip(&da_ref.data) {
        assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
    }
    assert!(grad.iter().any(|&g| g != 0.0), "gradient should be nonzero");
}
