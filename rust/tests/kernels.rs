//! Property-style coverage of the hashed-layer kernel variants: every
//! kernel (legacy gather, scratch-row, bucket-major, the inverse-plan
//! kernel, and the dispatch heuristic in `forward`) must match the
//! materialized virtual-matrix reference over a sweep of shapes,
//! including the degenerate corners `k = 1`, `k ≥ n·(m+1)` and batch 1;
//! the inverse plan itself must be an exact permutation of the forward
//! plan; plus a finite-difference check on the batch-amortized hashed
//! backward. The tiled (`hashed_tile`) kernels are held to a stronger
//! bar: forward and backward must **bit-agree** with a per-cell
//! materialization of the virtual matrix driven through the
//! lane-structured scalar SIMD twins, across tile shapes × odd virtual
//! dims — which simultaneously proves the avx2 and scalar dispatch
//! paths identical. These tests need no artifacts — they run on a
//! fresh checkout.

use hashednets::hash::{bucket_sign, layer_seeds, HashPlan, TilePlan, DEFAULT_SEED_BASE};
use hashednets::nn::{Layer, LayerKind, TrainOptions};
use hashednets::tensor::{simd, Matrix};
use hashednets::util::rng::Pcg32;

fn hashed_layer(m: usize, n: usize, k: usize, seed: u64) -> Layer {
    let mut layer = Layer::new(m, n, LayerKind::Hashed { k }, 0, DEFAULT_SEED_BASE);
    let mut rng = Pcg32::new(seed, seed ^ 0xA5A5);
    layer.init(&mut rng);
    layer
}

fn reference_forward(layer: &Layer, a: &Matrix) -> Matrix {
    a.augment_ones().matmul_nt(&layer.virtual_matrix())
}

fn assert_close(name: &str, shape: (usize, usize, usize, usize), got: &Matrix, want: &Matrix) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{name} {shape:?}: shape");
    for (idx, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (g - w).abs() < 1e-5 * (1.0 + w.abs()),
            "{name} (m,n,k,b)={shape:?} cell {idx}: {g} vs {w}"
        );
    }
}

#[test]
fn every_kernel_matches_reference_across_shapes() {
    // (m, n, k, batch) — corners: k=1 (all cells share one weight),
    // k = n·(m+1) and k > n·(m+1) (near-injective plan), batch 1
    // (serving), batch 50 (the paper's minibatch).
    let shapes: &[(usize, usize, usize, usize)] = &[
        (1, 1, 1, 1),
        (5, 3, 1, 4),
        (7, 5, 11, 1),
        (10, 6, 13, 4),
        (6, 4, 40, 3),    // k > n·(m+1) = 28
        (17, 9, 162, 2),  // k = n·(m+1) exactly
        (12, 8, 6, 50),
        (3, 16, 25, 2),
    ];
    for &(m, n, k, batch) in shapes {
        let layer = hashed_layer(m, n, k, (m * 131 + n * 17 + k) as u64);
        let mut rng = Pcg32::new(batch as u64 + 1, k as u64);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let want = reference_forward(&layer, &a);
        let shape = (m, n, k, batch);
        assert_close("gather", shape, &layer.forward_hashed_gather(&a), &want);
        assert_close("scratch", shape, &layer.forward_hashed_scratch(&a), &want);
        assert_close("bucket", shape, &layer.forward_hashed_bucket(&a), &want);
        assert_close("inverse", shape, &layer.forward_hashed_inverse(&a), &want);
        assert_close("dispatch", shape, &layer.forward(&a), &want);
    }
}

/// The inverse plan is an exact permutation of the forward plan: every
/// virtual cell `(i, j)` appears in `cells` exactly once, under the
/// bucket the forward plan assigns it, carrying the same ξ sign as
/// `bucket_sign` — and the bucket ranges tile `cells` exactly.
#[test]
fn inverse_plan_is_an_exact_permutation_with_agreeing_signs() {
    for (n, m1, k, layer_index) in
        [(40usize, 31usize, 64usize, 0u32), (7, 5, 1, 1), (16, 9, 500, 2), (1, 1, 3, 3)]
    {
        let plan = HashPlan::build(n, m1, k, layer_index, DEFAULT_SEED_BASE);
        let inv = plan.inverse();
        let (s_h, s_xi) = layer_seeds(layer_index, DEFAULT_SEED_BASE);
        assert_eq!(inv.n_buckets(), k);
        assert_eq!(inv.cells.len(), n * m1);
        assert_eq!(inv.bucket_offsets.len(), k + 1);
        let mut seen = vec![false; n * m1];
        for b in 0..k {
            for &cell in inv.cells_of(b) {
                let idx = (cell & HashPlan::BUCKET_MASK) as usize;
                assert!(idx < n * m1, "cell index {idx} out of range");
                assert!(!seen[idx], "cell {idx} appears twice");
                seen[idx] = true;
                let (i, j) = (idx / m1, idx % m1);
                // bucket and sign agree with the ground-truth hash pair
                let (want_b, want_sign) =
                    bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
                assert_eq!(b, want_b as usize, "bucket at ({i},{j})");
                let applied = HashPlan::apply_sign(cell, 2.0);
                assert_eq!(applied, 2.0 * want_sign, "sign at ({i},{j})");
            }
        }
        assert!(seen.iter().all(|&s| s), "every forward cell appears exactly once");
    }
}

/// Decompressing through the inverse view reproduces Eq. 7: scattering
/// `ξ·w_k` over bucket `k`'s cells rebuilds the same virtual matrix as
/// the forward plan's row decompression.
#[test]
fn inverse_plan_rebuilds_the_virtual_matrix() {
    let layer = hashed_layer(10, 8, 13, 5);
    let v = layer.virtual_matrix(); // forward-plan decompression
    let plan = layer.plan().expect("hashed layer has a plan");
    let inv = plan.inverse();
    let mut rebuilt = Matrix::zeros(v.rows, v.cols);
    for (k, &w) in layer.params.iter().enumerate() {
        for &cell in inv.cells_of(k) {
            let idx = (cell & HashPlan::BUCKET_MASK) as usize;
            rebuilt.data[idx] = HashPlan::apply_sign(cell, w);
        }
    }
    assert_eq!(rebuilt.data, v.data, "bit-identical virtual matrices");
}

#[test]
fn scratch_kernel_parallel_path_matches_reference() {
    // large enough that forward_hashed_scratch crosses its
    // multi-threading threshold (n·(m+1)·(B+1) > 2^21)
    let (m, n, k, batch) = (300usize, 128usize, 4800usize, 64usize);
    let layer = hashed_layer(m, n, k, 99);
    let mut rng = Pcg32::new(4, 4);
    let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
    let want = reference_forward(&layer, &a);
    assert_close("scratch-par", (m, n, k, batch), &layer.forward_hashed_scratch(&a), &want);
}

#[test]
fn hashed_backward_matches_finite_difference() {
    for &(m, n, k, batch) in &[(9usize, 7usize, 12usize, 3usize), (6, 5, 4, 1), (5, 3, 1, 2)] {
        let mut layer = hashed_layer(m, n, k, (k * 7 + batch) as u64);
        let mut rng = Pcg32::new(batch as u64, 2);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let co = Matrix::from_fn(batch, n, |_, _| rng.normal()); // cotangent
        let loss = |l: &Layer| -> f32 {
            let z = l.forward(&a);
            z.data.iter().zip(&co.data).map(|(z, c)| z * c).sum()
        };
        let mut grad = vec![0.0f32; layer.params.len()];
        let da = layer.backward(&a, &co, &mut grad, &TrainOptions::default());
        let eps = 1e-2f32;
        for p in 0..layer.params.len() {
            let orig = layer.params[p];
            layer.params[p] = orig + eps;
            let lp = loss(&layer);
            layer.params[p] = orig - eps;
            let lm = loss(&layer);
            layer.params[p] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[p]).abs() < 2e-2 * (1.0 + fd.abs()),
                "(m,n,k,b)=({m},{n},{k},{batch}) param {p}: fd {fd} vs ad {}",
                grad[p]
            );
        }
        // spot-check the input gradient against the reference chain rule
        let v = layer.virtual_matrix();
        let da_ref = co.matmul(&v).drop_last_col();
        for (x, y) in da.data.iter().zip(&da_ref.data) {
            assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "da {x} vs {y}");
        }
    }
}

fn tiled_layer(m: usize, n: usize, k: usize, tile: (usize, usize), seed: u64) -> Layer {
    let mut layer = Layer::new(m, n, LayerKind::HashedTile { k, tile }, 0, DEFAULT_SEED_BASE);
    let mut rng = Pcg32::new(seed, seed ^ 0x715E);
    layer.init(&mut rng);
    layer
}

/// Materialize one tile-padded virtual row straight from the documented
/// cell mapping `V[i][j] = ξ(tr,tc) · w[base + (i mod th)·tw + (j mod tw)]`
/// — per cell, independent of `TilePlan::decompress_padded_row_into`'s
/// run-copy implementation.
fn materialized_padded_row(plan: &TilePlan, params: &[f32], i: usize) -> Vec<f32> {
    let (th, tw) = plan.tile;
    let mut v = vec![0.0f32; plan.padded_width()];
    let tr = i / th;
    for (j, out) in v.iter_mut().enumerate() {
        let e = plan.tile_entry(tr, j / tw);
        *out = HashPlan::apply_sign(e, params[TilePlan::base(e) + (i % th) * tw + (j % tw)]);
    }
    v
}

/// Tile-padded activations exactly as the tiled kernel builds them:
/// `[a | 1 | 0…]` at the plan's padded width.
fn padded_activations(a: &Matrix, padded_width: usize) -> Vec<Vec<f32>> {
    (0..a.rows)
        .map(|b| {
            let mut row = vec![0.0f32; padded_width];
            row[..a.cols].copy_from_slice(a.row(b));
            row[a.cols] = 1.0;
            row
        })
        .collect()
}

/// Tile shapes × odd virtual dims (partial edge tiles on both axes) ×
/// batch sizes used by every tiled bit-agreement test below.
const TILED_SHAPES: &[((usize, usize), usize, usize, usize, usize)] = &[
    ((1, 8), 7, 5, 11, 1),
    ((1, 8), 13, 9, 40, 3),
    ((8, 8), 13, 9, 70, 4),
    ((8, 8), 9, 17, 64, 2),
    ((2, 4), 11, 7, 23, 5),
];

/// The tiled forward must reproduce, bit for bit, a per-cell
/// materialization of each padded virtual row driven through the
/// lane-structured scalar dot — on avx2 hardware this simultaneously
/// proves the vector dispatch path bit-identical to the scalar twin.
#[test]
fn tiled_forward_bit_agrees_with_per_cell_materialization() {
    for &(tile, m, n, k, batch) in TILED_SHAPES {
        let layer = tiled_layer(m, n, k, tile, (m * 37 + n * 5 + k) as u64);
        let plan = layer.tile_plan().expect("tiled layer has a tile plan");
        let mut rng = Pcg32::new(batch as u64 + 2, k as u64);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let a_pad = padded_activations(&a, plan.padded_width());
        let got = layer.forward_hashed_tiled(&a);
        let via_dispatch = layer.forward(&a);
        for i in 0..n {
            let vrow = materialized_padded_row(plan, &layer.params, i);
            for (b, pad_row) in a_pad.iter().enumerate() {
                let want = simd::dot8_scalar(pad_row, &vrow);
                // dispatched and scalar dots agree exactly...
                assert_eq!(
                    simd::dot8(pad_row, &vrow).to_bits(),
                    want.to_bits(),
                    "dot8 dispatch diverges from scalar at tile {tile:?} row {i}"
                );
                // ...and so does the whole kernel
                assert_eq!(
                    got.at(b, i).to_bits(),
                    want.to_bits(),
                    "tile {tile:?} (m,n,k,b)=({m},{n},{k},{batch}) z[{b}][{i}]"
                );
                assert_eq!(via_dispatch.at(b, i).to_bits(), want.to_bits());
            }
        }
    }
}

/// Single-threaded tiled backward must bit-agree with a per-cell
/// reference: ∂w from the same Eq. 12 pre-reduction `S = δᵀ·[a|1]`
/// scattered in the kernel's fixed row-major tile walk, ∂a from serial
/// scalar-twin axpy rows over per-cell materialized virtual rows.
#[test]
fn tiled_backward_bit_agrees_with_per_cell_reference() {
    for &(tile, m, n, k, batch) in TILED_SHAPES {
        let layer = tiled_layer(m, n, k, tile, (m * 13 + n + k * 3) as u64);
        let plan = layer.tile_plan().expect("tiled layer has a tile plan");
        let mut rng = Pcg32::new(batch as u64 + 9, m as u64);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let delta = Matrix::from_fn(batch, n, |_, _| rng.normal());
        let mut grad = vec![0.0f32; k];
        let da = layer.backward(&a, &delta, &mut grad, &TrainOptions::default());

        let (th, tw) = tile;
        let m1 = m + 1;
        let (tiles_r, tiles_c) = plan.tiles();
        let s = delta.matmul_tn_aug(&a, 1);
        let mut grad_ref = vec![0.0f32; k];
        for tr in 0..tiles_r {
            for tc in 0..tiles_c {
                let e = plan.tile_entry(tr, tc);
                let base = TilePlan::base(e);
                let (j0, j1) = (tc * tw, (tc * tw + tw).min(m1));
                for i in tr * th..(tr * th + th).min(n) {
                    let run = base + (i - tr * th) * tw;
                    for (o, j) in (j0..j1).enumerate() {
                        grad_ref[run + o] += HashPlan::apply_sign(e, s.at(i, j));
                    }
                }
            }
        }
        for (p, (g, r)) in grad.iter().zip(&grad_ref).enumerate() {
            assert_eq!(
                g.to_bits(),
                r.to_bits(),
                "tile {tile:?} (m,n,k,b)=({m},{n},{k},{batch}) grad[{p}]: {g} vs {r}"
            );
        }

        let mut da_ref = Matrix::zeros(batch, m);
        for i in 0..n {
            let vrow = materialized_padded_row(plan, &layer.params, i);
            for b in 0..batch {
                let d = delta.at(b, i);
                if d != 0.0 {
                    simd::axpy8_scalar(da_ref.row_mut(b), &vrow[..m], d);
                }
            }
        }
        for (idx, (g, r)) in da.data.iter().zip(&da_ref.data).enumerate() {
            assert_eq!(
                g.to_bits(),
                r.to_bits(),
                "tile {tile:?} (m,n,k,b)=({m},{n},{k},{batch}) da[{idx}]: {g} vs {r}"
            );
        }
    }
}

#[test]
fn backward_skips_zero_delta_columns_correctly() {
    // delta with entire zero columns exercises the early-skip path
    let layer = hashed_layer(8, 6, 10, 77);
    let mut rng = Pcg32::new(6, 6);
    let a = Matrix::from_fn(4, 8, |_, _| rng.normal());
    let mut delta = Matrix::zeros(4, 6);
    for b in 0..4 {
        delta.row_mut(b)[1] = rng.normal();
        delta.row_mut(b)[4] = rng.normal();
    }
    let mut grad = vec![0.0f32; layer.params.len()];
    let da = layer.backward(&a, &delta, &mut grad, &TrainOptions::default());
    let v = layer.virtual_matrix();
    let da_ref = delta.matmul(&v).drop_last_col();
    for (x, y) in da.data.iter().zip(&da_ref.data) {
        assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
    }
    assert!(grad.iter().any(|&g| g != 0.0), "gradient should be nonzero");
}
