//! Bundle format acceptance: save→load→predict bit-equality for every
//! [`Method`], plus the corruption error paths — truncation, checksum
//! damage, future format versions and parameter-shape mismatches all
//! fail with the right typed [`ModelError`].

use hashednets::model::{Method, ModelBundle, ModelError, ModelSpec, BUNDLE_VERSION};
use hashednets::nn::Network;
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;
use std::path::PathBuf;

fn spec_for(method: Method) -> ModelSpec {
    // budgets sized so every kind is exercised: hashed K, RER kept
    // edges, LRD ranks 3 and 4 (budget/n rounded)
    ModelSpec::new(
        format!("rt_{method}"),
        method,
        vec![9, 7, 4],
        vec![21, 14],
        hashednets::hash::DEFAULT_SEED_BASE,
        5,
    )
    .expect("valid spec")
}

fn trained_net(spec: &ModelSpec, seed: u64) -> Network {
    let mut net = Network::from_spec(spec).expect("from_spec");
    net.init(&mut Pcg32::new(seed, 31));
    net
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hn_bundle_{tag}_{}.hnb", std::process::id()))
}

#[test]
fn save_load_predict_bit_equality_per_method() {
    let x = Matrix::from_fn(6, 9, |i, j| ((i * 13 + j * 7) % 11) as f32 * 0.17 - 0.8);
    for method in Method::ALL {
        let spec = spec_for(method);
        let net = trained_net(&spec, 42);
        let want = net.predict(&x);

        let path = tmp(method.as_str());
        net.to_bundle(&spec).expect("to_bundle").save(&path).expect("save");
        let loaded = ModelBundle::load(&path).expect("load");
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.spec, spec, "{method}: spec round-trip");
        assert_eq!(loaded.version, BUNDLE_VERSION);
        let back = Network::from_bundle(&loaded).expect("from_bundle");
        let got = back.predict(&x);
        // bit-exact: same params, same hash plans, same kernels
        assert_eq!(got.data, want.data, "{method}: predict must be bit-identical");
    }
}

#[test]
fn truncated_file_is_a_clean_error() {
    let spec = spec_for(Method::Hashnet);
    let bytes = trained_net(&spec, 1).to_bundle(&spec).unwrap().to_bytes();
    // cut at several depths: inside the header, the spec, the tensors
    for cut in [2usize, 9, 20, bytes.len() / 2, bytes.len() - 5] {
        let err = ModelBundle::from_bytes(&bytes[..cut]).expect_err("truncated must fail");
        assert!(
            matches!(err, ModelError::Truncated(_) | ModelError::BadChecksum { .. }),
            "cut at {cut}: unexpected {err:?}"
        );
    }
    // cutting the trailing checksum itself
    let err = ModelBundle::from_bytes(&bytes[..bytes.len() - 4]).expect_err("no checksum");
    assert!(
        matches!(err, ModelError::Truncated(_) | ModelError::BadChecksum { .. }),
        "{err:?}"
    );
}

#[test]
fn flipped_payload_byte_is_a_checksum_error() {
    let spec = spec_for(Method::Hashnet);
    let mut bytes = trained_net(&spec, 2).to_bundle(&spec).unwrap().to_bytes();
    // flip one byte inside the f32 payload (well past the header+spec,
    // before the checksum) — structure stays parseable, content lies
    let at = bytes.len() - 12;
    bytes[at] ^= 0xA5;
    let err = ModelBundle::from_bytes(&bytes).expect_err("corrupt payload must fail");
    assert!(matches!(err, ModelError::BadChecksum { .. }), "{err:?}");
}

#[test]
fn future_version_is_rejected_before_anything_else() {
    let spec = spec_for(Method::Nn);
    let mut bytes = trained_net(&spec, 3).to_bundle(&spec).unwrap().to_bytes();
    // version field lives at bytes 4..8; a future writer may change
    // everything after it (including the checksum scheme), so the
    // version check must fire without consulting the checksum
    bytes[4..8].copy_from_slice(&(BUNDLE_VERSION + 7).to_le_bytes());
    let err = ModelBundle::from_bytes(&bytes).expect_err("future version must fail");
    match err {
        ModelError::FutureVersion { found, supported } => {
            assert_eq!(found, BUNDLE_VERSION + 7);
            assert_eq!(supported, BUNDLE_VERSION);
        }
        other => panic!("expected FutureVersion, got {other:?}"),
    }
}

#[test]
fn wrong_shape_params_are_rejected_on_load() {
    let spec = spec_for(Method::Hashnet);
    let net = trained_net(&spec, 4);
    let mut bundle = net.to_bundle(&spec).unwrap();
    // doctor the bundle post-validation (fields are public; `load` is
    // the trust boundary): claim a different budget than the tensors
    bundle.spec.budgets = vec![22, 14];
    let bytes = bundle.to_bytes();
    let err = ModelBundle::from_bytes(&bytes).expect_err("shape lie must fail");
    assert!(matches!(err, ModelError::ShapeMismatch(_)), "{err:?}");
}

#[test]
fn interrupted_write_artifacts_fail_load_with_typed_error() {
    // Simulate every prefix a non-atomic writer could have left behind
    // after a crash: each must fail `load` with a typed ModelError,
    // never parse into a garbage model. (With the atomic save these
    // on-disk states can no longer occur at the published path; this
    // pins down the defense in depth for files that predate it or
    // arrived over a lossy channel.)
    let spec = spec_for(Method::Hashnet);
    let bytes = trained_net(&spec, 7).to_bundle(&spec).unwrap().to_bytes();
    let path = tmp("interrupted");
    for frac in [1usize, 4, 10, 19] {
        let cut = bytes.len() * frac / 20;
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = ModelBundle::load(&path).expect_err("torn prefix must fail load");
        assert!(
            matches!(
                err,
                ModelError::Truncated(_) | ModelError::BadChecksum { .. } | ModelError::BadMagic
            ),
            "cut at {cut}/{}: unexpected {err:?}",
            bytes.len()
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn atomic_save_replaces_in_place_and_leaves_no_temp_files() {
    let dir = std::env::temp_dir().join(format!("hn_bundle_atomic_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.hnb");

    // first save, then overwrite with a differently-initialized net:
    // the readable file must always be one complete, valid bundle
    let spec = spec_for(Method::Hashnet);
    trained_net(&spec, 8).to_bundle(&spec).unwrap().save(&path).expect("first save");
    let first = ModelBundle::load(&path).expect("first load");
    trained_net(&spec, 9).to_bundle(&spec).unwrap().save(&path).expect("overwrite save");
    let second = ModelBundle::load(&path).expect("load after overwrite");
    assert_eq!(first.spec, second.spec);
    assert_ne!(first.params, second.params, "overwrite must publish the new parameters");

    // the temp file must not survive a successful save
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n != "model.hnb")
        .collect();
    assert!(leftovers.is_empty(), "stray files after save: {leftovers:?}");

    // a directory target (no file name to derive a temp from) is a
    // typed error, not a panic
    let err = trained_net(&spec, 8)
        .to_bundle(&spec)
        .unwrap()
        .save(std::path::Path::new("/"))
        .expect_err("saving to '/' must fail");
    assert!(matches!(err, ModelError::Io(_)), "{err:?}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn save_syncs_the_parent_directory_for_nested_and_relative_targets() {
    // The crash-durability contract: after `save` returns, both the
    // file *and its directory entry* are fsynced — a power cut right
    // after the call must not resurrect the old file or lose the new
    // one. The syscall sequence can't be observed portably from a unit
    // test, so this pins the two path shapes the directory-fsync code
    // must handle: a nested directory (Some(parent)) and a bare
    // filename whose parent() is the empty string (the "." fallback).
    let spec = spec_for(Method::Hashnet);
    let bundle = trained_net(&spec, 11).to_bundle(&spec).unwrap();

    // nested directory, created fresh so the new entry is unsynced
    let dir = std::env::temp_dir()
        .join(format!("hn_bundle_fsync_{}", std::process::id()))
        .join("deeper");
    std::fs::create_dir_all(&dir).unwrap();
    let nested = dir.join("model.hnb");
    bundle.save(&nested).expect("save into nested dir");
    assert_eq!(ModelBundle::load(&nested).expect("load back").params, bundle.params);
    std::fs::remove_dir_all(dir.parent().unwrap()).ok();

    // bare relative filename: parent() is Some("") — save must fsync
    // the cwd, not error trying to open an empty path
    let rel = std::path::Path::new("hn_bundle_relative_fsync.hnb");
    bundle.save(rel).expect("save to bare relative path");
    assert_eq!(ModelBundle::load(rel).expect("load back").params, bundle.params);
    std::fs::remove_file(rel).ok();
}

#[test]
fn garbage_magic_is_not_a_bundle() {
    let err = ModelBundle::from_bytes(b"HNCKxxxxxxxxxxxxxxxx").expect_err("wrong magic");
    assert!(matches!(err, ModelError::BadMagic), "{err:?}");
    let err = ModelBundle::from_bytes(b"HN").expect_err("too short");
    assert!(matches!(err, ModelError::Truncated(_)), "{err:?}");
}
