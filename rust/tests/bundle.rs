//! Bundle format acceptance: save→load→predict bit-equality for every
//! [`Method`], plus the corruption error paths — truncation, checksum
//! damage, future format versions and parameter-shape mismatches all
//! fail with the right typed [`ModelError`].

use hashednets::model::{Method, ModelBundle, ModelError, ModelSpec, BUNDLE_VERSION};
use hashednets::nn::Network;
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;
use std::path::PathBuf;

fn spec_for(method: Method) -> ModelSpec {
    // budgets sized so every kind is exercised: hashed K, RER kept
    // edges, LRD ranks 3 and 4 (budget/n rounded)
    ModelSpec::new(
        format!("rt_{method}"),
        method,
        vec![9, 7, 4],
        vec![21, 14],
        hashednets::hash::DEFAULT_SEED_BASE,
        5,
    )
    .expect("valid spec")
}

fn trained_net(spec: &ModelSpec, seed: u64) -> Network {
    let mut net = Network::from_spec(spec).expect("from_spec");
    net.init(&mut Pcg32::new(seed, 31));
    net
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hn_bundle_{tag}_{}.hnb", std::process::id()))
}

#[test]
fn save_load_predict_bit_equality_per_method() {
    let x = Matrix::from_fn(6, 9, |i, j| ((i * 13 + j * 7) % 11) as f32 * 0.17 - 0.8);
    for method in Method::ALL {
        let spec = spec_for(method);
        let net = trained_net(&spec, 42);
        let want = net.predict(&x);

        let path = tmp(method.as_str());
        net.to_bundle(&spec).expect("to_bundle").save(&path).expect("save");
        let loaded = ModelBundle::load(&path).expect("load");
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.spec, spec, "{method}: spec round-trip");
        assert_eq!(loaded.version, BUNDLE_VERSION);
        let back = Network::from_bundle(&loaded).expect("from_bundle");
        let got = back.predict(&x);
        // bit-exact: same params, same hash plans, same kernels
        assert_eq!(got.data, want.data, "{method}: predict must be bit-identical");
    }
}

#[test]
fn truncated_file_is_a_clean_error() {
    let spec = spec_for(Method::Hashnet);
    let bytes = trained_net(&spec, 1).to_bundle(&spec).unwrap().to_bytes();
    // cut at several depths: inside the header, the spec, the tensors
    for cut in [2usize, 9, 20, bytes.len() / 2, bytes.len() - 5] {
        let err = ModelBundle::from_bytes(&bytes[..cut]).expect_err("truncated must fail");
        assert!(
            matches!(err, ModelError::Truncated(_) | ModelError::BadChecksum { .. }),
            "cut at {cut}: unexpected {err:?}"
        );
    }
    // cutting the trailing checksum itself
    let err = ModelBundle::from_bytes(&bytes[..bytes.len() - 4]).expect_err("no checksum");
    assert!(
        matches!(err, ModelError::Truncated(_) | ModelError::BadChecksum { .. }),
        "{err:?}"
    );
}

#[test]
fn flipped_payload_byte_is_a_checksum_error() {
    let spec = spec_for(Method::Hashnet);
    let mut bytes = trained_net(&spec, 2).to_bundle(&spec).unwrap().to_bytes();
    // flip one byte inside the f32 payload (well past the header+spec,
    // before the checksum) — structure stays parseable, content lies
    let at = bytes.len() - 12;
    bytes[at] ^= 0xA5;
    let err = ModelBundle::from_bytes(&bytes).expect_err("corrupt payload must fail");
    assert!(matches!(err, ModelError::BadChecksum { .. }), "{err:?}");
}

#[test]
fn future_version_is_rejected_before_anything_else() {
    let spec = spec_for(Method::Nn);
    let mut bytes = trained_net(&spec, 3).to_bundle(&spec).unwrap().to_bytes();
    // version field lives at bytes 4..8; a future writer may change
    // everything after it (including the checksum scheme), so the
    // version check must fire without consulting the checksum
    bytes[4..8].copy_from_slice(&(BUNDLE_VERSION + 7).to_le_bytes());
    let err = ModelBundle::from_bytes(&bytes).expect_err("future version must fail");
    match err {
        ModelError::FutureVersion { found, supported } => {
            assert_eq!(found, BUNDLE_VERSION + 7);
            assert_eq!(supported, BUNDLE_VERSION);
        }
        other => panic!("expected FutureVersion, got {other:?}"),
    }
}

#[test]
fn wrong_shape_params_are_rejected_on_load() {
    let spec = spec_for(Method::Hashnet);
    let net = trained_net(&spec, 4);
    let mut bundle = net.to_bundle(&spec).unwrap();
    // doctor the bundle post-validation (fields are public; `load` is
    // the trust boundary): claim a different budget than the tensors
    bundle.spec.budgets = vec![22, 14];
    let bytes = bundle.to_bytes();
    let err = ModelBundle::from_bytes(&bytes).expect_err("shape lie must fail");
    assert!(matches!(err, ModelError::ShapeMismatch(_)), "{err:?}");
}

#[test]
fn garbage_magic_is_not_a_bundle() {
    let err = ModelBundle::from_bytes(b"HNCKxxxxxxxxxxxxxxxx").expect_err("wrong magic");
    assert!(matches!(err, ModelError::BadMagic), "{err:?}");
    let err = ModelBundle::from_bytes(b"HN").expect_err("too short");
    assert!(matches!(err, ModelError::Truncated(_)), "{err:?}");
}
