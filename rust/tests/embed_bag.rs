//! Acceptance tests for the hashed embedding-bag subsystem (ISSUE 8):
//! (a) the hashed bag forward must match a materialized reference table
//! bit-exactly, (b) the Eq. 12-style backward must be bit-identical
//! across thread counts in ordered mode, and (c) JSON and binary
//! sparse requests must return identical results through the real
//! server — including empty bags (zero vectors) and out-of-range
//! indices (`bad_input` on both protocols).

use hashednets::hash::DEFAULT_SEED_BASE;
use hashednets::model::BagMode;
use hashednets::nn::{EmbedBag, TrainOptions};
use hashednets::serve::frame::{self, FrameReply};
use hashednets::serve::{
    Backend, Client, FrameClient, InferenceEngine, NativeEngine, ServeOptions, Server,
};
use hashednets::tensor::Matrix;
use hashednets::util::json::Json;
use hashednets::util::rng::Pcg32;
use std::sync::Arc;

fn make_bag(nc: usize, dim: usize, k: usize, mode: BagMode, seed: u64) -> EmbedBag {
    let mut bag = EmbedBag::new(nc, dim, k, mode, DEFAULT_SEED_BASE);
    bag.init(&mut Pcg32::new(seed, 11));
    bag
}

/// Random CSR bags: `n` bags of 1..=max_len ids over the category range.
fn random_bags(rng: &mut Pcg32, nc: usize, n: usize, max_len: usize) -> (Vec<u32>, Vec<u32>) {
    let mut indices = Vec::new();
    let mut offsets = Vec::with_capacity(n);
    for _ in 0..n {
        offsets.push(indices.len() as u32);
        let len = 1 + (rng.next_u32() as usize) % max_len;
        for _ in 0..len {
            indices.push(rng.next_u32() % nc as u32);
        }
    }
    (indices, offsets)
}

/// Reference reduction over a fully materialized `nc × dim` table,
/// accumulating in the same (bag-order, then column) order as the
/// hashed path so f32 equality can be exact.
fn reference_forward(bag: &EmbedBag, indices: &[u32], offsets: &[u32]) -> Vec<f32> {
    let mut table = vec![0.0f32; bag.num_categories * bag.dim];
    for row in 0..bag.num_categories {
        bag.decompress_row_into(row, &mut table[row * bag.dim..(row + 1) * bag.dim]);
    }
    let n_bags = offsets.len();
    let mut out = vec![0.0f32; n_bags * bag.dim];
    for b in 0..n_bags {
        let start = offsets[b] as usize;
        let end = offsets.get(b + 1).map(|&o| o as usize).unwrap_or(indices.len());
        for &idx in &indices[start..end] {
            let row = &table[idx as usize * bag.dim..(idx as usize + 1) * bag.dim];
            for (o, &v) in out[b * bag.dim..(b + 1) * bag.dim].iter_mut().zip(row) {
                *o += v;
            }
        }
        if bag.mode == BagMode::Mean && end > start {
            let inv = 1.0 / (end - start) as f32;
            for o in &mut out[b * bag.dim..(b + 1) * bag.dim] {
                *o *= inv;
            }
        }
    }
    out
}

// ---- (a) forward vs materialized table ----

#[test]
fn forward_matches_materialized_table_bit_exact_in_both_modes() {
    for mode in [BagMode::Sum, BagMode::Mean] {
        let bag = make_bag(200, 8, 64, mode, 3);
        let mut rng = Pcg32::new(17, 5);
        let (indices, offsets) = random_bags(&mut rng, 200, 40, 6);
        let z = bag.forward(&indices, &offsets);
        let want = reference_forward(&bag, &indices, &offsets);
        assert_eq!(z.data.len(), want.len());
        for (i, (got, want)) in z.data.iter().zip(&want).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{mode:?} value {i}: {got} vs {want}"
            );
        }
    }
}

// ---- (b) backward determinism across thread counts ----

#[test]
fn sum_backward_is_bit_identical_across_thread_counts_in_ordered_mode() {
    let bag = make_bag(5000, 16, 512, BagMode::Sum, 9);
    let mut rng = Pcg32::new(23, 7);
    let (indices, offsets) = random_bags(&mut rng, 5000, 64, 10);
    let delta = Matrix::from_fn(offsets.len(), 16, |i, j| {
        ((i * 31 + j * 7) % 13) as f32 * 0.17 - 1.0
    });
    let grad_at = |threads: usize| {
        let opts = TrainOptions::with_threads(threads).ordered();
        let mut grad = vec![0.0f32; bag.k()];
        bag.backward(&indices, &offsets, &delta, &mut grad, &opts);
        grad
    };
    let base = grad_at(1);
    assert!(base.iter().any(|&g| g != 0.0), "gradient must be nonzero");
    for threads in [2, 3, 8] {
        let got = grad_at(threads);
        for (b, (x, y)) in base.iter().zip(&got).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "bucket {b} differs at {threads} threads: {x} vs {y}"
            );
        }
    }
}

// ---- (c) both wire protocols through the real server ----

fn serve_embedding() -> (std::thread::JoinHandle<anyhow::Result<()>>, String, EmbedBag) {
    // A million-row virtual table (1M × 16 = 16M virtual cells) served
    // from 4096 resident buckets — the table is never materialized.
    let bag = make_bag(1_000_000, 16, 4096, BagMode::Sum, 7);
    let engine: Arc<dyn InferenceEngine + Send + Sync> = {
        let mut served = EmbedBag::new(1_000_000, 16, 4096, BagMode::Sum, DEFAULT_SEED_BASE);
        served.w = bag.w.clone();
        Arc::new(NativeEngine::from_embed_bag(served, 8))
    };
    let opts = ServeOptions {
        artifacts_dir: std::env::temp_dir().join("hn_embed_bag_no_artifacts"),
        models: Vec::new(),
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 2,
        ..Default::default()
    };
    let srv = Server::bind_with_engines(opts, vec![("embed".into(), engine)]).expect("bind");
    let addr = srv.local_addr().to_string();
    (std::thread::spawn(move || srv.run()), addr, bag)
}

fn json_values(v: &Json) -> Vec<f32> {
    v.get("values")
        .and_then(Json::as_arr)
        .expect("values array")
        .iter()
        .map(|x| x.as_f64().expect("number") as f32)
        .collect()
}

#[test]
fn json_and_binary_sparse_requests_agree_through_the_real_server() {
    let (server, addr, bag) = serve_embedding();
    let mut json = Client::connect(&addr).expect("json connect");
    let mut bin = FrameClient::connect(&addr).expect("bin connect");

    let mut rng = Pcg32::new(41, 13);
    let (indices, offsets) = random_bags(&mut rng, 1_000_000, 12, 8);
    let want = bag.forward(&indices, &offsets);

    // JSON sparse round trip: the f32 → text → f32 trip is bit-exact.
    let v = json.classify_sparse_raw(None, &indices, &offsets, None).expect("json sparse");
    assert_eq!(
        v.get("bags").and_then(Json::as_f64),
        Some(offsets.len() as f64),
        "reply: {v:?}"
    );
    let jvals = json_values(&v);
    assert_eq!(jvals.len(), want.data.len());
    for (i, (got, want)) in jvals.iter().zip(&want.data).enumerate() {
        assert_eq!(got.to_bits(), want.to_bits(), "json value {i}");
    }

    // Binary sparse round trip: same reply through the frame protocol.
    match bin.classify_sparse("", &indices, &offsets, 0).expect("bin sparse") {
        FrameReply::Ok { class, probs, .. } => {
            assert_eq!(class as usize, offsets.len(), "class carries the bag count");
            assert_eq!(probs.len(), want.data.len());
            for (i, (got, want)) in probs.iter().zip(&want.data).enumerate() {
                assert_eq!(got.to_bits(), want.to_bits(), "binary value {i}");
            }
        }
        other => panic!("expected Ok frame, got {other:?}"),
    }

    json.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

#[test]
fn empty_bags_return_zero_vectors_on_both_protocols() {
    let (server, addr, _bag) = serve_embedding();
    let mut json = Client::connect(&addr).expect("json connect");
    let mut bin = FrameClient::connect(&addr).expect("bin connect");

    // Three empty bags: indices is empty, every offset is 0.
    let offsets = vec![0u32, 0, 0];
    let v = json.classify_sparse_raw(None, &[], &offsets, None).expect("json sparse");
    assert_eq!(v.get("bags").and_then(Json::as_f64), Some(3.0), "reply: {v:?}");
    let jvals = json_values(&v);
    assert_eq!(jvals.len(), 3 * 16);
    assert!(jvals.iter().all(|&x| x == 0.0), "empty bags must be zero vectors");

    match bin.classify_sparse("", &[], &offsets, 0).expect("bin sparse") {
        FrameReply::Ok { class, probs, .. } => {
            assert_eq!(class, 3);
            assert_eq!(probs.len(), 3 * 16);
            assert!(probs.iter().all(|&x| x == 0.0));
        }
        other => panic!("expected Ok frame, got {other:?}"),
    }

    json.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}

#[test]
fn out_of_range_index_is_bad_input_on_both_protocols() {
    let (server, addr, _bag) = serve_embedding();
    let mut json = Client::connect(&addr).expect("json connect");
    let mut bin = FrameClient::connect(&addr).expect("bin connect");

    // index == num_categories is one past the last valid id
    let indices = vec![1_000_000u32];
    let offsets = vec![0u32];
    let v = json.classify_sparse_raw(None, &indices, &offsets, None).expect("json sparse");
    assert_eq!(
        v.get("code").and_then(Json::as_str),
        Some("bad_input"),
        "reply: {v:?}"
    );
    match bin.classify_sparse("", &indices, &offsets, 0).expect("bin sparse") {
        FrameReply::Err { code, message, .. } => {
            assert_eq!(frame::num_to_code(code), "bad_input");
            assert!(message.contains("out of range"), "diagnostic: {message}");
        }
        other => panic!("expected Err frame, got {other:?}"),
    }

    // a dense pixel request against the sparse model is bad_input too
    let v = json.classify_raw(None, &[0.5; 16], None).expect("dense raw");
    assert_eq!(v.get("code").and_then(Json::as_str), Some("bad_input"), "reply: {v:?}");

    json.shutdown().expect("shutdown");
    server.join().unwrap().expect("server run");
}
