//! Backward-parity and determinism coverage for the threaded training
//! path.
//!
//! Two contracts are asserted here:
//!
//! * **Fast mode** (the default unordered reduction): threaded
//!   gradients match the single-thread gradients within float
//!   tolerance (1e-5 relative) across batch 1 / 50 and shapes hitting
//!   all three hashed kernel regimes (bucket-major `B = 1, K ≤ m+1`,
//!   gather `B = 1, K > m+1`, scratch-row `B ≥ 2`).
//! * **Ordered mode** (`TrainOptions::deterministic`, the CLI's
//!   `--reduction ordered`): results are **bit-identical** across
//!   thread counts — at layer level (gradients), network level
//!   (trained parameters) and end-to-end (`run_native` bundles are
//!   byte-identical between `--threads 1` and `--threads 4`).
//!
//! These tests need no artifacts — they run on a fresh checkout.

use hashednets::coordinator::trainer::{self, TrainConfig};
use hashednets::data::Kind;
use hashednets::model::{Method, ModelSpec};
use hashednets::nn::{Layer, LayerKind, TrainOptions};
use hashednets::tensor::Matrix;
use hashednets::util::rng::Pcg32;

/// (m, n, k, batch) shapes hitting each hashed kernel regime.
const REGIMES: &[(usize, usize, usize, usize)] = &[
    (30, 40, 20, 1),    // bucket-major: B = 1, K ≤ m+1
    (30, 40, 2000, 1),  // gather: B = 1, K > m+1 (and > n·(m+1))
    (30, 40, 200, 50),  // scratch-row: the paper's minibatch
    (30, 40, 20, 50),   // scratch-row with heavy weight sharing
];

fn hashed_layer(m: usize, n: usize, k: usize, seed: u64) -> Layer {
    let mut layer =
        Layer::new(m, n, LayerKind::Hashed { k }, 0, hashednets::hash::DEFAULT_SEED_BASE);
    layer.init(&mut Pcg32::new(seed, seed ^ 0x77));
    layer
}

fn grads(layer: &Layer, a: &Matrix, delta: &Matrix, opts: &TrainOptions) -> (Vec<f32>, Matrix) {
    let mut g = vec![0.0f32; layer.params.len()];
    let da = layer.backward(a, delta, &mut g, opts);
    (g, da)
}

fn assert_close(name: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() < 1e-5 * (1.0 + w.abs()),
            "{name} elem {i}: {g} vs {w}"
        );
    }
}

fn assert_bits(name: &str, got: &[f32], want: &[f32]) {
    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
    let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
    assert_eq!(gb, wb, "{name}: not bit-identical");
}

#[test]
fn fast_mode_threaded_gradients_match_serial_across_regimes() {
    for &(m, n, k, batch) in REGIMES {
        let layer = hashed_layer(m, n, k, (m + n * 3 + k) as u64);
        let mut rng = Pcg32::new(batch as u64 + 1, k as u64);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let delta = Matrix::from_fn(batch, n, |_, _| rng.normal());
        let (g1, da1) = grads(&layer, &a, &delta, &TrainOptions::default());
        for threads in [2usize, 4, 8] {
            let (gt, dat) = grads(&layer, &a, &delta, &TrainOptions::with_threads(threads));
            assert_close(&format!("grad k={k} b={batch} t={threads}"), &gt, &g1);
            assert_close(&format!("da k={k} b={batch} t={threads}"), &dat.data, &da1.data);
        }
    }
}

#[test]
fn ordered_mode_bit_identical_across_thread_counts() {
    for &(m, n, k, batch) in REGIMES {
        let layer = hashed_layer(m, n, k, (m + n + k * 5) as u64);
        let mut rng = Pcg32::new(batch as u64 + 2, k as u64);
        let a = Matrix::from_fn(batch, m, |_, _| rng.normal());
        let delta = Matrix::from_fn(batch, n, |_, _| rng.normal());
        // small block height forces a multi-block partition (n = 40 → 5
        // blocks), so the reduction order is genuinely exercised
        let ordered =
            |t: usize| TrainOptions { threads: t, block_rows: 8, deterministic: true };
        let (g1, da1) = grads(&layer, &a, &delta, &ordered(1));
        for threads in [2usize, 4, 8] {
            let (gt, dat) = grads(&layer, &a, &delta, &ordered(threads));
            assert_bits(&format!("grad k={k} b={batch} t={threads}"), &gt, &g1);
            assert_bits(&format!("da k={k} b={batch} t={threads}"), &dat.data, &da1.data);
        }
        // ordered-mode gradients are still the same math: close to the
        // serial fast path
        let (gf, _) = grads(&layer, &a, &delta, &TrainOptions::default());
        assert_close(&format!("ordered-vs-serial k={k} b={batch}"), &g1, &gf);
    }
}

#[test]
fn dense_masked_lowrank_backward_thread_count_invariant() {
    // the non-hashed paths go through row-parallel matmuls, which are
    // bit-identical to serial at any thread count in *both* modes
    for kind in [
        LayerKind::Dense,
        LayerKind::Masked { k: 300 },
        LayerKind::LowRank { r: 4 },
    ] {
        let mut layer =
            Layer::new(25, 18, kind.clone(), 0, hashednets::hash::DEFAULT_SEED_BASE);
        layer.init(&mut Pcg32::new(3, 3));
        let mut rng = Pcg32::new(8, 8);
        let a = Matrix::from_fn(50, 25, |_, _| rng.normal());
        let delta = Matrix::from_fn(50, 18, |_, _| rng.normal());
        let (g1, da1) = grads(&layer, &a, &delta, &TrainOptions::default());
        for threads in [2usize, 4] {
            for opts in [
                TrainOptions::with_threads(threads),
                TrainOptions::with_threads(threads).ordered(),
            ] {
                let (gt, dat) = grads(&layer, &a, &delta, &opts);
                assert_bits(&format!("{kind:?} grad t={threads}"), &gt, &g1);
                assert_bits(&format!("{kind:?} da t={threads}"), &dat.data, &da1.data);
            }
        }
    }
}

/// The satellite contract for PoolExec: ordered-mode bit-identity must
/// survive the move from per-call `thread::scope` workers to the shared
/// pool. The pool is warmed first and the backward is run with exactly
/// the pool's lane count, so the blocks genuinely execute on parked
/// pool workers (not the caller-only inline path).
#[test]
fn ordered_mode_bit_identity_holds_on_pool_exec() {
    let lanes = hashednets::rt::pool::max_concurrency().max(2);
    hashednets::rt::pool::run(lanes * 2, |_| {}); // warm: workers spawned and parked
    let layer = hashed_layer(24, 96, 300, 42);
    let mut rng = Pcg32::new(31, 7);
    let a = Matrix::from_fn(50, 24, |_, _| rng.normal());
    let delta = Matrix::from_fn(50, 96, |_, _| rng.normal());
    let ordered = |t: usize| TrainOptions { threads: t, block_rows: 8, deterministic: true };
    let (g1, da1) = grads(&layer, &a, &delta, &ordered(1));
    let (gp, dap) = grads(&layer, &a, &delta, &ordered(lanes));
    assert_bits("pool ordered grad", &gp, &g1);
    assert_bits("pool ordered da", &dap.data, &da1.data);
    // the inverse-plan Eq. 12 pass makes ∂w thread-count-invariant even
    // in fast mode — a determinism upgrade the pool must preserve too
    let (gf1, _) = grads(&layer, &a, &delta, &TrainOptions::with_threads(1));
    let (gfp, _) = grads(&layer, &a, &delta, &TrainOptions::with_threads(lanes));
    assert_bits("pool fast-mode grad", &gfp, &gf1);
}

/// The tiled (`hashed_tile`) backward in ordered mode: both ∂w and ∂a
/// bit-identical across thread counts. Unlike the per-cell path (whose
/// inverse-plan Eq. 12 is invariant even in fast mode), tile runs
/// overlap arbitrarily in the stored vector, so ∂w invariance is an
/// ordered-mode contract — exactly what this asserts.
#[test]
fn tiled_ordered_mode_bit_identical_across_thread_counts() {
    for &(tile, k) in &[((1usize, 8usize), 200usize), ((8, 8), 120)] {
        for batch in [1usize, 50] {
            let mut layer = Layer::new(
                30,
                40,
                LayerKind::HashedTile { k, tile },
                0,
                hashednets::hash::DEFAULT_SEED_BASE,
            );
            layer.init(&mut Pcg32::new((k + batch) as u64, 0x717E));
            let mut rng = Pcg32::new(batch as u64 + 5, k as u64);
            let a = Matrix::from_fn(batch, 30, |_, _| rng.normal());
            let delta = Matrix::from_fn(batch, 40, |_, _| rng.normal());
            let ordered =
                |t: usize| TrainOptions { threads: t, block_rows: 8, deterministic: true };
            let (g1, da1) = grads(&layer, &a, &delta, &ordered(1));
            for threads in [2usize, 4, 8] {
                let (gt, dat) = grads(&layer, &a, &delta, &ordered(threads));
                assert_bits(&format!("tiled{tile:?} grad b={batch} t={threads}"), &gt, &g1);
                assert_bits(&format!("tiled{tile:?} da b={batch} t={threads}"), &dat.data, &da1.data);
            }
            // ordered is the same math as the serial fast path
            let (gf, _) = grads(&layer, &a, &delta, &TrainOptions::default());
            assert_close(&format!("tiled{tile:?} ordered-vs-serial b={batch}"), &g1, &gf);
        }
    }
}

/// Acceptance: `Method::HashedTile` round-trips spec → native train →
/// bundle, with ordered-mode training byte-identical between
/// `--threads 1` and `--threads 4`.
#[test]
fn tiled_ordered_run_native_bundles_are_byte_identical() {
    let spec = ModelSpec::new(
        "det_hashed_tile",
        Method::HashedTile { tile: (1, 8) },
        vec![784, 12, 10],
        vec![400, 50],
        hashednets::hash::DEFAULT_SEED_BASE,
        50,
    )
    .unwrap();
    let bundle_bytes = |threads: usize| -> Vec<u8> {
        let cfg = TrainConfig {
            artifact: spec.name.clone(),
            dataset: Kind::Basic,
            n_train: 300,
            n_test: 200,
            epochs: 2,
            seed: 13,
            train: TrainOptions { threads, block_rows: 4, deterministic: true },
            ..Default::default()
        };
        let res = trainer::run_native(&spec, &cfg).unwrap();
        assert_eq!(res.threads, threads);
        assert_eq!(res.stored_params, 450);
        res.bundle().unwrap().to_bytes()
    };
    let b1 = bundle_bytes(1);
    let b4 = bundle_bytes(4);
    assert_eq!(b1, b4, "ordered-mode tiled bundles must be byte-identical");
    // and the bytes reload into the same spec
    let reloaded = hashednets::model::ModelBundle::from_bytes(&b1).unwrap();
    assert_eq!(reloaded.spec.method, Method::HashedTile { tile: (1, 8) });
}

#[test]
fn empty_batch_backward_is_a_noop() {
    let layer = hashed_layer(10, 8, 12, 4);
    let a = Matrix::zeros(0, 10);
    let delta = Matrix::zeros(0, 8);
    for opts in [TrainOptions::with_threads(4), TrainOptions::with_threads(4).ordered()] {
        let (g, da) = grads(&layer, &a, &delta, &opts);
        assert!(g.iter().all(|&v| v == 0.0));
        assert_eq!(da.rows, 0);
    }
}

/// The acceptance-level contract: `train --threads 4 --reduction
/// ordered` writes the same bytes to disk as `--threads 1`.
#[test]
fn ordered_run_native_bundles_are_byte_identical() {
    let spec = ModelSpec::new(
        "det_hashnet",
        Method::Hashnet,
        vec![784, 12, 10],
        vec![400, 50],
        hashednets::hash::DEFAULT_SEED_BASE,
        50,
    )
    .unwrap();
    let bundle_bytes = |threads: usize, deterministic: bool| -> Vec<u8> {
        let cfg = TrainConfig {
            artifact: spec.name.clone(),
            dataset: Kind::Basic,
            n_train: 300,
            n_test: 200,
            epochs: 2,
            seed: 11,
            train: TrainOptions { threads, block_rows: 4, deterministic },
            ..Default::default()
        };
        let res = trainer::run_native(&spec, &cfg).unwrap();
        assert_eq!(res.threads, threads);
        res.bundle().unwrap().to_bytes()
    };
    let b1 = bundle_bytes(1, true);
    let b4 = bundle_bytes(4, true);
    assert_eq!(b1, b4, "ordered-mode bundles must be byte-identical");
    // fast mode still trains a valid model of the same shape (bytes may
    // differ in the float low bits — that's the documented trade)
    let bf = bundle_bytes(4, false);
    assert_eq!(bf.len(), b1.len());
}
