//! API-compatible stub of the `xla` (xla-rs) PJRT binding.
//!
//! The real binding needs the vendored XLA dependency closure, which
//! this environment does not ship. This stub exposes the same type and
//! method surface the `hashednets::runtime` module uses, with every
//! entry point that would touch PJRT returning an "unavailable" error.
//! `Runtime::open` therefore fails cleanly, and everything
//! artifact-dependent (integration tests, benches, examples) already
//! skips gracefully on that failure — the native engine, trainer tests
//! and serving unit tests are unaffected.
//!
//! To enable the PJRT path, replace this crate with the real vendored
//! `xla` crate; no source change in `hashednets` is needed.

/// Error reported for every stubbed PJRT operation (printed with `{:?}`).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT backend unavailable: the offline xla stub is linked (vendor the real xla crate to enable artifacts)"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Compiled executable handle (stub: unreachable without a client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Host literal (stub: constructors exist so marshaling code compiles;
/// readbacks fail).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Parsed HLO module (stub: parsing always fails — nothing downstream
/// could execute it anyway).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
