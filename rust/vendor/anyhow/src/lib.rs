//! Minimal offline stand-in for the `anyhow` crate, covering exactly
//! the subset `hashednets` uses: [`Error`], [`Result`], the [`anyhow!`]
//! macro, the [`Context`] extension trait, and `?`-conversion from any
//! `std::error::Error`. Error messages render the context chain the
//! same way for `{}`, `{:#}` and `{:?}`.

use std::fmt;

/// A string-backed error with an optional chain of context lines.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn push_context(mut self, c: impl fmt::Display) -> Error {
        self.context.push(c.to_string());
        self
    }

    fn render(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // outermost context first, root cause last — like anyhow's `{:#}`
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.render(f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.render(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string, a displayable value, or
/// a format string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Attach context to an error result.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        let b: Error = anyhow!(String::from("from expr"));
        let c: Error = anyhow!("x = {}", 7);
        let name = "y";
        let d: Error = anyhow!("inline {name}");
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "from expr");
        assert_eq!(c.to_string(), "x = 7");
        assert_eq!(d.to_string(), "inline y");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().with_context(|| "loading config").unwrap_err();
        let text = format!("{e:#}");
        assert!(text.starts_with("loading config: "), "{text}");
    }
}
