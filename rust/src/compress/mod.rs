//! Compression toolkit: post-hoc conversion of trained dense networks
//! into HashedNets, plus measurements behind the paper's analysis.
//!
//! The paper trains HashedNets from scratch; this module additionally
//! supports the deployment workflow its introduction motivates — take
//! an existing dense model, compress it to a target budget, optionally
//! fine-tune — and implements the feature-hashing inner-product
//! preservation check (Eq. 1) used by tests and benches.

use crate::hash::{bucket_sign, layer_seeds};
use crate::nn::{Layer, LayerKind};
use crate::tensor::Matrix;

/// Least-squares projection of a dense weight matrix onto the hashed
/// parameterization: each bucket takes the ξ-weighted mean of its
/// members (the minimizer of ‖V − V̂‖²_F under Eq. 7).
///
/// `dense` is `(n × (m+1))` (bias column included, like hashed layers).
pub fn compress_dense(dense: &Matrix, k: usize, layer_index: u32, seed_base: u32) -> Vec<f32> {
    let (n, m1) = (dense.rows, dense.cols);
    let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
    let mut sums = vec![0.0f64; k];
    let mut counts = vec![0u32; k];
    for i in 0..n {
        for j in 0..m1 {
            let (b, sg) = bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
            // V_ij = ξ w_b  ⇒  contribution to w_b is ξ V_ij
            sums[b as usize] += (sg * dense.at(i, j)) as f64;
            counts[b as usize] += 1;
        }
    }
    sums.iter()
        .zip(&counts)
        .map(|(&s, &c)| if c == 0 { 0.0 } else { (s / c as f64) as f32 })
        .collect()
}

/// Build a hashed layer whose virtual matrix approximates `dense`.
pub fn hashed_layer_from_dense(
    dense: &Matrix,
    k: usize,
    layer_index: usize,
    seed_base: u32,
) -> Layer {
    let (n, m1) = (dense.rows, dense.cols);
    let mut layer = Layer::new(m1 - 1, n, LayerKind::Hashed { k }, layer_index, seed_base);
    layer.params = compress_dense(dense, k, layer_index as u32, seed_base);
    layer
}

/// Relative Frobenius reconstruction error ‖V − V̂‖ / ‖V‖ of compressing
/// `dense` to `k` buckets (the redundancy measurement of Denil et al.
/// that motivates the paper).
pub fn reconstruction_error(dense: &Matrix, k: usize, layer_index: u32, seed_base: u32) -> f64 {
    let w = compress_dense(dense, k, layer_index, seed_base);
    let (n, m1) = (dense.rows, dense.cols);
    let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        for j in 0..m1 {
            let (b, sg) = bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
            let v = dense.at(i, j) as f64;
            let vhat = (sg * w[b as usize]) as f64;
            num += (v - vhat) * (v - vhat);
            den += v * v;
        }
    }
    (num / den.max(1e-30)).sqrt()
}

/// Hashed inner product ⟨φ(x), φ(x')⟩ under one hash-pair seed — the
/// quantity Eq. 1 says is unbiased for ⟨x, x'⟩.
pub fn hashed_inner_product(x: &[f32], y: &[f32], k: usize, seed_h: u32, seed_xi: u32) -> f64 {
    let m = x.len() as u32;
    let mut phi_x = vec![0.0f64; k];
    let mut phi_y = vec![0.0f64; k];
    for j in 0..x.len() {
        let (b, sg) = bucket_sign(0, j as u32, m, k as u32, seed_h, seed_xi);
        phi_x[b as usize] += (sg * x[j]) as f64;
        phi_y[b as usize] += (sg * y[j]) as f64;
    }
    phi_x.iter().zip(&phi_y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn compression_is_exact_when_k_large_and_injective() {
        // with k >> n*m most buckets have one member: near-exact recon
        let mut rng = Pcg32::new(1, 1);
        let dense = Matrix::from_fn(6, 8, |_, _| rng.normal());
        let err = reconstruction_error(&dense, 4096, 0, crate::hash::DEFAULT_SEED_BASE);
        assert!(err < 0.35, "err {err}"); // birthday collisions only
    }

    #[test]
    fn reconstruction_error_decreases_with_k() {
        let mut rng = Pcg32::new(2, 1);
        let dense = Matrix::from_fn(20, 21, |_, _| rng.normal());
        let seed = crate::hash::DEFAULT_SEED_BASE;
        let e8 = reconstruction_error(&dense, 420 / 8, 0, seed);
        let e2 = reconstruction_error(&dense, 420 / 2, 0, seed);
        let e1 = reconstruction_error(&dense, 4200, 0, seed);
        assert!(e1 < e2 && e2 < e8, "{e1} {e2} {e8}");
    }

    #[test]
    fn compressed_layer_approximates_dense_forward() {
        let mut rng = Pcg32::new(3, 1);
        // low-complexity dense matrix (smooth) compresses well
        let dense = Matrix::from_fn(10, 13, |i, j| ((i as f32 * 0.3).sin() + (j as f32 * 0.2).cos()) * 0.3);
        let layer = hashed_layer_from_dense(&dense, 60, 0, crate::hash::DEFAULT_SEED_BASE);
        let a = Matrix::from_fn(4, 12, |_, _| rng.normal());
        let z_dense = a.augment_ones().matmul_nt(&dense);
        let z_hash = layer.forward(&a);
        let rel = {
            let mut num = 0.0f32;
            let mut den = 0.0f32;
            for (zd, zh) in z_dense.data.iter().zip(&z_hash.data) {
                num += (zd - zh) * (zd - zh);
                den += zd * zd;
            }
            (num / den).sqrt()
        };
        assert!(rel < 0.9, "relative error {rel}");
    }

    #[test]
    fn inner_product_unbiased_over_seeds() {
        // Eq. 1: averaging over independent hash functions approaches x·y
        let mut rng = Pcg32::new(4, 1);
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let true_ip: f64 = x.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
        let trials = 800;
        let mean: f64 = (0..trials)
            .map(|t| hashed_inner_product(&x, &y, 16, 900 + t, 7700 + t))
            .sum::<f64>()
            / trials as f64;
        let norm = (x.iter().map(|v| (v * v) as f64).sum::<f64>()
            * y.iter().map(|v| (v * v) as f64).sum::<f64>())
        .sqrt();
        let tol = 4.0 * norm / (16.0f64 * trials as f64).sqrt();
        assert!((mean - true_ip).abs() < tol, "mean {mean} true {true_ip} tol {tol}");
    }
}
