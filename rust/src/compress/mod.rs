//! Compression toolkit: post-hoc conversion of trained dense networks
//! into HashedNets, plus measurements behind the paper's analysis.
//!
//! The paper trains HashedNets from scratch; this module additionally
//! supports the deployment workflow its introduction motivates — take
//! an existing dense model, compress it to a target budget, optionally
//! fine-tune — and implements the feature-hashing inner-product
//! preservation check (Eq. 1) used by tests and benches.
//!
//! # Mapping to the paper
//!
//! * [`compress_dense`] — the least-squares projection onto Eq. 7's
//!   parameterization: each bucket `k < K` takes the ξ-weighted mean of
//!   its members, the minimizer of `‖V − V̂‖²_F` given the hash pair
//!   `(h, ξ)` of §4.2. The `K` budgets are exactly the per-layer
//!   `budgets` of a [`crate::model::ModelSpec`].
//! * [`reconstruction_error`] — the relative Frobenius redundancy
//!   measurement (Denil et al. 2013) that motivates §3: how well `K`
//!   buckets can represent an `n × (m+1)` dense matrix.
//! * [`hashed_inner_product`] — Eq. 1's hashed feature map
//!   `⟨φ(x), φ(x′)⟩`, whose unbiasedness for `⟨x, x′⟩` is why hashing
//!   with signs preserves the forward activations in expectation.
//! * [`compress_network`] — the one-call dense → HashedNet pipeline,
//!   emitting a self-describing [`crate::model::ModelBundle`]; after
//!   compression, `hashednets train --threads N` fine-tunes the result
//!   with the threaded backward (Eqs. 11–12).

use crate::hash::{bucket_sign, layer_seeds, HashPlan, TilePlan};
use crate::model::{Method, ModelBundle, ModelError, ModelSpec};
use crate::nn::{Layer, LayerKind, Network};
use crate::tensor::Matrix;

/// Shared validation for the dense → compressed pipelines: the source
/// must be fully dense with one budget per layer.
fn check_dense_source(net: &Network, budgets: &[usize]) -> Result<(), ModelError> {
    if net.layers.is_empty() {
        return Err(ModelError::InvalidSpec("network has no layers".into()));
    }
    if let Some((l, kind)) = net
        .layers
        .iter()
        .enumerate()
        .find_map(|(l, lay)| (lay.kind != LayerKind::Dense).then(|| (l, lay.kind.clone())))
    {
        return Err(ModelError::InvalidSpec(format!(
            "layer {l} is {kind:?}; compression takes a fully dense network"
        )));
    }
    if budgets.len() != net.layers.len() {
        return Err(ModelError::InvalidSpec(format!(
            "{} budgets for {} layers",
            budgets.len(),
            net.layers.len()
        )));
    }
    Ok(())
}

/// Compress a trained **dense** network into a HashedNet bundle in one
/// call: every layer's `(n × (m+1))` weight+bias matrix is
/// least-squares-projected onto `budgets[l]` hash buckets
/// ([`compress_dense`]), and the result is packaged as a
/// self-describing [`ModelBundle`] ready to save or serve.
///
/// The returned spec is named `{name}` with the source network's
/// dims and seed base; rename via `bundle.spec.name` if needed.
pub fn compress_network(
    net: &Network,
    budgets: &[usize],
    name: impl Into<String>,
) -> Result<ModelBundle, ModelError> {
    check_dense_source(net, budgets)?;
    let seed_base = net.layers[0].seed_base;
    let mut dims: Vec<usize> = vec![net.n_in()];
    dims.extend(net.layers.iter().map(|l| l.n));
    let spec = ModelSpec::new(
        name,
        Method::Hashnet,
        dims,
        budgets.to_vec(),
        seed_base,
        50,
    )?;
    let mut hashed = Network::from_spec(&spec)?;
    for (l, (dense_layer, hashed_layer)) in
        net.layers.iter().zip(hashed.layers.iter_mut()).enumerate()
    {
        let vb = dense_with_bias(dense_layer);
        hashed_layer.params = compress_dense(&vb, budgets[l], l as u32, seed_base).into();
    }
    hashed.to_bundle(&spec)
}

/// [`compress_network`]'s block-structured twin: project every dense
/// layer onto the tile-run parameterization of
/// [`Method::HashedTile`] (see [`compress_dense_tiled`]) and package
/// the result as a `hashed_tile` bundle that the SIMD kernels serve.
pub fn compress_network_tiled(
    net: &Network,
    budgets: &[usize],
    tile: (usize, usize),
    name: impl Into<String>,
) -> Result<ModelBundle, ModelError> {
    check_dense_source(net, budgets)?;
    let seed_base = net.layers[0].seed_base;
    let mut dims: Vec<usize> = vec![net.n_in()];
    dims.extend(net.layers.iter().map(|l| l.n));
    let spec = ModelSpec::new(
        name,
        Method::HashedTile { tile },
        dims,
        budgets.to_vec(),
        seed_base,
        50,
    )?;
    let mut tiled = Network::from_spec(&spec)?;
    for (l, (dense_layer, tiled_layer)) in
        net.layers.iter().zip(tiled.layers.iter_mut()).enumerate()
    {
        let vb = dense_with_bias(dense_layer);
        tiled_layer.params =
            compress_dense_tiled(&vb, budgets[l], tile, l as u32, seed_base).into();
    }
    tiled.to_bundle(&spec)
}

/// A dense layer's `(n × (m+1))` weight matrix with the bias folded in
/// as the last column — the shape the hashed parameterization virtualizes.
/// Panics if the layer is not dense (callers validate first).
pub fn dense_with_bias(layer: &Layer) -> Matrix {
    assert_eq!(layer.kind, LayerKind::Dense, "dense_with_bias on {:?}", layer.kind);
    let (m, n) = (layer.m, layer.n);
    let w = layer.virtual_matrix(); // (n × m), no bias
    let bias = &layer.params[n * m..];
    let mut vb = Matrix::zeros(n, m + 1);
    for i in 0..n {
        vb.row_mut(i)[..m].copy_from_slice(w.row(i));
        vb.row_mut(i)[m] = bias[i];
    }
    vb
}

/// Per-layer relative reconstruction error of a hashed bundle (as
/// produced by [`compress_network`]) against the dense `net` it came
/// from — the diagnostic `hashednets compress` prints. Reuses the
/// bundle's bucket values instead of recompressing each layer.
pub fn reconstruction_report(net: &Network, hashed: &ModelBundle) -> Result<Vec<f64>, ModelError> {
    if hashed.params.len() != net.layers.len() {
        return Err(ModelError::InvalidSpec(format!(
            "{} hashed tensors for {} dense layers",
            hashed.params.len(),
            net.layers.len()
        )));
    }
    if let Some(l) = net.layers.iter().position(|lay| lay.kind != LayerKind::Dense) {
        return Err(ModelError::InvalidSpec(format!("layer {l} is not dense")));
    }
    let seed_base = hashed.spec.seed_base;
    if let Method::HashedTile { tile } = hashed.spec.method {
        return Ok(net
            .layers
            .iter()
            .zip(&hashed.params)
            .enumerate()
            .map(|(l, (layer, w))| {
                reconstruction_error_tiled_of(&dense_with_bias(layer), w, tile, l as u32, seed_base)
            })
            .collect());
    }
    Ok(net
        .layers
        .iter()
        .zip(&hashed.params)
        .enumerate()
        .map(|(l, (layer, w))| {
            reconstruction_error_of(&dense_with_bias(layer), w, l as u32, seed_base)
        })
        .collect())
}

/// Least-squares projection of a dense weight matrix onto the hashed
/// parameterization: each bucket takes the ξ-weighted mean of its
/// members (the minimizer of ‖V − V̂‖²_F under Eq. 7).
///
/// `dense` is `(n × (m+1))` (bias column included, like hashed layers).
pub fn compress_dense(dense: &Matrix, k: usize, layer_index: u32, seed_base: u32) -> Vec<f32> {
    let (n, m1) = (dense.rows, dense.cols);
    let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
    let mut sums = vec![0.0f64; k];
    let mut counts = vec![0u32; k];
    for i in 0..n {
        for j in 0..m1 {
            let (b, sg) = bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
            // V_ij = ξ w_b  ⇒  contribution to w_b is ξ V_ij
            sums[b as usize] += (sg * dense.at(i, j)) as f64;
            counts[b as usize] += 1;
        }
    }
    sums.iter()
        .zip(&counts)
        .map(|(&s, &c)| if c == 0 { 0.0 } else { (s / c as f64) as f32 })
        .collect()
}

/// [`compress_dense`]'s block-structured twin: the least-squares
/// projection onto the [`TilePlan`] parameterization. Each stored
/// weight takes the ξ-weighted mean over every virtual cell that maps
/// to it — cells of the tile offset it serves, across all (possibly
/// overlapping) runs that cover it — which minimizes `‖V − V̂‖²_F`
/// given the tile mapping, exactly as the per-cell version does for
/// Eq. 7's.
pub fn compress_dense_tiled(
    dense: &Matrix,
    k: usize,
    tile: (usize, usize),
    layer_index: u32,
    seed_base: u32,
) -> Vec<f32> {
    let (n, m1) = (dense.rows, dense.cols);
    let plan = TilePlan::build(n, m1, k, tile, layer_index, seed_base);
    let (th, tw) = tile;
    let mut sums = vec![0.0f64; k];
    let mut counts = vec![0u32; k];
    for i in 0..n {
        for j in 0..m1 {
            let e = plan.tile_entry(i / th, j / tw);
            let idx = TilePlan::base(e) + (i % th) * tw + (j % tw);
            let sg = if e & HashPlan::SIGN_BIT != 0 { -1.0 } else { 1.0 };
            sums[idx] += (sg * dense.at(i, j)) as f64;
            counts[idx] += 1;
        }
    }
    sums.iter()
        .zip(&counts)
        .map(|(&s, &c)| if c == 0 { 0.0 } else { (s / c as f64) as f32 })
        .collect()
}

/// Relative Frobenius reconstruction error of already-computed tiled
/// bucket values `w` against `dense` — the tiled counterpart of
/// [`reconstruction_error_of`].
pub fn reconstruction_error_tiled_of(
    dense: &Matrix,
    w: &[f32],
    tile: (usize, usize),
    layer_index: u32,
    seed_base: u32,
) -> f64 {
    let (n, m1) = (dense.rows, dense.cols);
    let plan = TilePlan::build(n, m1, w.len(), tile, layer_index, seed_base);
    let mut vrow = vec![0.0f32; m1];
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        plan.decompress_row_into(i, w, &mut vrow);
        for j in 0..m1 {
            let v = dense.at(i, j) as f64;
            let d = v - vrow[j] as f64;
            num += d * d;
            den += v * v;
        }
    }
    (num / den.max(1e-30)).sqrt()
}

/// Build a hashed layer whose virtual matrix approximates `dense`.
pub fn hashed_layer_from_dense(
    dense: &Matrix,
    k: usize,
    layer_index: usize,
    seed_base: u32,
) -> Layer {
    let (n, m1) = (dense.rows, dense.cols);
    let mut layer = Layer::new(m1 - 1, n, LayerKind::Hashed { k }, layer_index, seed_base);
    layer.params = compress_dense(dense, k, layer_index as u32, seed_base).into();
    layer
}

/// Relative Frobenius reconstruction error ‖V − V̂‖ / ‖V‖ of compressing
/// `dense` to `k` buckets (the redundancy measurement of Denil et al.
/// that motivates the paper).
pub fn reconstruction_error(dense: &Matrix, k: usize, layer_index: u32, seed_base: u32) -> f64 {
    let w = compress_dense(dense, k, layer_index, seed_base);
    reconstruction_error_of(dense, &w, layer_index, seed_base)
}

/// [`reconstruction_error`] against **already-computed** bucket values
/// `w` — so callers that just compressed a layer don't pay the
/// bucket-averaging pass a second time for the diagnostic.
pub fn reconstruction_error_of(dense: &Matrix, w: &[f32], layer_index: u32, seed_base: u32) -> f64 {
    let (n, m1) = (dense.rows, dense.cols);
    let k = w.len();
    let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        for j in 0..m1 {
            let (b, sg) = bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
            let v = dense.at(i, j) as f64;
            let vhat = (sg * w[b as usize]) as f64;
            num += (v - vhat) * (v - vhat);
            den += v * v;
        }
    }
    (num / den.max(1e-30)).sqrt()
}

/// Hashed inner product ⟨φ(x), φ(x')⟩ under one hash-pair seed — the
/// quantity Eq. 1 says is unbiased for ⟨x, x'⟩.
pub fn hashed_inner_product(x: &[f32], y: &[f32], k: usize, seed_h: u32, seed_xi: u32) -> f64 {
    let m = x.len() as u32;
    let mut phi_x = vec![0.0f64; k];
    let mut phi_y = vec![0.0f64; k];
    for j in 0..x.len() {
        let (b, sg) = bucket_sign(0, j as u32, m, k as u32, seed_h, seed_xi);
        phi_x[b as usize] += (sg * x[j]) as f64;
        phi_y[b as usize] += (sg * y[j]) as f64;
    }
    phi_x.iter().zip(&phi_y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn compression_is_exact_when_k_large_and_injective() {
        // with k >> n*m most buckets have one member: near-exact recon
        let mut rng = Pcg32::new(1, 1);
        let dense = Matrix::from_fn(6, 8, |_, _| rng.normal());
        let err = reconstruction_error(&dense, 4096, 0, crate::hash::DEFAULT_SEED_BASE);
        assert!(err < 0.35, "err {err}"); // birthday collisions only
    }

    #[test]
    fn reconstruction_error_decreases_with_k() {
        let mut rng = Pcg32::new(2, 1);
        let dense = Matrix::from_fn(20, 21, |_, _| rng.normal());
        let seed = crate::hash::DEFAULT_SEED_BASE;
        let e8 = reconstruction_error(&dense, 420 / 8, 0, seed);
        let e2 = reconstruction_error(&dense, 420 / 2, 0, seed);
        let e1 = reconstruction_error(&dense, 4200, 0, seed);
        assert!(e1 < e2 && e2 < e8, "{e1} {e2} {e8}");
    }

    #[test]
    fn compressed_layer_approximates_dense_forward() {
        let mut rng = Pcg32::new(3, 1);
        // low-complexity dense matrix (smooth) compresses well
        let dense = Matrix::from_fn(10, 13, |i, j| ((i as f32 * 0.3).sin() + (j as f32 * 0.2).cos()) * 0.3);
        let layer = hashed_layer_from_dense(&dense, 60, 0, crate::hash::DEFAULT_SEED_BASE);
        let a = Matrix::from_fn(4, 12, |_, _| rng.normal());
        let z_dense = a.augment_ones().matmul_nt(&dense);
        let z_hash = layer.forward(&a);
        let rel = {
            let mut num = 0.0f32;
            let mut den = 0.0f32;
            for (zd, zh) in z_dense.data.iter().zip(&z_hash.data) {
                num += (zd - zh) * (zd - zh);
                den += zd * zd;
            }
            (num / den).sqrt()
        };
        assert!(rel < 0.9, "relative error {rel}");
    }

    #[test]
    fn compress_network_one_call_roundtrip() {
        // dense → hashed in one call; the bundle reconstructs a network
        // whose layer params equal the per-layer bucket averages
        let mut rng = Pcg32::new(7, 1);
        let mut dense = Network::from_dims(
            &[10, 8, 4],
            vec![LayerKind::Dense, LayerKind::Dense],
            crate::hash::DEFAULT_SEED_BASE,
        );
        dense.init(&mut rng);
        let bundle = compress_network(&dense, &[30, 12], "toy_hashed").unwrap();
        assert_eq!(bundle.spec.method, Method::Hashnet);
        assert_eq!(bundle.spec.dims, vec![10, 8, 4]);
        assert_eq!(bundle.spec.stored_params(), 42);
        let net = Network::from_bundle(&bundle).unwrap();
        // layer 0 params match a direct compress_dense of W|b
        let l0 = &dense.layers[0];
        let w = l0.virtual_matrix();
        let mut vb = Matrix::zeros(8, 11);
        for i in 0..8 {
            vb.row_mut(i)[..10].copy_from_slice(w.row(i));
            vb.row_mut(i)[10] = l0.params[80 + i];
        }
        let want = compress_dense(&vb, 30, 0, crate::hash::DEFAULT_SEED_BASE);
        assert_eq!(net.layers[0].params, want);
    }

    #[test]
    fn compress_network_tiled_roundtrip_and_report() {
        let mut rng = Pcg32::new(9, 1);
        let mut dense = Network::from_dims(
            &[10, 8, 4],
            vec![LayerKind::Dense, LayerKind::Dense],
            crate::hash::DEFAULT_SEED_BASE,
        );
        dense.init(&mut rng);
        let tile = (1usize, 8usize);
        let bundle = compress_network_tiled(&dense, &[30, 12], tile, "toy_tiled").unwrap();
        assert_eq!(bundle.spec.method, Method::HashedTile { tile });
        assert_eq!(bundle.spec.stored_params(), 42);
        // round-trips through the bundle into a serving-ready network
        let net = Network::from_bundle(&bundle).unwrap();
        let l0 = &dense.layers[0];
        let want =
            compress_dense_tiled(&dense_with_bias(l0), 30, tile, 0, crate::hash::DEFAULT_SEED_BASE);
        assert_eq!(net.layers[0].params, want);
        // the tiled diagnostic runs and reports a sane relative error
        let report = reconstruction_report(&dense, &bundle).unwrap();
        assert_eq!(report.len(), 2);
        assert!(report.iter().all(|&e| e.is_finite() && e >= 0.0 && e < 2.0), "{report:?}");
        // tile area larger than a budget is rejected by spec validation
        assert!(compress_network_tiled(&dense, &[30, 12], (8, 8), "bad").is_err());
    }

    #[test]
    fn tiled_reconstruction_error_decreases_with_k() {
        let mut rng = Pcg32::new(12, 1);
        let dense = Matrix::from_fn(20, 21, |_, _| rng.normal());
        let seed = crate::hash::DEFAULT_SEED_BASE;
        let err = |k: usize| {
            let w = compress_dense_tiled(&dense, k, (1, 8), 0, seed);
            reconstruction_error_tiled_of(&dense, &w, (1, 8), 0, seed)
        };
        let e8 = err(420 / 8);
        let e1 = err(4200);
        assert!(e1 < e8, "{e1} vs {e8}");
    }

    #[test]
    fn compress_network_rejects_non_dense_and_bad_budgets() {
        let mut rng = Pcg32::new(8, 1);
        let mut hashed = Network::from_dims(
            &[6, 4, 2],
            vec![LayerKind::Hashed { k: 9 }, LayerKind::Hashed { k: 4 }],
            crate::hash::DEFAULT_SEED_BASE,
        );
        hashed.init(&mut rng);
        assert!(compress_network(&hashed, &[9, 4], "x").is_err());
        let mut dense = Network::from_dims(
            &[6, 4, 2],
            vec![LayerKind::Dense, LayerKind::Dense],
            crate::hash::DEFAULT_SEED_BASE,
        );
        dense.init(&mut rng);
        assert!(compress_network(&dense, &[9], "x").is_err());
    }

    #[test]
    fn inner_product_unbiased_over_seeds() {
        // Eq. 1: averaging over independent hash functions approaches x·y
        let mut rng = Pcg32::new(4, 1);
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let true_ip: f64 = x.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
        let trials = 800;
        let mean: f64 = (0..trials)
            .map(|t| hashed_inner_product(&x, &y, 16, 900 + t, 7700 + t))
            .sum::<f64>()
            / trials as f64;
        let norm = (x.iter().map(|v| (v * v) as f64).sum::<f64>()
            * y.iter().map(|v| (v * v) as f64).sum::<f64>())
        .sqrt();
        let tol = 4.0 * norm / (16.0f64 * trials as f64).sqrt();
        assert!((mean - true_ip).abs() < tol, "mean {mean} true {true_ip} tol {tol}");
    }
}
