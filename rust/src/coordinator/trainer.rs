//! The per-run training loop: drives one artifact's `train_step` over
//! shuffled minibatches, tracks validation error for model selection,
//! and evaluates on the test split.
//!
//! This is the paper's experimental protocol (§6): SGD + momentum +
//! dropout, minibatch 50, hyperparameters selected on a 20% validation
//! split, test error reported for the best validation epoch.

use crate::data::{Dataset, Kind, Split};
use crate::model::{ModelBundle, ModelError, ModelSpec};
use crate::nn::{Network, TrainHyper, TrainOptions};
use crate::runtime::{Graph, Hyper, ModelState, Runtime};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Everything needed to run one training job.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifact: String,
    pub dataset: Kind,
    pub n_train: usize,
    pub n_test: usize,
    pub epochs: usize,
    pub hyper: Hyper,
    pub seed: u64,
    /// Teacher artifact name for DK methods (trained on the fly by
    /// [`train_teacher`] and cached by the caller).
    pub teacher: Option<String>,
    /// Early-stop patience in epochs without val improvement (0 = off).
    pub patience: usize,
    /// Backward-pass execution policy (worker count + reduction order).
    /// Applies to the native engine; the PJRT artifact path parallelizes
    /// inside XLA and only records the configured value.
    pub train: TrainOptions,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: String::new(),
            dataset: Kind::Basic,
            n_train: 3000,
            n_test: 2000,
            epochs: 12,
            hyper: Hyper::default(),
            seed: 0x5EED,
            teacher: None,
            patience: 0,
            train: TrainOptions::default(),
        }
    }
}

/// Outcome of one training job.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub artifact: String,
    pub dataset: &'static str,
    pub test_error: f64,
    pub val_error: f64,
    pub train_losses: Vec<f32>,
    pub stored_params: usize,
    pub virtual_params: usize,
    pub wall_s: f64,
    pub steps_per_s: f64,
    pub state: ModelState,
    /// The model identity trained — with [`TrainResult::bundle`] this
    /// makes every training run's output a self-describing artifact.
    pub spec: ModelSpec,
    /// Resolved backward worker count this run was configured with
    /// (recorded into the repro JSONL; the PJRT path parallelizes
    /// inside XLA and reports the configured native value).
    pub threads: usize,
}

impl TrainResult {
    /// Package the trained parameters as a [`ModelBundle`] — the one
    /// thing `--save` writes and `serve` loads.
    pub fn bundle(&self) -> Result<ModelBundle, ModelError> {
        ModelBundle::new(self.spec.clone(), self.state.params.clone())
    }
}

/// Temperature-softened teacher probabilities for the train split.
pub struct SoftTargets {
    pub probs: Matrix,
    pub temp: f32,
}

/// Compute soft targets from a trained teacher on given inputs.
pub fn soft_targets(
    rt: &Runtime,
    teacher: &str,
    teacher_state: &ModelState,
    x: &Matrix,
    temp: f32,
) -> Result<SoftTargets> {
    let exe = rt.load(teacher, Graph::Predict)?;
    let logits = exe.predict_all(teacher_state, x)?;
    let mut scaled = logits;
    scaled.scale(1.0 / temp);
    Ok(SoftTargets { probs: scaled.softmax_rows(), temp })
}

/// Train the `nn` compression-1 teacher for a dataset (used by DK).
/// `opts` is the training execution policy, threaded through so
/// teacher runs follow the same `--threads` configuration as the
/// student runs they feed.
pub fn train_teacher(
    rt: &Runtime,
    teacher: &str,
    train: &Dataset,
    epochs: usize,
    seed: u64,
    opts: &TrainOptions,
) -> Result<ModelState> {
    let cfg = TrainConfig {
        artifact: teacher.to_string(),
        dataset: train.kind,
        epochs,
        seed,
        hyper: Hyper { keep_prob: 0.9, ..Hyper::default() },
        train: *opts,
        ..Default::default()
    };
    let res = run_with_data(rt, &cfg, train, None, None)?;
    Ok(res.state)
}

/// Evaluate classification error of an artifact state on a dataset.
pub fn evaluate(
    rt: &Runtime,
    artifact: &str,
    state: &ModelState,
    ds: &Dataset,
) -> Result<f64> {
    let exe = rt.load(artifact, Graph::Predict)?;
    let logits = exe.predict_all(state, &ds.images)?;
    let pred = logits.argmax_rows();
    let wrong = pred
        .iter()
        .zip(&ds.labels)
        .filter(|(p, l)| **p != **l as usize)
        .count();
    Ok(wrong as f64 / ds.labels.len() as f64)
}

/// Full job: synthesize data, train, select on validation, test.
pub fn run(rt: &Runtime, cfg: &TrainConfig, soft: Option<&SoftTargets>) -> Result<TrainResult> {
    let train = crate::data::generate(cfg.dataset, Split::Train, cfg.n_train, cfg.seed);
    let test = crate::data::generate(cfg.dataset, Split::Test, cfg.n_test, cfg.seed);
    run_with_data(rt, cfg, &train, Some(&test), soft)
}

/// Training loop over caller-provided data (test split optional).
pub fn run_with_data(
    rt: &Runtime,
    cfg: &TrainConfig,
    train_full: &Dataset,
    test: Option<&Dataset>,
    soft: Option<&SoftTargets>,
) -> Result<TrainResult> {
    let spec = rt
        .manifest
        .get(&cfg.artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{}'", cfg.artifact))?
        .clone();
    let out_dim = *spec.dims.last().unwrap();
    if train_full.n_classes > out_dim {
        return Err(anyhow!(
            "dataset {} has {} classes but artifact {} outputs {}",
            train_full.kind.name(), train_full.n_classes, spec.name, out_dim
        ));
    }
    if spec.uses_soft_targets && soft.is_none() {
        return Err(anyhow!("artifact {} needs soft targets", spec.name));
    }

    let (train, val) = train_full.split_validation(0.2);
    let exe = rt.load(&cfg.artifact, Graph::Train)?;
    let mut state = spec.init_state(cfg.seed);
    let mut rng = Pcg32::new(cfg.seed, 0xB0B);

    let t0 = Instant::now();
    let mut losses = Vec::with_capacity(cfg.epochs);
    let mut best: Option<(f64, ModelState)> = None;
    let mut stale = 0usize;
    let mut steps = 0u64;
    let batch = spec.batch;
    // reused minibatch buffers — the step loop is allocation-free
    let mut x = Matrix::zeros(batch, train.images.cols);
    let mut y = vec![0i32; batch];
    let mut soft_buf = soft.map(|_| Matrix::zeros(batch, out_dim));
    for epoch in 0..cfg.epochs {
        let perm = rng.permutation(train.len());
        let mut total = 0.0f32;
        let mut count = 0u32;
        for chunk in perm.chunks(batch) {
            train.gather_batch_into(chunk, &mut x, &mut y);
            let soft_batch = soft.map(|s| {
                let m = soft_buf.as_mut().unwrap();
                for (b, &i) in chunk.iter().cycle().take(batch).enumerate() {
                    m.row_mut(b).copy_from_slice(s.probs.row(i as usize));
                }
                &*m
            });
            let step_seed = (cfg.seed as u32)
                .wrapping_mul(2654435761)
                .wrapping_add(steps as u32);
            let loss = exe.train_step(&mut state, &x, &y, soft_batch, &cfg.hyper, step_seed)?;
            total += loss;
            count += 1;
            steps += 1;
        }
        losses.push(total / count as f32);
        // validation-based model selection
        let v_err = evaluate(rt, &cfg.artifact, &state, &val)?;
        let improved = best.as_ref().map(|(b, _)| v_err < *b).unwrap_or(true);
        if improved {
            best = Some((v_err, state.clone()));
            stale = 0;
        } else {
            stale += 1;
            if cfg.patience > 0 && stale >= cfg.patience && epoch + 1 < cfg.epochs {
                break;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (val_error, best_state) = best.unwrap_or((1.0, state));
    let test_error = match test {
        Some(t) => evaluate(rt, &cfg.artifact, &best_state, t)?,
        None => val_error,
    };
    Ok(TrainResult {
        artifact: cfg.artifact.clone(),
        dataset: train_full.kind.name(),
        test_error,
        val_error,
        train_losses: losses,
        stored_params: spec.stored_params,
        virtual_params: spec.virtual_params,
        wall_s: wall,
        steps_per_s: steps as f64 / wall.max(1e-9),
        state: best_state,
        spec: spec.to_model_spec(),
        threads: cfg.train.resolved_threads(),
    })
}

/// Train a [`ModelSpec`] with the **native** engine — no manifest, no
/// PJRT, no HLO artifacts: the spec alone names the model, which is the
/// point of the model subsystem. Same protocol as [`run_with_data`]
/// (80/20 validation split, best-validation-epoch selection, optional
/// early-stop patience); `cfg.artifact` is ignored in favor of
/// `spec.name`. Dark-knowledge methods need the artifact path (the
/// teacher pipeline), so they are rejected here.
pub fn run_native(spec: &ModelSpec, cfg: &TrainConfig) -> Result<TrainResult> {
    spec.validate()?;
    if cfg.epochs == 0 {
        return Err(anyhow!("need at least one epoch"));
    }
    if spec.method.uses_soft_targets() {
        return Err(anyhow!(
            "method '{}' needs teacher soft targets — train it through the artifact path",
            spec.method
        ));
    }
    let train_full = crate::data::generate(cfg.dataset, Split::Train, cfg.n_train, cfg.seed);
    let test = crate::data::generate(cfg.dataset, Split::Test, cfg.n_test, cfg.seed);
    if train_full.n_classes > spec.n_out() {
        return Err(anyhow!(
            "dataset {} has {} classes but spec '{}' outputs {}",
            train_full.kind.name(),
            train_full.n_classes,
            spec.name,
            spec.n_out()
        ));
    }
    if spec.n_in() != train_full.images.cols {
        return Err(anyhow!(
            "dataset {} has {} features but spec '{}' takes {}",
            train_full.kind.name(),
            train_full.images.cols,
            spec.name,
            spec.n_in()
        ));
    }
    let (train, val) = train_full.split_validation(0.2);

    let mut net = Network::from_spec(spec)?;
    let mut rng = Pcg32::new(cfg.seed, 0xB0B);
    net.init(&mut rng);
    let hyper = TrainHyper {
        lr: cfg.hyper.lr,
        momentum: cfg.hyper.momentum,
        keep_prob: cfg.hyper.keep_prob,
        lam: 1.0,
        temp: cfg.hyper.temp,
    };

    let t0 = Instant::now();
    let mut losses = Vec::with_capacity(cfg.epochs);
    let mut best: Option<(f64, Vec<Vec<f32>>)> = None;
    let mut stale = 0usize;
    let steps_per_epoch = train.len().div_ceil(spec.batch.max(1)) as u64;
    let mut steps = 0u64;
    for epoch in 0..cfg.epochs {
        let epoch_loss = net.fit(
            &train.images,
            &train.labels,
            spec.batch.max(1),
            1,
            &hyper,
            &cfg.train,
            None,
            &mut rng,
        );
        losses.extend(epoch_loss);
        steps += steps_per_epoch;
        let v_err = net.error_rate(&val.images, &val.labels);
        let improved = best.as_ref().map(|(b, _)| v_err < *b).unwrap_or(true);
        if improved {
            best = Some((v_err, net.layers.iter().map(|l| l.params.clone()).collect()));
            stale = 0;
        } else {
            stale += 1;
            if cfg.patience > 0 && stale >= cfg.patience && epoch + 1 < cfg.epochs {
                break;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (val_error, best_params) = best.expect("at least one epoch");
    for (layer, p) in net.layers.iter_mut().zip(best_params) {
        layer.params = p;
    }
    let test_error = net.error_rate(&test.images, &test.labels);

    let bundle = net.to_bundle(spec)?;
    Ok(TrainResult {
        artifact: spec.name.clone(),
        dataset: train_full.kind.name(),
        test_error,
        val_error,
        train_losses: losses,
        stored_params: spec.stored_params(),
        virtual_params: spec.virtual_params(),
        wall_s: wall,
        steps_per_s: steps as f64 / wall.max(1e-9),
        state: ModelState::from_bundle(&bundle),
        spec: spec.clone(),
        threads: cfg.train.resolved_threads(),
    })
}
