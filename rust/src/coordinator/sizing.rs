//! Grid sizing — the Rust twin of `python/compile/sizing.py` plus the
//! grid-cell → [`ModelSpec`] resolution of `aot.py`'s `spec_for` /
//! `expansion_spec_for`.
//!
//! All methods of the paper's evaluation (§6, Baselines) are compared
//! at an identical number of *stored* parameters; this module computes
//! those budgets. It exists so the repro grids ([`super::repro`]) can
//! run on the **native** engine when no HLO artifacts have been
//! lowered: the spec a grid cell would have been lowered with is
//! re-derived here, bit-identically to what `aot.py` writes into
//! `manifest.json` (same float arithmetic, same Python `round`
//! semantics — cross-checked against the Python module by the tests
//! below).

use crate::hash::DEFAULT_SEED_BASE;
use crate::model::{Method, ModelError, ModelSpec};

/// Input width of every dataset in the evaluation (28×28 images).
pub const N_IN: usize = 784;

/// The paper's minibatch — grid specs are synthesized with it.
pub const GRID_BATCH: usize = 50;

/// Python's `round`: round-half-to-even ("banker's rounding"). Budgets
/// land exactly on .5 at several paper compressions (e.g.
/// `785·100/8 = 9812.5`), so matching this exactly is what keeps the
/// native grid specs identical to the lowered artifacts.
fn py_round(x: f64) -> i64 {
    let floor = x.floor();
    let diff = x - floor;
    let f = floor as i64;
    if diff > 0.5 {
        f + 1
    } else if diff < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

/// Paper nomenclature: a "3-layer" net has 1 hidden layer, "5-layer"
/// has 3 (`depth - 2` in general).
pub fn layer_dims(depth: usize, n_in: usize, hidden: usize, n_out: usize) -> Vec<usize> {
    let n_hidden = depth.saturating_sub(2);
    let mut dims = Vec::with_capacity(n_hidden + 2);
    dims.push(n_in);
    for _ in 0..n_hidden {
        dims.push(hidden);
    }
    dims.push(n_out);
    dims
}

/// Stored parameters of a fully-connected net (weights + biases).
pub fn dense_params(dims: &[usize]) -> usize {
    (0..dims.len() - 1).map(|l| (dims[l] + 1) * dims[l + 1]).sum()
}

/// Per-layer HashedNet budget `K^ℓ = max(1, round(c·(n^ℓ+1)·n^{ℓ+1}))`
/// under compression factor `c` (the bias column is hashed with the
/// weights, §4.1). Arithmetic mirrors the Python expression
/// `round(c * (dims[l] + 1) * dims[l + 1])` term for term.
pub fn hashed_budgets(dims: &[usize], c: f64) -> Vec<usize> {
    (0..dims.len() - 1)
        .map(|l| py_round(c * ((dims[l] + 1) as f64) * (dims[l + 1] as f64)).max(1) as usize)
        .collect()
}

/// Largest uniform hidden width whose dense net stores ≤ `budget`
/// parameters — the paper's "Neural Network (Equivalent-Size)"
/// baseline: hidden layers shrunk at the same rate until the stored
/// parameter count matches the target. Closed-form seed, then a scan.
pub fn equivalent_hidden_width(dims: &[usize], budget: usize) -> usize {
    let (n_in, n_out) = (dims[0], dims[dims.len() - 1]);
    let n_hidden = dims.len() - 2;
    assert!(n_hidden >= 1, "need at least one hidden layer");
    let count = |h: usize| dense_params(&layer_dims(n_hidden + 2, n_in, h, n_out));
    // closed-form seed: a·h² + b·h + c0 = budget
    let a = n_hidden.saturating_sub(1) as f64;
    let b = ((n_in + 1) + (n_hidden - 1) + n_out) as f64;
    let c0 = n_out as f64;
    let budget_f = budget as f64;
    let h_seed = if a == 0.0 {
        (budget_f - c0) / b
    } else {
        let disc = b * b - 4.0 * a * (c0 - budget_f);
        (-b + disc.max(0.0).sqrt()) / (2.0 * a)
    };
    let mut h = (h_seed as i64).max(1) as usize;
    while count(h + 1) <= budget {
        h += 1;
    }
    while h > 1 && count(h) > budget {
        h -= 1;
    }
    h
}

/// Fig. 4 setup: storage fixed to a `base_hidden`-unit dense net, the
/// virtual architecture inflated by `factor`. Returns
/// `(virtual dims, per-layer K^ℓ)` where `K^ℓ` is the dense parameter
/// count of layer ℓ at base width.
pub fn expansion_dims(
    depth: usize,
    n_in: usize,
    base_hidden: usize,
    n_out: usize,
    factor: usize,
) -> (Vec<usize>, Vec<usize>) {
    let base = layer_dims(depth, n_in, base_hidden, n_out);
    let ks = (0..base.len() - 1).map(|l| (base[l] + 1) * base[l + 1]).collect();
    let virt = layer_dims(depth, n_in, base_hidden * factor, n_out);
    (virt, ks)
}

/// Resolve a compression-grid cell (Figs. 2–3, Tables 1–2) to the
/// [`ModelSpec`] its artifact would have been lowered with — the Rust
/// twin of `aot.spec_for`. `name` is the artifact name (the spec/bundle
/// registry key), e.g. `hashnet_3l_h100_o10_c1-8`.
pub fn grid_spec(
    name: &str,
    method: Method,
    depth: usize,
    hidden: usize,
    out: usize,
    c: f64,
) -> Result<ModelSpec, ModelError> {
    let full = layer_dims(depth, N_IN, hidden, out);
    let budgets = hashed_budgets(&full, c);
    match method {
        Method::Nn | Method::Dk => {
            // equivalent-size dense baseline: shrink hidden width to budget
            let h_eq = if c == 1.0 {
                hidden
            } else {
                equivalent_hidden_width(&full, budgets.iter().sum())
            };
            let dims = layer_dims(depth, N_IN, h_eq, out);
            let budgets_used =
                (0..dims.len() - 1).map(|l| (dims[l] + 1) * dims[l + 1]).collect();
            ModelSpec::new(name, method, dims, budgets_used, DEFAULT_SEED_BASE, GRID_BATCH)
        }
        _ => ModelSpec::new(name, method, full, budgets, DEFAULT_SEED_BASE, GRID_BATCH),
    }
}

/// Resolve a Fig. 4 expansion cell to its [`ModelSpec`] — the Rust twin
/// of `aot.expansion_spec_for` (`name` ≈ `hashnet_3l_b50_o10_x4`).
pub fn expansion_grid_spec(
    name: &str,
    method: Method,
    depth: usize,
    base_hidden: usize,
    out: usize,
    factor: usize,
) -> Result<ModelSpec, ModelError> {
    let (virt, ks) = expansion_dims(depth, N_IN, base_hidden, out, factor);
    match method {
        Method::Nn | Method::Dk => {
            // the fixed-size dense reference (dashed line in Fig. 4)
            let dims = layer_dims(depth, N_IN, base_hidden, out);
            let budgets = (0..dims.len() - 1).map(|l| (dims[l] + 1) * dims[l + 1]).collect();
            ModelSpec::new(name, method, dims, budgets, DEFAULT_SEED_BASE, GRID_BATCH)
        }
        _ => ModelSpec::new(name, method, virt, ks, DEFAULT_SEED_BASE, GRID_BATCH),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn py_round_is_half_to_even() {
        // golden cases cross-checked against Python's round()
        assert_eq!(py_round(9812.5), 9812);
        assert_eq!(py_round(82.5), 82);
        assert_eq!(py_round(126.25), 126);
        assert_eq!(py_round(2.5), 2);
        assert_eq!(py_round(3.5), 4);
        assert_eq!(py_round(31.5625), 32);
        assert_eq!(py_round(7.0), 7);
        assert_eq!(py_round(7.4), 7);
        assert_eq!(py_round(7.6), 8);
    }

    #[test]
    fn layer_dims_match_paper_nomenclature() {
        assert_eq!(layer_dims(3, 784, 100, 10), vec![784, 100, 10]);
        assert_eq!(layer_dims(5, 784, 100, 10), vec![784, 100, 100, 100, 10]);
        assert_eq!(dense_params(&[784, 100, 10]), 78500 + 1010);
    }

    #[test]
    fn budgets_match_python_sizing_golden() {
        // printed by python/compile/sizing.py for the repro grid widths
        let d3 = layer_dims(3, 784, 100, 10);
        let d5 = layer_dims(5, 784, 100, 10);
        assert_eq!(hashed_budgets(&d3, 1.0), vec![78500, 1010]);
        assert_eq!(hashed_budgets(&d3, 0.125), vec![9812, 126]); // 9812.5 → even
        assert_eq!(hashed_budgets(&d3, 1.0 / 64.0), vec![1227, 16]);
        assert_eq!(hashed_budgets(&d5, 0.125), vec![9812, 1262, 1262, 126]);
        assert_eq!(hashed_budgets(&d5, 1.0 / 32.0), vec![2453, 316, 316, 32]);
        let d2 = layer_dims(3, 784, 100, 2);
        assert_eq!(hashed_budgets(&d2, 0.125), vec![9812, 25]);
    }

    #[test]
    fn equivalent_width_matches_python_and_bounds_budget() {
        let d3 = layer_dims(3, 784, 100, 10);
        let d5 = layer_dims(5, 784, 100, 10);
        assert_eq!(equivalent_hidden_width(&d3, 9812 + 126), 12);
        assert_eq!(equivalent_hidden_width(&d5, 9812 + 1262 + 1262 + 126), 15);
        assert_eq!(equivalent_hidden_width(&d3, 1227 + 16), 1);
        // the invariant behind the baseline: count(h) ≤ budget < count(h+1)
        for budget in [500usize, 5_000, 20_000, 79_510] {
            let h = equivalent_hidden_width(&d3, budget);
            let count = |h: usize| dense_params(&layer_dims(3, 784, h, 10));
            assert!(count(h) <= budget || h == 1, "h={h} budget={budget}");
            assert!(count(h + 1) > budget, "h={h} budget={budget}");
        }
    }

    #[test]
    fn expansion_dims_match_python_golden() {
        assert_eq!(
            expansion_dims(3, 784, 50, 10, 4),
            (vec![784, 200, 10], vec![39250, 510])
        );
        assert_eq!(
            expansion_dims(5, 784, 50, 10, 8),
            (vec![784, 400, 400, 400, 10], vec![39250, 2550, 2550, 510])
        );
    }

    #[test]
    fn grid_specs_validate_for_every_method() {
        for method in Method::ALL {
            for depth in [3usize, 5] {
                for c in [1.0, 0.125, 1.0 / 64.0] {
                    let spec = grid_spec("cell", method, depth, 100, 10, c).unwrap();
                    spec.validate().unwrap();
                    assert_eq!(spec.n_in(), 784);
                    assert_eq!(spec.n_out(), 10);
                    assert_eq!(spec.batch, GRID_BATCH);
                }
            }
        }
    }

    #[test]
    fn hashnet_grid_spec_matches_manifest_convention() {
        // the mnist 1/8 cell of the ModelSpec doc example
        let spec = grid_spec("hashnet_3l_h100_o10_c1-8", Method::Hashnet, 3, 100, 10, 0.125)
            .unwrap();
        assert_eq!(spec.dims, vec![784, 100, 10]);
        assert_eq!(spec.budgets, vec![9812, 126]);
        assert_eq!(spec.stored_params(), 9938);
        assert!((spec.compression() - 0.125).abs() < 1e-3);
        // the equivalent-size dense baseline shrinks its hidden width
        let nn = grid_spec("nn_3l_h100_o10_c1-8", Method::Nn, 3, 100, 10, 0.125).unwrap();
        assert_eq!(nn.dims, vec![784, 12, 10]);
        assert!(nn.stored_params() <= 9938);
        // at compression 1 the dense baseline keeps the full width
        let teacher = grid_spec("nn_3l_h100_o10_c1-1", Method::Nn, 3, 100, 10, 1.0).unwrap();
        assert_eq!(teacher.dims, vec![784, 100, 10]);
    }

    #[test]
    fn expansion_specs_fix_storage_and_inflate_virtual_dims() {
        let h = expansion_grid_spec("hashnet_3l_b50_o10_x4", Method::Hashnet, 3, 50, 10, 4)
            .unwrap();
        assert_eq!(h.dims, vec![784, 200, 10]);
        assert_eq!(h.budgets, vec![39250, 510]);
        let h1 = expansion_grid_spec("hashnet_3l_b50_o10_x1", Method::Hashnet, 3, 50, 10, 1)
            .unwrap();
        // same storage at every factor — Fig. 4's premise
        assert_eq!(h.stored_params(), h1.stored_params());
        let nn = expansion_grid_spec("nn_3l_b50_o10_x1", Method::Nn, 3, 50, 10, 1).unwrap();
        assert_eq!(nn.dims, vec![784, 50, 10]);
    }
}
