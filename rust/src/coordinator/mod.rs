//! Layer-3 coordinator: the experiment engine.
//!
//! * [`trainer`] — one training job: minibatch loop over the artifact's
//!   in-graph `train_step`, validation-based model selection, test eval.
//! * [`repro`] — the paper's full experiment grid (Figures 2–4, Tables
//!   1–2) on a worker pool, with JSONL + markdown/CSV emission.
//! * [`hpo`] — random-search + successive-halving hyperparameter tuning
//!   (substitute for the paper's Bayesian optimization).
//! * [`metrics`] — JSONL records and paper-shaped pivot tables.
//! * [`native`] — artifact ↔ native-engine parameter bridging for
//!   cross-validation.
//! * [`sizing`] — the §6 size-equivalence solvers (Rust twin of
//!   `python/compile/sizing.py`), which let [`repro`] synthesize grid
//!   specs and fall back to the native engine when `artifacts/` is
//!   absent.

pub mod hpo;
pub mod metrics;
pub mod native;
pub mod repro;
pub mod sizing;
pub mod trainer;
