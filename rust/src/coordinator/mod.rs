//! Layer-3 coordinator: the experiment engine.
//!
//! * [`trainer`] — one training job: minibatch loop over the artifact's
//!   in-graph `train_step`, validation-based model selection, test eval.
//! * [`repro`] — the paper's full experiment grid (Figures 2–4, Tables
//!   1–2) on a worker pool, with JSONL + markdown/CSV emission.
//! * [`hpo`] — random-search + successive-halving hyperparameter tuning
//!   (substitute for the paper's Bayesian optimization).
//! * [`metrics`] — JSONL records and paper-shaped pivot tables.
//! * [`native`] — artifact ↔ native-engine parameter bridging for
//!   cross-validation.

pub mod hpo;
pub mod metrics;
pub mod native;
pub mod repro;
pub mod trainer;
