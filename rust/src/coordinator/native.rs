//! Bridge between artifact specs and the native engine: build a
//! [`Network`] that computes *exactly* what an artifact computes, from
//! the same [`ModelState`] parameters.
//!
//! Because `crate::hash` is bit-identical to the Python hashing, the
//! native HashedNet and the Pallas kernel inside the artifact
//! decompress the same virtual matrices; integration tests assert the
//! logits agree to float tolerance.

use crate::nn::{Layer, LayerKind, Network};
use crate::runtime::{ArtifactSpec, ModelState};

/// Instantiate the native twin of an artifact.
pub fn network_from_spec(spec: &ArtifactSpec) -> Network {
    let dims = &spec.dims;
    let n_layers = dims.len() - 1;
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let (m, n) = (dims[l], dims[l + 1]);
        let kind = match spec.method.as_str() {
            "hashnet" | "hashnet_dk" => LayerKind::Hashed { k: spec.budgets[l] },
            "nn" | "dk" => LayerKind::Dense,
            "rer" => LayerKind::Masked { k: spec.budgets[l] },
            "lrd" => {
                let r = (spec.budgets[l] as f64 / n as f64).round().max(1.0) as usize;
                LayerKind::LowRank { r }
            }
            other => panic!("unknown method '{other}'"),
        };
        layers.push(Layer::new(m, n, kind, l, spec.seed_base));
    }
    Network::new(layers)
}

/// Fallible [`network_from_spec`] + [`load_params`]: validates that the
/// state's tensor lengths match the spec's layer layout before copying,
/// so a wrong checkpoint is a clean error instead of a slice panic.
/// This is how `serve::engine::NativeEngine` builds its model.
pub fn try_build(spec: &ArtifactSpec, state: &ModelState) -> anyhow::Result<Network> {
    let mut net = network_from_spec(spec);
    let mut expect: Vec<usize> = Vec::new();
    for layer in &net.layers {
        match layer.kind {
            LayerKind::Dense => {
                expect.push(layer.n * layer.m);
                expect.push(layer.n);
            }
            _ => expect.push(layer.params.len()),
        }
    }
    let got: Vec<usize> = state.params.iter().map(Vec::len).collect();
    if got != expect {
        return Err(anyhow::anyhow!(
            "state does not match artifact '{}': tensor lengths {:?}, expected {:?}",
            spec.name,
            got,
            expect
        ));
    }
    load_params(&mut net, spec, state);
    Ok(net)
}

/// Copy artifact parameters into the native network.
///
/// Layouts match by construction (manifest order is layer order, and
/// dense layers store `[W, b]` as two manifest params that concatenate
/// into the native layer's single buffer).
pub fn load_params(net: &mut Network, _spec: &ArtifactSpec, state: &ModelState) {
    let mut it = state.params.iter();
    for layer in &mut net.layers {
        match layer.kind {
            LayerKind::Dense => {
                let w = it.next().expect("missing W");
                let b = it.next().expect("missing b");
                layer.params[..w.len()].copy_from_slice(w);
                layer.params[w.len()..].copy_from_slice(b);
            }
            _ => {
                let p = it.next().expect("missing param");
                layer.params.copy_from_slice(p);
            }
        }
    }
    assert!(it.next().is_none(), "leftover artifact params");
}

/// Extract native network parameters back into artifact layout.
pub fn store_params(net: &Network, spec: &ArtifactSpec, state: &mut ModelState) {
    let mut idx = 0;
    for layer in &net.layers {
        match layer.kind {
            LayerKind::Dense => {
                let nm = layer.n * layer.m;
                state.params[idx].copy_from_slice(&layer.params[..nm]);
                state.params[idx + 1].copy_from_slice(&layer.params[nm..]);
                idx += 2;
            }
            _ => {
                state.params[idx].copy_from_slice(&layer.params);
                idx += 1;
            }
        }
    }
    assert_eq!(idx, spec.params.len(), "param count mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "n_in": 8,
          "artifacts": [
            {"name":"h","method":"hashnet","dims":[8,6,3],"budgets":[27,11],
             "batch":2,"seed_base":2654435769,"uses_soft_targets":false,
             "compression":0.5,"virtual_params":75,"stored_params":38,
             "params":[{"name":"w0","shape":[27],"init_std":0.47},
                        {"name":"w1","shape":[11],"init_std":0.53}],
             "graphs":{"train":"x","predict":"y"}},
            {"name":"d","method":"nn","dims":[8,6,3],"budgets":[54,21],
             "batch":2,"seed_base":2654435769,"uses_soft_targets":false,
             "compression":1.0,"virtual_params":75,"stored_params":75,
             "params":[{"name":"W0","shape":[6,8],"init_std":0.5},
                        {"name":"b0","shape":[6],"init_std":0.0},
                        {"name":"W1","shape":[3,6],"init_std":0.57},
                        {"name":"b1","shape":[3],"init_std":0.0}],
             "graphs":{"train":"x","predict":"y"}}
          ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_hashed_params() {
        let m = toy_manifest();
        let spec = m.get("h").unwrap();
        let state = ModelState::init(spec, 5);
        let mut net = network_from_spec(spec);
        load_params(&mut net, spec, &state);
        assert_eq!(net.layers[0].params, state.params[0]);
        let mut state2 = ModelState::init(spec, 99);
        store_params(&net, spec, &mut state2);
        assert_eq!(state2.params, state.params);
    }

    #[test]
    fn roundtrip_dense_params_concat() {
        let m = toy_manifest();
        let spec = m.get("d").unwrap();
        let state = ModelState::init(spec, 5);
        let mut net = network_from_spec(spec);
        load_params(&mut net, spec, &state);
        assert_eq!(&net.layers[0].params[..48], state.params[0].as_slice());
        assert_eq!(&net.layers[0].params[48..], state.params[1].as_slice());
        let mut state2 = ModelState::init(spec, 99);
        store_params(&net, spec, &mut state2);
        assert_eq!(state2.params, state.params);
    }

    #[test]
    fn stored_params_match_manifest() {
        let m = toy_manifest();
        for name in ["h", "d"] {
            let spec = m.get(name).unwrap();
            let net = network_from_spec(spec);
            assert_eq!(
                net.stored_params(),
                spec.params.iter().map(|p| p.count()).sum::<usize>()
            );
        }
    }
}
