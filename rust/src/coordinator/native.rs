//! Bridge between artifact state and the native engine: build a
//! [`Network`] that computes *exactly* what an artifact computes, from
//! the same [`ModelState`] parameters.
//!
//! Since the model subsystem landed, this is one function deep: the
//! artifact's identity converts to a [`crate::model::ModelSpec`], the
//! state's tensors to a [`crate::model::ModelBundle`], and
//! [`Network::from_bundle`] does the rest — the method match that used
//! to live (and `panic!`) here is now the typed
//! [`crate::model::Method`] enum, so an unknown method fails at
//! manifest parse time and a mismatched checkpoint fails here with a
//! clean shape error.
//!
//! Because `crate::hash` is bit-identical to the Python hashing, the
//! native HashedNet and the Pallas kernel inside the artifact
//! decompress the same virtual matrices; integration tests assert the
//! logits agree to float tolerance.

use crate::nn::Network;
use crate::runtime::{ArtifactSpec, ModelState};

/// Instantiate the native twin of an artifact on `state`'s parameters.
/// Validates the state against the spec's layer layout before copying,
/// so a wrong checkpoint is a clean error instead of a slice panic.
pub fn try_build(spec: &ArtifactSpec, state: &ModelState) -> anyhow::Result<Network> {
    let bundle = state.to_bundle(spec)?;
    Ok(Network::from_bundle(&bundle)?)
}

/// Extract native network parameters back into artifact layout — the
/// inverse of [`try_build`] (used after native fine-tuning to hand
/// parameters back to the PJRT runtime).
pub fn store_params(net: &Network, spec: &ArtifactSpec, state: &mut ModelState) -> anyhow::Result<()> {
    let bundle = net.to_bundle(&spec.to_model_spec())?;
    let expect: Vec<usize> = state.params.iter().map(Vec::len).collect();
    let got: Vec<usize> = bundle.params.iter().map(Vec::len).collect();
    if got != expect {
        return Err(anyhow::anyhow!(
            "state for '{}' has tensor lengths {:?}, network produced {:?}",
            spec.name,
            expect,
            got
        ));
    }
    for (dst, src) in state.params.iter_mut().zip(bundle.params) {
        dst.copy_from_slice(&src);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "n_in": 8,
          "artifacts": [
            {"name":"h","method":"hashnet","dims":[8,6,3],"budgets":[27,11],
             "batch":2,"seed_base":2654435769,"uses_soft_targets":false,
             "compression":0.5,"virtual_params":75,"stored_params":38,
             "params":[{"name":"w0","shape":[27],"init_std":0.47},
                        {"name":"w1","shape":[11],"init_std":0.53}],
             "graphs":{"train":"x","predict":"y"}},
            {"name":"d","method":"nn","dims":[8,6,3],"budgets":[54,21],
             "batch":2,"seed_base":2654435769,"uses_soft_targets":false,
             "compression":1.0,"virtual_params":75,"stored_params":75,
             "params":[{"name":"W0","shape":[6,8],"init_std":0.5},
                        {"name":"b0","shape":[6],"init_std":0.0},
                        {"name":"W1","shape":[3,6],"init_std":0.57},
                        {"name":"b1","shape":[3],"init_std":0.0}],
             "graphs":{"train":"x","predict":"y"}}
          ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_hashed_params() {
        let m = toy_manifest();
        let spec = m.get("h").unwrap();
        let state = spec.init_state(5);
        let net = try_build(spec, &state).unwrap();
        assert_eq!(net.layers[0].params, state.params[0]);
        let mut state2 = spec.init_state(99);
        store_params(&net, spec, &mut state2).unwrap();
        assert_eq!(state2.params, state.params);
    }

    #[test]
    fn roundtrip_dense_params_concat() {
        let m = toy_manifest();
        let spec = m.get("d").unwrap();
        let state = spec.init_state(5);
        let net = try_build(spec, &state).unwrap();
        assert_eq!(&net.layers[0].params[..48], state.params[0].as_slice());
        assert_eq!(&net.layers[0].params[48..], state.params[1].as_slice());
        let mut state2 = spec.init_state(99);
        store_params(&net, spec, &mut state2).unwrap();
        assert_eq!(state2.params, state.params);
    }

    #[test]
    fn stored_params_match_manifest() {
        let m = toy_manifest();
        for name in ["h", "d"] {
            let spec = m.get(name).unwrap();
            let net = try_build(spec, &spec.init_state(1)).unwrap();
            assert_eq!(
                net.stored_params(),
                spec.params.iter().map(|p| p.count()).sum::<usize>()
            );
        }
    }

    #[test]
    fn mismatched_state_is_a_clean_error() {
        let m = toy_manifest();
        let hstate = m.get("h").unwrap().init_state(1);
        let err = try_build(m.get("d").unwrap(), &hstate).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("shape mismatch"), "{msg}");
    }
}
