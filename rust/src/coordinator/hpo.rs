//! Hyperparameter search: deterministic random search with successive
//! halving on the 20% validation split.
//!
//! The paper tunes hyperparameters with Bayesian optimization
//! (Snoek et al. 2012 via `bayesopt.m`) plus hand-tuning; offline we
//! substitute random search (Bergstra & Bengio 2012) with a halving
//! schedule, which matches the budget at these scales (DESIGN.md §3).

use super::trainer::{run_with_data, TrainConfig};
use crate::data::Dataset;
use crate::nn::TrainOptions;
use crate::runtime::{Hyper, Runtime};
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Search space: log-uniform lr, categorical momentum / keep_prob,
/// (DK) lam and temp.
pub fn sample_hyper(rng: &mut Pcg32, dk: bool) -> Hyper {
    let lr = 10f32.powf(rng.range_f32(-2.0, -0.3)); // 0.01 .. 0.5
    let momentum = *pick(rng, &[0.5, 0.9, 0.95]);
    let keep_prob = *pick(rng, &[0.8, 0.9, 1.0]);
    let (lam, temp) = if dk {
        (*pick(rng, &[0.3, 0.5, 0.7, 0.9]), *pick(rng, &[1.0, 2.0, 4.0, 8.0]))
    } else {
        (1.0, 4.0)
    };
    Hyper { lr, momentum, keep_prob, lam, temp }
}

fn pick<'a, T>(rng: &mut Pcg32, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len() as u32) as usize]
}

/// Result of one search.
#[derive(Debug, Clone)]
pub struct HpoResult {
    pub best: Hyper,
    pub best_val_error: f64,
    pub trials: Vec<(Hyper, f64)>,
}

/// Random search + successive halving: `n_trials` configs at
/// `epochs/4`, the top half re-run at `epochs/2`, the top quarter at
/// full `epochs`. Deterministic in `seed`; every trial trains under the
/// same execution policy `opts` (worker count + reduction order), so
/// the search no longer hard-codes single-threaded training.
#[allow(clippy::too_many_arguments)]
pub fn search(
    rt: &Runtime,
    artifact: &str,
    train: &Dataset,
    epochs: usize,
    n_trials: usize,
    seed: u64,
    opts: &TrainOptions,
) -> Result<HpoResult> {
    let dk = rt
        .manifest
        .get(artifact)
        .map(|s| s.uses_soft_targets)
        .unwrap_or(false);
    let mut rng = Pcg32::new(seed, 0x4270);
    let mut pool: Vec<Hyper> = (0..n_trials).map(|_| sample_hyper(&mut rng, dk)).collect();
    let mut all: Vec<(Hyper, f64)> = Vec::new();
    let stages = [epochs.div_ceil(4).max(1), epochs.div_ceil(2).max(1), epochs.max(1)];
    for (si, &ep) in stages.iter().enumerate() {
        let mut scored: Vec<(Hyper, f64)> = Vec::with_capacity(pool.len());
        for (ti, h) in pool.iter().enumerate() {
            let cfg = TrainConfig {
                artifact: artifact.to_string(),
                dataset: train.kind,
                epochs: ep,
                hyper: *h,
                seed: seed ^ (ti as u64) << 8,
                train: *opts,
                ..Default::default()
            };
            // NOTE: DK search would need soft targets; HPO is exposed for
            // non-DK methods (the DK scalars are part of the space only
            // when the caller provides targets).
            let res = run_with_data(rt, &cfg, train, None, None)?;
            scored.push((*h, res.val_error));
        }
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        all.extend(scored.iter().cloned());
        let keep = (scored.len() / 2).max(1);
        pool = scored.into_iter().take(keep).map(|(h, _)| h).collect();
        if si == stages.len() - 1 || pool.len() == 1 {
            break;
        }
    }
    let (best, best_val_error) = all
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .cloned()
        .unwrap();
    Ok(HpoResult { best, best_val_error, trials: all })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_hypers_in_bounds() {
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..200 {
            let h = sample_hyper(&mut rng, true);
            assert!((0.01..=0.51).contains(&h.lr), "lr {}", h.lr);
            assert!([0.5, 0.9, 0.95].contains(&h.momentum));
            assert!([0.8, 0.9, 1.0].contains(&h.keep_prob));
            assert!([0.3, 0.5, 0.7, 0.9].contains(&h.lam));
        }
        let h = sample_hyper(&mut rng, false);
        assert_eq!(h.lam, 1.0);
    }

    #[test]
    fn sampling_is_deterministic() {
        let a: Vec<f32> = {
            let mut r = Pcg32::new(3, 0x4270);
            (0..5).map(|_| sample_hyper(&mut r, false).lr).collect()
        };
        let b: Vec<f32> = {
            let mut r = Pcg32::new(3, 0x4270);
            (0..5).map(|_| sample_hyper(&mut r, false).lr).collect()
        };
        assert_eq!(a, b);
    }
}
