//! Experiment definitions and the parallel grid runner that regenerate
//! every table and figure of the paper's evaluation (§6):
//!
//! * `fig2` — test error vs. compression, 3-layer, MNIST & ROT
//! * `fig3` — same, 5-layer
//! * `table1` — all 8 datasets at compression 1/8, 3- & 5-layer
//! * `table2` — same at 1/64
//! * `fig4` — fixed storage, virtual expansion ×{1..16}, MNIST
//! * `tile_sweep` — accuracy vs. tile shape for the block-structured
//!   `hashed_tile` method against the per-cell `hashnet` baseline at
//!   the same budget (extension; not a paper figure)
//!
//! Teachers (dense compression-1 nets) are trained first — once per
//! (dataset, depth, out) — then all runs execute on a worker pool; each
//! worker owns its own PJRT runtime. Results stream to JSONL and are
//! pivoted into markdown/CSV tables mirroring the paper's layout.
//!
//! **Native fallback:** when the artifact runtime is unavailable
//! (`artifacts/` absent, or the vendored `xla` stub), the non-DK grid
//! cells run through [`trainer::run_native`] instead — their specs are
//! re-derived by [`super::sizing`] bit-identically to what `aot.py`
//! would have lowered — so the paper grids run from a fresh checkout
//! with no Python toolchain. Dark-knowledge cells need the teacher
//! pipeline (PJRT soft targets) and are skipped with a notice.

use super::metrics::{run_record, JsonlWriter, Table};
use super::sizing;
use super::trainer::{self, SoftTargets, TrainConfig};
use crate::data::{generate, Kind, Split};
use crate::model::{Method, ModelSpec};
use crate::nn::TrainOptions;
use crate::runtime::{Graph, Hyper, ModelState, Runtime};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};

/// Every method of the evaluation grid, in the paper's table order.
pub const METHODS: [Method; 6] = Method::ALL;
pub const COMPRESSIONS: [(u32, u32); 7] =
    [(1, 1), (1, 2), (1, 4), (1, 8), (1, 16), (1, 32), (1, 64)];
pub const EXPANSIONS: [usize; 5] = [1, 2, 4, 8, 16];
/// Tile shapes swept by the `tile_sweep` experiment — 1×8 (vector rows)
/// through 8×8 (square blocks), all SIMD-width-aligned.
pub const TILE_SWEEP: [(usize, usize); 4] = [(1, 8), (2, 8), (4, 8), (8, 8)];

/// Scale knobs for the whole grid (defaults match the CPU testbed;
/// `--scale paper` in the CLI raises them to the paper's sizes).
#[derive(Debug, Clone)]
pub struct ReproOptions {
    pub artifacts_dir: PathBuf,
    pub results_dir: PathBuf,
    pub hidden: usize,
    pub exp_base: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub epochs: usize,
    pub teacher_epochs: usize,
    pub workers: usize,
    pub seed: u64,
    /// Per-run training execution policy (backward worker count +
    /// reduction order), recorded into every JSONL run record. Grid
    /// `workers` and backward `train.threads` multiply — the default
    /// keeps each run single-threaded so the worker pool owns the
    /// machine.
    pub train: TrainOptions,
}

impl Default for ReproOptions {
    fn default() -> Self {
        ReproOptions {
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
            hidden: 100,
            exp_base: 50,
            n_train: 3000,
            n_test: 2000,
            epochs: 12,
            teacher_epochs: 12,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            seed: 0x5EED,
            train: TrainOptions::default(),
        }
    }
}

/// One grid cell to run.
#[derive(Debug, Clone)]
pub struct Job {
    pub experiment: String,
    pub dataset: Kind,
    pub method: Method,
    pub artifact: String,
    /// Paper layer-count nomenclature (3 or 5) — with `method`,
    /// `compression`/`expansion` and the grid widths this is enough to
    /// re-derive the cell's spec without a manifest (native fallback).
    pub depth: usize,
    pub compression: f64,
    pub expansion: Option<usize>,
    pub teacher: Option<String>,
}

/// Per-method default hyperparameters (stand-in for the paper's
/// Bayesian optimization; see `hpo` for the search tool).
pub fn default_hyper(method: Method) -> Hyper {
    if method.uses_soft_targets() {
        Hyper { lam: 0.7, temp: 4.0, ..Hyper::default() }
    } else {
        Hyper::default()
    }
}

fn artifact_name(method: &str, depth: usize, hidden: usize, out: usize, c: (u32, u32)) -> String {
    format!("{method}_{depth}l_h{hidden}_o{out}_c{}-{}", c.0, c.1)
}

fn expansion_artifact(method: &str, depth: usize, base: usize, factor: usize) -> String {
    format!("{method}_{depth}l_b{base}_o10_x{factor}")
}

fn teacher_name(depth: usize, hidden: usize, out: usize) -> String {
    artifact_name("nn", depth, hidden, out, (1, 1))
}

/// Build the job list for one experiment id.
pub fn jobs_for(experiment: &str, opt: &ReproOptions) -> Result<Vec<Job>> {
    let mut jobs = Vec::new();
    let mut push_grid = |datasets: &[Kind], depths: &[usize], comps: &[(u32, u32)], exp: &str| {
        for &ds in datasets {
            let out = ds.n_classes();
            for &depth in depths {
                for &c in comps {
                    for method in METHODS {
                        let teacher = method
                            .uses_soft_targets()
                            .then(|| teacher_name(depth, opt.hidden, out));
                        jobs.push(Job {
                            experiment: exp.to_string(),
                            dataset: ds,
                            method,
                            artifact: artifact_name(method.as_str(), depth, opt.hidden, out, c),
                            depth,
                            compression: c.0 as f64 / c.1 as f64,
                            expansion: None,
                            teacher,
                        });
                    }
                }
            }
        }
    };
    match experiment {
        "fig2" => push_grid(&[Kind::Mnist, Kind::Rot], &[3], &COMPRESSIONS, "fig2"),
        "fig3" => push_grid(&[Kind::Mnist, Kind::Rot], &[5], &COMPRESSIONS, "fig3"),
        "table1" => push_grid(&Kind::all(), &[3, 5], &[(1, 8)], "table1"),
        "table2" => push_grid(&Kind::all(), &[3, 5], &[(1, 64)], "table2"),
        "fig4" => {
            for &depth in &[3usize, 5] {
                for &factor in &EXPANSIONS {
                    for method in [Method::Hashnet, Method::Rer, Method::Lrd] {
                        jobs.push(Job {
                            experiment: "fig4".into(),
                            dataset: Kind::Mnist,
                            method,
                            artifact: expansion_artifact(
                                method.as_str(),
                                depth,
                                opt.exp_base,
                                factor,
                            ),
                            depth,
                            compression: 1.0 / factor as f64,
                            expansion: Some(factor),
                            teacher: None,
                        });
                    }
                }
                // the fixed-size dense reference (dashed line in Fig. 4)
                jobs.push(Job {
                    experiment: "fig4".into(),
                    dataset: Kind::Mnist,
                    method: Method::Nn,
                    artifact: expansion_artifact("nn", depth, opt.exp_base, 1),
                    depth,
                    compression: 1.0,
                    expansion: Some(1),
                    teacher: None,
                });
            }
        }
        "tile_sweep" => {
            // structured-hashing extension: same MNIST 3-layer 1/8 cell,
            // per-cell hashing vs. every SIMD-aligned tile shape
            let out = Kind::Mnist.n_classes();
            let c = (1u32, 8u32);
            let mut push = |method: Method, tag: &str| {
                jobs.push(Job {
                    experiment: "tile_sweep".into(),
                    dataset: Kind::Mnist,
                    method,
                    artifact: artifact_name(tag, 3, opt.hidden, out, c),
                    depth: 3,
                    compression: c.0 as f64 / c.1 as f64,
                    expansion: None,
                    teacher: None,
                });
            };
            push(Method::Hashnet, "hashnet");
            for tile in TILE_SWEEP {
                push(Method::HashedTile { tile }, &format!("tile{}x{}", tile.0, tile.1));
            }
        }
        other => {
            return Err(anyhow!(
                "unknown experiment '{other}' (fig2|fig3|table1|table2|fig4|tile_sweep)"
            ))
        }
    }
    Ok(jobs)
}

/// Result row streamed back from workers.
#[derive(Debug, Clone)]
pub struct RunRow {
    pub job: Job,
    pub test_error: f64,
    pub val_error: f64,
    pub stored_params: usize,
    pub wall_s: f64,
    pub steps_per_s: f64,
    /// Backward worker count the run was configured with.
    pub threads: usize,
}

type TeacherMap = HashMap<(Kind, String), (ModelState, Matrix)>; // state + train logits

/// Train all unique teachers needed by `jobs` (single runtime, serial —
/// teachers are few and each is the most expensive config).
fn train_teachers(jobs: &[Job], opt: &ReproOptions) -> Result<TeacherMap> {
    let mut needed: BTreeMap<(Kind, String), ()> = BTreeMap::new();
    for j in jobs {
        if let Some(t) = &j.teacher {
            needed.insert((j.dataset, t.clone()), ());
        }
    }
    let mut map = TeacherMap::new();
    if needed.is_empty() {
        return Ok(map);
    }
    let rt = Runtime::open(&opt.artifacts_dir)?;
    for (ds, teacher) in needed.keys() {
        eprintln!("[teacher] {} on {}", teacher, ds.name());
        let train = generate(*ds, Split::Train, opt.n_train, opt.seed);
        // teachers get the same lr screen as the grid cells
        let mut best: Option<(f64, crate::runtime::ModelState)> = None;
        for &lr in &LR_SCREEN {
            let cfg = TrainConfig {
                artifact: teacher.clone(),
                dataset: *ds,
                n_train: opt.n_train,
                n_test: opt.n_test,
                epochs: opt.teacher_epochs,
                hyper: crate::runtime::Hyper { lr, ..Default::default() },
                seed: opt.seed,
                teacher: None,
                patience: 0,
                train: opt.train,
            };
            let res = trainer::run_with_data(&rt, &cfg, &train, None, None)?;
            if best.as_ref().map(|(v, _)| res.val_error < *v).unwrap_or(true) {
                best = Some((res.val_error, res.state));
            }
        }
        let (_, state) = best.unwrap();
        let exe = rt.load(teacher, Graph::Predict)?;
        let logits = exe.predict_all(&state, &train.images)?;
        map.insert((*ds, teacher.clone()), (state, logits));
    }
    Ok(map)
}

/// Run a job list; stream rows back in completion order. Uses the PJRT
/// artifact runtime when it opens, and otherwise falls back to the
/// native engine for every non-DK cell (see the module docs).
pub fn run_jobs(jobs: Vec<Job>, opt: &ReproOptions) -> Result<Vec<RunRow>> {
    match Runtime::open(&opt.artifacts_dir) {
        Ok(_) => run_jobs_artifact(jobs, opt),
        Err(e) => {
            eprintln!(
                "artifact runtime unavailable ({e:#}) — running the grid on the \
                 native engine"
            );
            run_jobs_native(jobs, opt)
        }
    }
}

/// The artifact path: a worker pool where each worker owns its own
/// PJRT runtime (clients are not `Send`).
fn run_jobs_artifact(jobs: Vec<Job>, opt: &ReproOptions) -> Result<Vec<RunRow>> {
    let teachers = Arc::new(train_teachers(&jobs, opt)?);
    let total = jobs.len();
    let queue = Arc::new(Mutex::new(VecDeque::from(jobs)));
    let (tx, rx) = mpsc::channel::<Result<RunRow>>();
    let n_workers = opt.workers.clamp(1, total.max(1));
    let mut handles = Vec::new();
    for _ in 0..n_workers {
        let queue = queue.clone();
        let tx = tx.clone();
        let teachers = teachers.clone();
        let opt = opt.clone();
        handles.push(std::thread::spawn(move || {
            let rt = match Runtime::open(&opt.artifacts_dir) {
                Ok(rt) => rt,
                Err(e) => {
                    let _ = tx.send(Err(e));
                    return;
                }
            };
            loop {
                let job = match queue.lock().unwrap().pop_front() {
                    Some(j) => j,
                    None => break,
                };
                let _ = tx.send(run_one(&rt, &job, &teachers, &opt));
            }
        }));
    }
    drop(tx);
    let rows = collect_rows(rx, total);
    for h in handles {
        let _ = h.join();
    }
    Ok(rows)
}

/// The native fallback: non-DK cells train through
/// [`trainer::run_native`] on specs synthesized by [`sizing`]; DK
/// cells are skipped (their soft targets come from the PJRT teacher
/// pipeline). Long-lived coarse workers, like the artifact path.
fn run_jobs_native(jobs: Vec<Job>, opt: &ReproOptions) -> Result<Vec<RunRow>> {
    let (native, skipped): (Vec<Job>, Vec<Job>) =
        jobs.into_iter().partition(|j| !j.method.uses_soft_targets());
    if !skipped.is_empty() {
        eprintln!(
            "skipping {} dark-knowledge cells (the teacher pipeline needs the \
             artifact runtime — run `make artifacts` to include them)",
            skipped.len()
        );
    }
    let total = native.len();
    let queue = Arc::new(Mutex::new(VecDeque::from(native)));
    let (tx, rx) = mpsc::channel::<Result<RunRow>>();
    let n_workers = opt.workers.clamp(1, total.max(1));
    let mut handles = Vec::new();
    for _ in 0..n_workers {
        let queue = queue.clone();
        let tx = tx.clone();
        let opt = opt.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = match queue.lock().unwrap().pop_front() {
                Some(j) => j,
                None => break,
            };
            let _ = tx.send(run_one_native(&job, &opt));
        }));
    }
    drop(tx);
    let rows = collect_rows(rx, total);
    for h in handles {
        let _ = h.join();
    }
    Ok(rows)
}

/// Drain worker results, logging progress/failures in completion order.
fn collect_rows(rx: mpsc::Receiver<Result<RunRow>>, total: usize) -> Vec<RunRow> {
    let mut rows = Vec::with_capacity(total);
    for (i, res) in rx.iter().enumerate() {
        match res {
            Ok(row) => {
                eprintln!(
                    "[{}/{}] {} {} {}: test {:.2}% ({:.1}s, {:.0} steps/s)",
                    i + 1, total, row.job.experiment, row.job.dataset.name(),
                    row.job.artifact, row.test_error * 100.0, row.wall_s, row.steps_per_s
                );
                rows.push(row);
            }
            Err(e) => eprintln!("[{}/{}] FAILED: {e:#}", i + 1, total),
        }
    }
    rows
}

/// The [`ModelSpec`] a grid cell's artifact would have been lowered
/// with, re-derived from the job parameters (no manifest needed).
fn native_spec_for(job: &Job, opt: &ReproOptions) -> Result<ModelSpec> {
    let out = job.dataset.n_classes();
    let spec = match job.expansion {
        Some(factor) => sizing::expansion_grid_spec(
            &job.artifact,
            job.method,
            job.depth,
            opt.exp_base,
            out,
            factor,
        )?,
        None => sizing::grid_spec(
            &job.artifact,
            job.method,
            job.depth,
            opt.hidden,
            out,
            job.compression,
        )?,
    };
    Ok(spec)
}

/// One grid cell on the native engine: the same lr screen + full run
/// protocol as [`run_one`], driven by [`trainer::run_native`].
fn run_one_native(job: &Job, opt: &ReproOptions) -> Result<RunRow> {
    let spec = native_spec_for(job, opt)?;
    let base = TrainConfig {
        artifact: job.artifact.clone(),
        dataset: job.dataset,
        n_train: opt.n_train,
        n_test: opt.n_test,
        epochs: opt.epochs,
        hyper: default_hyper(job.method),
        seed: opt.seed,
        teacher: None,
        patience: 0,
        train: opt.train,
    };
    let mut best_lr = LR_SCREEN[0];
    let mut best_val = f64::INFINITY;
    for &lr in &LR_SCREEN {
        let mut probe = base.clone();
        probe.hyper.lr = lr;
        probe.epochs = (opt.epochs / 4).clamp(2, 3);
        let v = trainer::run_native(&spec, &probe)?.val_error;
        if v < best_val {
            best_val = v;
            best_lr = lr;
        }
    }
    let mut cfg = base;
    cfg.hyper.lr = best_lr;
    let res = trainer::run_native(&spec, &cfg)?;
    Ok(RunRow {
        job: job.clone(),
        test_error: res.test_error,
        val_error: res.val_error,
        stored_params: res.stored_params,
        wall_s: res.wall_s,
        steps_per_s: res.steps_per_s,
        threads: res.threads,
    })
}

/// Learning-rate candidates screened per (method × dataset) cell — the
/// paper tunes hyperparameters per configuration with Bayesian opt; a
/// short validation screen over a log grid plays that role here (the
/// full random-search tool lives in [`super::hpo`]).
pub const LR_SCREEN: [f32; 2] = [0.1, 0.01];

fn run_one(
    rt: &Runtime,
    job: &Job,
    teachers: &TeacherMap,
    opt: &ReproOptions,
) -> Result<RunRow> {
    let hyper = default_hyper(job.method);
    let mut cfg = TrainConfig {
        artifact: job.artifact.clone(),
        dataset: job.dataset,
        n_train: opt.n_train,
        n_test: opt.n_test,
        epochs: opt.epochs,
        hyper,
        seed: opt.seed,
        teacher: job.teacher.clone(),
        patience: 0,
        train: opt.train,
    };
    let soft = match &job.teacher {
        Some(t) => {
            let (_, logits) = teachers
                .get(&(job.dataset, t.clone()))
                .ok_or_else(|| anyhow!("missing teacher {t} for {}", job.dataset.name()))?;
            let mut scaled = logits.clone();
            scaled.scale(1.0 / hyper.temp);
            Some(SoftTargets { probs: scaled.softmax_rows(), temp: hyper.temp })
        }
        None => None,
    };
    // short validation screen over the lr grid, then the full run
    let mut best_lr = LR_SCREEN[0];
    let mut best_val = f64::INFINITY;
    for &lr in &LR_SCREEN {
        let mut probe = cfg.clone();
        probe.hyper.lr = lr;
        probe.epochs = (opt.epochs / 4).clamp(2, 3);
        let v = trainer::run(rt, &probe, soft.as_ref())?.val_error;
        if v < best_val {
            best_val = v;
            best_lr = lr;
        }
    }
    cfg.hyper.lr = best_lr;
    let res = trainer::run(rt, &cfg, soft.as_ref())?;
    Ok(RunRow {
        job: job.clone(),
        test_error: res.test_error,
        val_error: res.val_error,
        stored_params: res.stored_params,
        wall_s: res.wall_s,
        steps_per_s: res.steps_per_s,
        threads: res.threads,
    })
}

/// Run one experiment end-to-end and emit JSONL + tables.
pub fn run_experiment(experiment: &str, opt: &ReproOptions) -> Result<()> {
    let jobs = jobs_for(experiment, opt)?;
    eprintln!("experiment {experiment}: {} runs on {} workers", jobs.len(), opt.workers);
    let rows = run_jobs(jobs, opt)?;

    std::fs::create_dir_all(&opt.results_dir)?;
    let mut log = JsonlWriter::create(&opt.results_dir.join(format!("{experiment}.jsonl")))?;
    for r in &rows {
        log.write(&run_record(
            &r.job.experiment, r.job.dataset.name(), r.job.method.as_str(), &r.job.artifact,
            r.job.compression, r.job.expansion, r.test_error, r.val_error,
            r.stored_params, r.wall_s, r.steps_per_s, r.threads,
        ))?;
    }
    for table in pivot_tables(experiment, &rows) {
        let stem = table.title.split_whitespace().next().unwrap_or("table").to_lowercase();
        table.save(&opt.results_dir, &stem)?;
        println!("{}", table.to_markdown());
    }
    Ok(())
}

/// Pivot result rows into the paper's table/figure layouts.
pub fn pivot_tables(experiment: &str, rows: &[RunRow]) -> Vec<Table> {
    let method_cols = ["RER", "LRD", "NN", "DK", "HashNet", "HashNetDK"];
    let pretty = |m: Method| -> &'static str {
        match m {
            Method::Rer => "RER",
            Method::Lrd => "LRD",
            Method::Nn => "NN",
            Method::Dk => "DK",
            Method::Hashnet => "HashNet",
            Method::HashnetDk => "HashNetDK",
            Method::HashedEmbedding { .. } => "HashedEmbedding",
            Method::HashedTile { .. } => "HashedTile",
        }
    };
    match experiment {
        "fig2" | "fig3" => {
            let mut tables = Vec::new();
            for ds in [Kind::Mnist, Kind::Rot] {
                let mut t = Table::new(
                    &format!("{experiment}_{} test error (%) vs compression", ds.name()),
                    "compression",
                    &method_cols,
                );
                for r in rows.iter().filter(|r| r.job.dataset == ds) {
                    t.set_err(&format!("{:.5}", r.job.compression), pretty(r.job.method), r.test_error);
                }
                t.bold_row_minima();
                tables.push(t);
            }
            tables
        }
        "table1" | "table2" => {
            let cols: Vec<String> = [3, 5]
                .iter()
                .flat_map(|d| method_cols.iter().map(move |m| format!("{m}({d}L)")))
                .collect();
            let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
            let mut t = Table::new(
                &format!("{experiment} test error (%), compression {}",
                         if experiment == "table1" { "1/8" } else { "1/64" }),
                "dataset",
                &cols_ref,
            );
            for r in rows {
                let depth = if r.job.artifact.contains("_3l_") { 3 } else { 5 };
                t.set_err(
                    r.job.dataset.name(),
                    &format!("{}({}L)", pretty(r.job.method), depth),
                    r.test_error,
                );
            }
            t.bold_row_minima();
            vec![t]
        }
        "fig4" => {
            let mut tables = Vec::new();
            for depth in [3usize, 5] {
                let mut t = Table::new(
                    &format!("fig4_{depth}l test error (%) vs expansion (fixed storage)"),
                    "expansion",
                    &["NN", "RER", "LRD", "HashNet"],
                );
                for r in rows.iter().filter(|r| {
                    r.job.artifact.contains(&format!("_{depth}l_"))
                }) {
                    let x = r.job.expansion.unwrap_or(1);
                    t.set_err(&format!("{x}"), pretty(r.job.method), r.test_error);
                }
                tables.push(t);
            }
            tables
        }
        "tile_sweep" => {
            // one variant per column (per-cell baseline, then the tile
            // shapes), one row per compression level in the sweep
            let label = |m: Method| -> String {
                match m {
                    Method::HashedTile { tile } => format!("{}x{}", tile.0, tile.1),
                    other => pretty(other).to_string(),
                }
            };
            let mut cols: Vec<String> = vec!["HashNet".into()];
            cols.extend(TILE_SWEEP.iter().map(|t| format!("{}x{}", t.0, t.1)));
            let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
            let mut t = Table::new(
                "tile_sweep test error (%) vs tile shape, MNIST 3-layer",
                "compression",
                &cols_ref,
            );
            for r in rows {
                t.set_err(&format!("{:.5}", r.job.compression), &label(r.job.method), r.test_error);
            }
            t.bold_row_minima();
            vec![t]
        }
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_lists_have_expected_sizes() {
        let opt = ReproOptions::default();
        assert_eq!(jobs_for("fig2", &opt).unwrap().len(), 2 * 7 * 6);
        assert_eq!(jobs_for("fig3", &opt).unwrap().len(), 2 * 7 * 6);
        assert_eq!(jobs_for("table1", &opt).unwrap().len(), 8 * 2 * 6);
        assert_eq!(jobs_for("table2", &opt).unwrap().len(), 8 * 2 * 6);
        assert_eq!(jobs_for("fig4", &opt).unwrap().len(), 2 * (5 * 3 + 1));
        // hashnet baseline + one job per swept tile shape
        assert_eq!(jobs_for("tile_sweep", &opt).unwrap().len(), 1 + TILE_SWEEP.len());
        assert!(jobs_for("nope", &opt).is_err());
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        let opt = ReproOptions::default();
        let jobs = jobs_for("table1", &opt).unwrap();
        assert!(jobs.iter().any(|j| j.artifact == "hashnet_3l_h100_o10_c1-8"));
        assert!(jobs.iter().any(|j| j.artifact == "lrd_5l_h100_o2_c1-8"));
        // binary datasets target the o2 artifacts
        for j in &jobs {
            if matches!(j.dataset, Kind::Rect | Kind::Convex) {
                assert!(j.artifact.contains("_o2_"), "{}", j.artifact);
            }
        }
    }

    #[test]
    fn every_grid_cell_resolves_to_a_valid_native_spec() {
        // the fallback path must be able to synthesize a spec for every
        // cell of every experiment (DK cells included — they are only
        // skipped because of the teacher pipeline, not the spec)
        let opt = ReproOptions::default();
        for exp in ["fig2", "fig3", "table1", "table2", "fig4", "tile_sweep"] {
            for job in jobs_for(exp, &opt).unwrap() {
                let spec = native_spec_for(&job, &opt)
                    .unwrap_or_else(|e| panic!("{}: {e:#}", job.artifact));
                spec.validate().unwrap();
                assert_eq!(spec.name, job.artifact);
                assert_eq!(spec.method, job.method);
                assert_eq!(spec.n_out(), job.dataset.n_classes());
            }
        }
    }

    #[test]
    fn native_fallback_trains_a_tiny_cell_end_to_end() {
        // a shrunken grid so the test stays fast: hidden 16, 2 epochs
        let opt = ReproOptions {
            hidden: 16,
            n_train: 240,
            n_test: 120,
            epochs: 2,
            ..ReproOptions::default()
        };
        let job = Job {
            experiment: "fig2".into(),
            dataset: Kind::Basic,
            method: Method::Hashnet,
            artifact: "hashnet_3l_h16_o10_c1-4".into(),
            depth: 3,
            compression: 0.25,
            expansion: None,
            teacher: None,
        };
        let row = run_one_native(&job, &opt).expect("native cell");
        assert!(row.test_error <= 1.0 && row.test_error >= 0.0);
        assert!(row.stored_params > 0);
        assert_eq!(row.threads, opt.train.resolved_threads());
    }

    #[test]
    fn dk_jobs_reference_teachers() {
        let opt = ReproOptions::default();
        let jobs = jobs_for("fig2", &opt).unwrap();
        for j in &jobs {
            if j.method.uses_soft_targets() {
                assert_eq!(j.teacher.as_deref(), Some("nn_3l_h100_o10_c1-1"));
            } else {
                assert!(j.teacher.is_none());
            }
        }
    }

    #[test]
    fn pivot_fig_table_shapes() {
        let job = Job {
            experiment: "fig2".into(),
            dataset: Kind::Mnist,
            method: Method::Hashnet,
            artifact: "hashnet_3l_h100_o10_c1-8".into(),
            depth: 3,
            compression: 0.125,
            expansion: None,
            teacher: None,
        };
        let rows = vec![RunRow {
            job,
            test_error: 0.0145,
            val_error: 0.015,
            stored_params: 1,
            wall_s: 1.0,
            steps_per_s: 10.0,
            threads: 1,
        }];
        let tables = pivot_tables("fig2", &rows);
        assert_eq!(tables.len(), 2);
        assert!(tables[0].to_csv().contains("0.12500,,,,,1.45,"));
    }

    #[test]
    fn pivot_tile_sweep_labels_tiles() {
        let mk = |method: Method, artifact: &str, err: f64| RunRow {
            job: Job {
                experiment: "tile_sweep".into(),
                dataset: Kind::Mnist,
                method,
                artifact: artifact.into(),
                depth: 3,
                compression: 0.125,
                expansion: None,
                teacher: None,
            },
            test_error: err,
            val_error: err,
            stored_params: 9938,
            wall_s: 1.0,
            steps_per_s: 10.0,
            threads: 1,
        };
        let rows = vec![
            mk(Method::Hashnet, "hashnet_3l_h100_o10_c1-8", 0.02),
            mk(
                Method::HashedTile { tile: (8, 8) },
                "tile8x8_3l_h100_o10_c1-8",
                0.03,
            ),
        ];
        let tables = pivot_tables("tile_sweep", &rows);
        assert_eq!(tables.len(), 1);
        let csv = tables[0].to_csv();
        assert!(csv.contains("HashNet") && csv.contains("8x8"), "{csv}");
        assert!(csv.contains("0.12500,2.00"), "{csv}");
    }
}
