//! Metrics sinks: JSONL run records and markdown/CSV tables in the
//! shape of the paper's Tables 1–2 and Figures 2–4.

use crate::util::json::{num, obj, s, Json};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Append-only JSONL metrics writer.
pub struct JsonlWriter {
    file: std::fs::File,
}

impl JsonlWriter {
    pub fn create(path: &Path) -> std::io::Result<JsonlWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(JsonlWriter { file: std::fs::File::create(path)? })
    }

    pub fn write(&mut self, record: &Json) -> std::io::Result<()> {
        writeln!(self.file, "{}", record.to_string())
    }
}

/// One run record for the JSONL log. `threads` is the backward worker
/// count the run was configured with, so throughput numbers in the log
/// are attributable to an execution policy.
#[allow(clippy::too_many_arguments)]
pub fn run_record(
    experiment: &str,
    dataset: &str,
    method: &str,
    artifact: &str,
    compression: f64,
    expansion: Option<usize>,
    test_error: f64,
    val_error: f64,
    stored_params: usize,
    wall_s: f64,
    steps_per_s: f64,
    threads: usize,
) -> Json {
    let mut pairs = vec![
        ("experiment", s(experiment)),
        ("dataset", s(dataset)),
        ("method", s(method)),
        ("artifact", s(artifact)),
        ("compression", num(compression)),
        ("test_error", num(crate::util::round_to(test_error * 100.0, 3))),
        ("val_error", num(crate::util::round_to(val_error * 100.0, 3))),
        ("stored_params", num(stored_params as f64)),
        ("wall_s", num(crate::util::round_to(wall_s, 2))),
        ("steps_per_s", num(crate::util::round_to(steps_per_s, 1))),
        ("threads", num(threads as f64)),
    ];
    if let Some(x) = expansion {
        pairs.push(("expansion", num(x as f64)));
    }
    obj(pairs)
}

/// A 2-D results table keyed by (row, column) → cell string, rendered
/// as markdown or CSV with a fixed column order.
pub struct Table {
    pub title: String,
    pub row_label: String,
    columns: Vec<String>,
    rows: BTreeMap<String, BTreeMap<String, String>>,
    row_order: Vec<String>,
}

impl Table {
    pub fn new(title: &str, row_label: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            row_label: row_label.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: BTreeMap::new(),
            row_order: Vec::new(),
        }
    }

    pub fn set(&mut self, row: &str, col: &str, value: String) {
        if !self.rows.contains_key(row) {
            self.row_order.push(row.to_string());
        }
        self.rows.entry(row.to_string()).or_default().insert(col.to_string(), value);
    }

    pub fn set_err(&mut self, row: &str, col: &str, err: f64) {
        self.set(row, col, format!("{:.2}", err * 100.0));
    }

    /// Bold (markdown) the minimum numeric cell per row — the paper
    /// prints best results in blue; we use bold.
    pub fn bold_row_minima(&mut self) {
        for row in self.rows.values_mut() {
            let min = row
                .values()
                .filter_map(|v| v.parse::<f64>().ok())
                .fold(f64::INFINITY, f64::min);
            if min.is_finite() {
                for v in row.values_mut() {
                    if v.parse::<f64>().map(|x| (x - min).abs() < 5e-3).unwrap_or(false) {
                        *v = format!("**{v}**");
                    }
                }
            }
        }
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |", self.row_label));
        for c in &self.columns {
            out.push_str(&format!(" {c} |"));
        }
        out.push('\n');
        out.push_str(&format!("|{}", "---|".repeat(self.columns.len() + 1)));
        out.push('\n');
        for r in &self.row_order {
            out.push_str(&format!("| {r} |"));
            let cells = &self.rows[r];
            for c in &self.columns {
                out.push_str(&format!(" {} |", cells.get(c).map(String::as_str).unwrap_or("—")));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = format!("{}", self.row_label);
        for c in &self.columns {
            out.push_str(&format!(",{c}"));
        }
        out.push('\n');
        for r in &self.row_order {
            out.push_str(r);
            let cells = &self.rows[r];
            for c in &self.columns {
                let raw = cells.get(c).cloned().unwrap_or_default();
                out.push_str(&format!(",{}", raw.replace("**", "")));
            }
            out.push('\n');
        }
        out
    }

    pub fn save(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown_and_csv() {
        let mut t = Table::new("Test error (%)", "dataset", &["RER", "NN", "HashNet"]);
        t.set_err("mnist", "RER", 0.0219);
        t.set_err("mnist", "NN", 0.0169);
        t.set_err("mnist", "HashNet", 0.0145);
        t.bold_row_minima();
        let md = t.to_markdown();
        assert!(md.contains("| mnist | 2.19 | 1.69 | **1.45** |"), "{md}");
        let csv = t.to_csv();
        assert!(csv.contains("mnist,2.19,1.69,1.45"), "{csv}");
    }

    #[test]
    fn missing_cells_render_dash() {
        let mut t = Table::new("t", "r", &["a", "b"]);
        t.set("x", "a", "1.0".into());
        assert!(t.to_markdown().contains("| x | 1.0 | — |"));
    }

    #[test]
    fn jsonl_writer_appends_lines() {
        let path = std::env::temp_dir().join(format!("hn_jsonl_{}.log", std::process::id()));
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            w.write(&run_record("fig2", "mnist", "hashnet", "a", 0.125, None,
                                0.0145, 0.015, 1000, 1.5, 100.0, 4)).unwrap();
            w.write(&obj(vec![("x", num(1.0))])).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.req_f64("test_error").unwrap(), 1.45);
        assert_eq!(first.req_f64("threads").unwrap(), 4.0);
        std::fs::remove_file(&path).ok();
    }
}
