//! Host-side f32 tensors: a small row-major matrix type with the ops the
//! native engine and the coordinator need (no ndarray offline).

pub mod simd;

/// Dot product over 4 independent accumulators: breaks the FP-add
/// dependency chain that serializes a single-accumulator loop, so the
/// CPU can keep several fused multiply-adds in flight. Shared by
/// [`Matrix::matmul_nt`] (the dense roofline / Dense-layer forward) and
/// the hashed scratch-row kernel in `nn::layers`.
#[inline]
pub fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot_unrolled length mismatch");
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 += x[0] * y[0];
        s1 += x[1] * y[1];
        s2 += x[2] * y[2];
        s3 += x[3] * y[3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// Row-major 2-D f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self (r×k) @ other (k×c) -> (r×c)`, blocked i-k-j loop order
    /// (cache-friendly: inner loop is contiguous in both `other` and out).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (r, k, c) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(r, c);
        for i in 0..r {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (p, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue; // ReLU activations are sparse — worth the branch
                }
                let brow = &other.data[p * c..(p + 1) * c];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// [`Matrix::matmul`] split over blocks of output rows on up to
    /// `threads` tasks of the shared [`crate::rt::PoolExec`]. Each
    /// output row is produced by exactly one task with the same
    /// accumulation order as the serial loop, so the result is
    /// **bit-identical** to `matmul` for every thread count — the
    /// backward pass relies on this for its determinism contract.
    pub fn matmul_par(&self, other: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (r, k, c) = (self.rows, self.cols, other.cols);
        let threads = threads.clamp(1, r.max(1));
        if threads == 1 || c == 0 {
            return self.matmul(other);
        }
        let mut out = Matrix::zeros(r, c);
        let rows_per = r.div_ceil(threads);
        crate::rt::pool::run_parts(
            out.data.chunks_mut(rows_per * c).collect(),
            |t, ochunk: &mut [f32]| {
                let i0 = t * rows_per;
                for (ri, orow) in ochunk.chunks_mut(c).enumerate() {
                    let arow = self.row(i0 + ri);
                    for (p, &a) in arow.iter().enumerate().take(k) {
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &other.data[p * c..(p + 1) * c];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                }
            },
        );
        out
    }

    /// `self (r×k) @ other.T (c×k) -> (r×c)` — dot-product form, inner
    /// loop unrolled into 4 independent accumulators ([`dot_unrolled`]).
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (r, c) = (self.rows, other.rows);
        let mut out = Matrix::zeros(r, c);
        for i in 0..r {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (j, ov) in orow.iter_mut().enumerate() {
                *ov = dot_unrolled(arow, other.row(j));
            }
        }
        out
    }

    /// [`Matrix::matmul_nt`] over blocks of output rows on up to
    /// `threads` pool tasks; bit-identical to the serial version for
    /// every thread count (each output cell is one `dot_unrolled` call).
    pub fn matmul_nt_par(&self, other: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (r, c) = (self.rows, other.rows);
        let threads = threads.clamp(1, r.max(1));
        if threads == 1 || c == 0 {
            return self.matmul_nt(other);
        }
        let mut out = Matrix::zeros(r, c);
        let rows_per = r.div_ceil(threads);
        crate::rt::pool::run_parts(
            out.data.chunks_mut(rows_per * c).collect(),
            |t, ochunk: &mut [f32]| {
                let i0 = t * rows_per;
                for (ri, orow) in ochunk.chunks_mut(c).enumerate() {
                    let arow = self.row(i0 + ri);
                    for (j, ov) in orow.iter_mut().enumerate() {
                        *ov = dot_unrolled(arow, other.row(j));
                    }
                }
            },
        );
        out
    }

    /// `self (r×m) @ [other | column of ones]ᵀ` where `other` is
    /// `(c × (m+1))`: the dot-product forward with an **implicit bias
    /// column** — `out[i][j] = Σ_p self[i,p]·other[j,p] + other[j,m]` —
    /// so callers never materialize `self.augment_ones()` (a full
    /// batch-matrix copy per layer call before this existed).
    pub fn matmul_nt_aug(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols + 1, other.cols, "matmul_nt_aug shape mismatch");
        let (r, c, m) = (self.rows, other.rows, self.cols);
        let mut out = Matrix::zeros(r, c);
        for i in 0..r {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (j, ov) in orow.iter_mut().enumerate() {
                let brow = other.row(j);
                *ov = brow[m] + dot_unrolled(arow, &brow[..m]);
            }
        }
        out
    }

    /// `selfᵀ (k×r) @ [other | column of ones] (k×(c+1)) -> (r×(c+1))`:
    /// the transpose product against `other` with an implicit trailing
    /// all-ones column. This is exactly `S = δᵀ·[a|1]` of the hashed
    /// backward (paper Eq. 12's per-cell factor `Σ_b a_bj δ_bi`,
    /// including the bias column `j = m`) without materializing
    /// `other.augment_ones()`. Row-parallel over output rows on up to
    /// `threads` pool tasks; every output element sums over `p` in
    /// ascending order in exactly one task, so the result is
    /// **bit-identical** to serial at any thread count.
    pub fn matmul_tn_aug(&self, other: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn_aug shape mismatch");
        let (k, r, c1) = (self.rows, self.cols, other.cols + 1);
        let mut out = Matrix::zeros(r, c1);
        if r == 0 {
            return out;
        }
        let threads = threads.clamp(1, r);
        let rows_per = r.div_ceil(threads);
        crate::rt::pool::run_parts(
            out.data.chunks_mut(rows_per * c1).collect(),
            |t, ochunk: &mut [f32]| {
                let i0 = t * rows_per;
                for p in 0..k {
                    let arow = self.row(p);
                    let brow = other.row(p);
                    for (ri, orow) in ochunk.chunks_mut(c1).enumerate() {
                        let a = arow[i0 + ri];
                        if a == 0.0 {
                            continue;
                        }
                        let (cols, bias) = orow.split_at_mut(c1 - 1);
                        for (o, &b) in cols.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                        bias[0] += a;
                    }
                }
            },
        );
        out
    }

    /// `self.T (k×r) @ other (k×c) -> (r×c)`.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (k, r, c) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(r, c);
        for p in 0..k {
            let arow = self.row(p);
            let brow = other.row(p);
            for (i, &a) in arow.iter().enumerate().take(r) {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * c..(i + 1) * c];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// [`Matrix::matmul_tn`] over blocks of output rows (columns of
    /// `self`) on up to `threads` pool tasks. Every output cell is
    /// `Σ_p self[p,i]·other[p,j]` summed over `p` in ascending order in
    /// exactly one task, so the result is **bit-identical** to the
    /// serial version for any thread count — this is what makes the
    /// dense backward (`dW = δᵀ·a`) deterministic without an ordered
    /// reduction mode. Each task re-streams `self` but touches only
    /// its own output rows; `self` here is a `(B × n)` delta matrix, so
    /// the duplicated traffic is small next to the `(n × m)` output.
    pub fn matmul_tn_par(&self, other: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (k, r, c) = (self.rows, self.cols, other.cols);
        let threads = threads.clamp(1, r.max(1));
        if threads == 1 || c == 0 {
            return self.matmul_tn(other);
        }
        let mut out = Matrix::zeros(r, c);
        let rows_per = r.div_ceil(threads);
        crate::rt::pool::run_parts(
            out.data.chunks_mut(rows_per * c).collect(),
            |t, ochunk: &mut [f32]| {
                let i0 = t * rows_per;
                for p in 0..k {
                    let arow = self.row(p);
                    let brow = other.row(p);
                    for (ri, orow) in ochunk.chunks_mut(c).enumerate() {
                        let a = arow[i0 + ri];
                        if a == 0.0 {
                            continue;
                        }
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                }
            },
        );
        out
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Append a constant-1 column (bias augmentation, mirrors L2).
    pub fn augment_ones(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols + 1);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols] = 1.0;
        }
        out
    }

    /// Drop the last column (inverse of `augment_ones` for gradients).
    pub fn drop_last_col(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols - 1, |i, j| self.at(i, j))
    }

    /// Row-wise softmax, numerically stable.
    pub fn softmax_rows(&self) -> Matrix {
        let mut out = self.clone();
        for i in 0..self.rows {
            let row = out.row_mut(i);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        out
    }

    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                let mut best = 0;
                for j in 1..self.cols {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = crate::util::rng::Pcg32::new(7, 7);
        for len in [0usize, 1, 3, 4, 5, 8, 17, 64, 101] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot_unrolled(&a, &b);
            assert!((naive - fast).abs() < 1e-4 * (1.0 + naive.abs()), "len {len}");
        }
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]);
        assert_eq!(a.matmul(&b).data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = crate::util::rng::Pcg32::new(1, 1);
        let a = Matrix::from_fn(5, 7, |_, _| rng.normal());
        let b = Matrix::from_fn(7, 4, |_, _| rng.normal());
        let c1 = a.matmul(&b);
        let c2 = a.matmul_nt(&b.transpose());
        let c3 = a.transpose().matmul_tn(&b);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-4);
        }
        for (x, y) in c1.data.iter().zip(&c3.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn par_variants_bit_identical_to_serial() {
        // the determinism contract of the threaded backward rests on
        // these being exact, not approximate, matches
        let mut rng = crate::util::rng::Pcg32::new(9, 9);
        let a = Matrix::from_fn(13, 11, |_, _| rng.normal());
        let b = Matrix::from_fn(11, 6, |_, _| rng.normal());
        let bt = b.transpose();
        let tall = Matrix::from_fn(13, 6, |_, _| rng.normal()); // same rows as `a` for tn
        for threads in [1usize, 2, 3, 8, 64] {
            assert_eq!(a.matmul(&b).data, a.matmul_par(&b, threads).data, "matmul t{threads}");
            assert_eq!(
                a.matmul_nt(&bt).data,
                a.matmul_nt_par(&bt, threads).data,
                "matmul_nt t{threads}"
            );
            assert_eq!(
                a.matmul_tn(&tall).data,
                a.matmul_tn_par(&tall, threads).data,
                "matmul_tn t{threads}"
            );
        }
    }

    #[test]
    fn aug_variants_match_materialized_augmentation() {
        let mut rng = crate::util::rng::Pcg32::new(21, 21);
        let a = Matrix::from_fn(9, 6, |_, _| rng.normal()); // batch × m
        let v = Matrix::from_fn(5, 7, |_, _| rng.normal()); // n × (m+1)
        let aug = a.augment_ones();
        // forward: a·[V|b]ᵀ with implicit bias column
        let want_nt = aug.matmul_nt(&v);
        let got_nt = a.matmul_nt_aug(&v);
        for (x, y) in got_nt.data.iter().zip(&want_nt.data) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()), "{x} vs {y}");
        }
        // backward: δᵀ·[a|1], bit-identical across thread counts
        let delta = Matrix::from_fn(9, 5, |_, _| rng.normal());
        let want_tn = delta.matmul_tn(&aug);
        let t1 = delta.matmul_tn_aug(&a, 1);
        for (x, y) in t1.data.iter().zip(&want_tn.data) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()), "{x} vs {y}");
        }
        for threads in [2usize, 3, 8] {
            assert_eq!(
                t1.data,
                delta.matmul_tn_aug(&a, threads).data,
                "matmul_tn_aug t{threads}"
            );
        }
    }

    #[test]
    fn matmul_tn_aug_handles_zero_width_and_zero_rows() {
        let delta = Matrix::zeros(4, 0); // no output rows
        let a = Matrix::from_fn(4, 3, |i, j| (i + j) as f32);
        let out = delta.matmul_tn_aug(&a, 4);
        assert_eq!((out.rows, out.cols), (0, 4));
        let empty_batch = Matrix::zeros(0, 5);
        let out2 = empty_batch.matmul_tn_aug(&Matrix::zeros(0, 3), 4);
        assert_eq!((out2.rows, out2.cols), (5, 4));
        assert!(out2.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn par_variants_handle_single_row_and_zero_rows() {
        let a = Matrix::from_fn(1, 5, |_, j| j as f32);
        let b = Matrix::from_fn(5, 3, |i, j| (i + j) as f32);
        assert_eq!(a.matmul(&b).data, a.matmul_par(&b, 4).data);
        let empty = Matrix::zeros(0, 5);
        assert_eq!(empty.matmul_par(&b, 4).rows, 0);
        assert_eq!(empty.matmul_nt_par(&b.transpose(), 4).rows, 0);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 1000., 1000., 1000.]);
        let s = m.softmax_rows();
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(s.row(i).iter().all(|&p| p.is_finite()));
        }
    }

    #[test]
    fn augment_and_drop_roundtrip() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let a = m.augment_ones();
        assert_eq!(a.cols, 3);
        assert_eq!(a.at(0, 2), 1.0);
        assert_eq!(a.drop_last_col(), m);
    }

    #[test]
    fn argmax_rows_ties_prefer_first() {
        let m = Matrix::from_vec(2, 3, vec![0., 5., 5., 9., 1., 2.]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
