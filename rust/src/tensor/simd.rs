//! Explicit 8-lane SIMD kernels with a **bit-identical** scalar twin.
//!
//! The hashed hot loops (scratch-row forward, tiled forward/backward,
//! Eq. 11's input gradient) reduce to two primitives over contiguous
//! f32 slices: a dot product and an `axpy` (`dst += c · src`). This
//! module provides both with
//!
//! * a hand-written AVX2 path (`std::arch` intrinsics, runtime-detected
//!   via `is_x86_feature_detected!` — no compile-time `-C target-cpu`
//!   requirement and **no new crates**), and
//! * a scalar fallback that performs the *same* floating-point
//!   operations in the *same* order, so the two paths return
//!   bit-identical results on every input.
//!
//! Bit-identity is a hard requirement, not a nicety: ordered training
//! (`TrainOptions::deterministic`) promises thread-count-invariant
//! results, and that promise must extend across machines with and
//! without AVX2. Two consequences shape the code:
//!
//! 1. **No FMA.** `_mm256_fmadd_ps` fuses the multiply-add with a single
//!    rounding, which scalar `a * b + c` (two roundings) cannot
//!    reproduce. The vector path therefore uses explicit
//!    `_mm256_add_ps(_mm256_mul_ps(..))` — same two roundings as the
//!    scalar twin.
//! 2. **Lane-structured accumulation.** [`dot8`] keeps 8 independent
//!    accumulators (lane `l` sums `a[8c+l]·b[8c+l]`) and combines them
//!    with a fixed reduction tree `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`
//!    plus a serial tail. The scalar twin mirrors that structure
//!    exactly instead of summing left-to-right — which is also why it
//!    is *fast* scalar code: 8 accumulators break the FP-add dependency
//!    chain just like `dot_unrolled`'s 4 do.

/// SIMD width in f32 lanes (AVX2 = 256 bits = 8 × f32). Tile widths in
/// `hash::TilePlan` are chosen as multiples of this.
pub const LANES: usize = 8;

/// Runtime AVX2 capability, detected once and cached.
#[inline]
pub fn avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        // 0 = unknown, 1 = absent, 2 = present.
        static STATE: AtomicU8 = AtomicU8::new(0);
        match STATE.load(Ordering::Relaxed) {
            2 => true,
            1 => false,
            _ => {
                let yes = std::arch::is_x86_feature_detected!("avx2");
                STATE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
                yes
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Combine 8 lane accumulators + serial tail with the fixed reduction
/// tree shared by both dispatch paths.
#[inline(always)]
fn combine(lanes: [f32; LANES], tail: f32) -> f32 {
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
        + tail
}

/// 8-lane dot product, scalar path. Lane `l` accumulates
/// `Σ_c a[8c+l]·b[8c+l]`; lanes combine via [`combine`]. Bit-identical
/// to the AVX2 path by construction.
pub fn dot8_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut lanes = [0.0f32; LANES];
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for (l, acc) in lanes.iter_mut().enumerate() {
            *acc += a[base + l] * b[base + l];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * LANES..n {
        tail += a[i] * b[i];
    }
    combine(lanes, tail)
}

/// 8-lane dot product, AVX2 path. One 256-bit accumulator holds the 8
/// lanes; multiply and add are separate instructions (two roundings, no
/// FMA) so each lane performs exactly the scalar twin's operation
/// sequence.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot8_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let pa = _mm256_loadu_ps(a.as_ptr().add(c * LANES));
        let pb = _mm256_loadu_ps(b.as_ptr().add(c * LANES));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(pa, pb));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0f32;
    for i in chunks * LANES..n {
        tail += a[i] * b[i];
    }
    combine(lanes, tail)
}

/// Dot product over the common prefix of `a` and `b`, dispatched to
/// AVX2 when available, with a bit-identical scalar fallback.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: guarded by runtime AVX2 detection.
            return unsafe { dot8_avx2(a, b) };
        }
    }
    dot8_scalar(a, b)
}

/// `dst[i] += c · src[i]` over the common prefix, scalar path. Purely
/// element-wise (no cross-lane reduction), so SIMD/scalar bit-identity
/// only needs matching per-element rounding: mul then add.
pub fn axpy8_scalar(dst: &mut [f32], src: &[f32], c: f32) {
    let n = dst.len().min(src.len());
    for i in 0..n {
        dst[i] += c * src[i];
    }
}

/// `dst[i] += c · src[i]`, AVX2 path (broadcast `c`, mul then add — no
/// FMA, same two roundings per element as the scalar twin).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy8_avx2(dst: &mut [f32], src: &[f32], c: f32) {
    use std::arch::x86_64::*;
    let n = dst.len().min(src.len());
    let chunks = n / LANES;
    let cv = _mm256_set1_ps(c);
    for ch in 0..chunks {
        let base = ch * LANES;
        let d = _mm256_loadu_ps(dst.as_ptr().add(base));
        let s = _mm256_loadu_ps(src.as_ptr().add(base));
        _mm256_storeu_ps(dst.as_mut_ptr().add(base), _mm256_add_ps(d, _mm256_mul_ps(cv, s)));
    }
    for i in chunks * LANES..n {
        dst[i] += c * src[i];
    }
}

/// `dst[i] += c · src[i]` over the common prefix, dispatched to AVX2
/// when available, with a bit-identical scalar fallback.
#[inline]
pub fn axpy8(dst: &mut [f32], src: &[f32], c: f32) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: guarded by runtime AVX2 detection.
            return unsafe { axpy8_avx2(dst, src, c) };
        }
    }
    axpy8_scalar(dst, src, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random f32s spanning magnitudes, signs, and
    /// exact zeros — the inputs most likely to expose reassociation.
    fn noise(len: usize, seed: u32) -> Vec<f32> {
        let mut x = seed.wrapping_mul(2654435761).wrapping_add(1);
        (0..len)
            .map(|i| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                if i % 11 == 0 {
                    0.0
                } else {
                    let mag = ((x >> 8) as f32 / (1u32 << 24) as f32) - 0.5;
                    mag * (1.0 + (i % 7) as f32 * 100.0)
                }
            })
            .collect()
    }

    #[test]
    fn dot8_paths_bit_identical_across_lengths() {
        for len in 0..40 {
            let a = noise(len, 1 + len as u32);
            let b = noise(len, 1000 + len as u32);
            let fast = dot8(&a, &b);
            let slow = dot8_scalar(&a, &b);
            assert_eq!(fast.to_bits(), slow.to_bits(), "len {len}: {fast} vs {slow}");
        }
        // Long vectors where accumulator state diverges if order differs.
        for len in [256usize, 1000, 4096 + 5] {
            let a = noise(len, 7);
            let b = noise(len, 9);
            assert_eq!(dot8(&a, &b).to_bits(), dot8_scalar(&a, &b).to_bits(), "len {len}");
        }
    }

    #[test]
    fn axpy8_paths_bit_identical_across_lengths() {
        for len in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 100, 1000] {
            let src = noise(len, 3 + len as u32);
            let mut fast = noise(len, 5 + len as u32);
            let mut slow = fast.clone();
            axpy8(&mut fast, &src, -1.75);
            axpy8_scalar(&mut slow, &src, -1.75);
            let same = fast.iter().zip(&slow).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "len {len}");
        }
    }

    #[test]
    fn dot8_matches_reference_within_tolerance() {
        // Against a plain f64 reference: the lane-structured sum is a
        // reassociation of the same products, so it should be close.
        let a = noise(333, 11);
        let b = noise(333, 13);
        let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let got = dot8(&a, &b) as f64;
        assert!((got - want).abs() <= 1e-3 * (1.0 + want.abs()), "{got} vs {want}");
    }

    #[test]
    fn dot8_scalar_lane_structure_is_as_documented() {
        // 16 elements, lane l of chunk c contributes a[8c+l]*b[8c+l]:
        // hand-evaluate the documented reduction tree.
        let a: Vec<f32> = (0..19).map(|i| (i as f32 + 1.0) * 0.5).collect();
        let b: Vec<f32> = (0..19).map(|i| (i as f32 - 9.0) * 0.25).collect();
        let mut lanes = [0.0f32; LANES];
        for c in 0..2 {
            for l in 0..LANES {
                lanes[l] += a[c * LANES + l] * b[c * LANES + l];
            }
        }
        let mut tail = 0.0f32;
        for i in 16..19 {
            tail += a[i] * b[i];
        }
        let want = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
            + tail;
        assert_eq!(dot8_scalar(&a, &b).to_bits(), want.to_bits());
    }

    #[test]
    fn axpy8_accumulates_in_place() {
        let mut dst = vec![1.0f32, 2.0, 3.0];
        axpy8(&mut dst, &[10.0, 20.0, 30.0], 0.5);
        assert_eq!(dst, vec![6.0, 12.0, 18.0]);
    }
}
