//! PJRT runtime: loads the AOT artifacts (HLO text) produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! * [`Manifest`] — parsed `artifacts/manifest.json`: every lowered
//!   configuration with its parameter shapes and I/O signature.
//! * [`Runtime`] — a PJRT CPU client plus a compile cache; hands out
//!   [`Executable`]s.
//! * [`Executable`] — a compiled `train` or `predict` graph with typed
//!   `train_step` / `predict` entry points that marshal [`ModelState`]
//!   and minibatch data into XLA literals.
//!
//! Python is never involved: the HLO text was emitted at build time and
//! `xla::HloModuleProto::from_text_file` re-parses it here (text, not
//! serialized proto — see DESIGN.md and aot.py for the version story).

pub mod manifest;
pub mod state;

pub use manifest::{ArtifactSpec, Manifest, ParamInfo};
pub use state::ModelState;

use crate::tensor::Matrix;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Which graph of an artifact to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Graph {
    Train,
    Predict,
}

/// Scalar hyperparameters fed to `train_step` (traced scalars in L2, so
/// one artifact serves any setting).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub lr: f32,
    pub momentum: f32,
    pub keep_prob: f32,
    pub lam: f32,
    pub temp: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { lr: 0.1, momentum: 0.9, keep_prob: 0.9, lam: 0.7, temp: 4.0 }
    }
}

/// PJRT client + artifact registry + compile cache.
///
/// Not `Send`: each coordinator worker thread owns its own `Runtime`
/// (client creation is ~100 ms; compilation is cached per-runtime).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: std::cell::RefCell<HashMap<(String, Graph), std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: Default::default() })
    }

    /// Compile (or fetch from cache) one graph of one artifact.
    pub fn load(&self, name: &str, graph: Graph) -> Result<Executable> {
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let key = (name.to_string(), graph);
        let exe = {
            let mut cache = self.cache.borrow_mut();
            if let Some(e) = cache.get(&key) {
                e.clone()
            } else {
                let file = match graph {
                    Graph::Train => &spec.graphs.0,
                    Graph::Predict => &spec.graphs.1,
                };
                let path = self.dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = std::rc::Rc::new(
                    self.client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?,
                );
                cache.insert(key, exe.clone());
                exe
            }
        };
        Ok(Executable { exe, spec, graph })
    }
}

/// A compiled graph with typed entry points. Holds an `Rc` to the
/// compiled executable (shared with the runtime's cache).
pub struct Executable {
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    pub spec: ArtifactSpec,
    graph: Graph,
}

impl Executable {
    /// Input width of the lowered graph.
    pub fn n_in(&self) -> usize {
        self.spec.dims[0]
    }

    /// Logit width of the lowered graph.
    pub fn n_out(&self) -> usize {
        *self.spec.dims.last().expect("artifact with no dims")
    }

    /// The static batch size the graph was lowered with — `predict`
    /// requires exactly this many rows.
    pub fn batch(&self) -> usize {
        self.spec.batch
    }

    fn mat_literal(m: &Matrix) -> Result<xla::Literal> {
        xla::Literal::vec1(&m.data)
            .reshape(&[m.rows as i64, m.cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    fn param_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data);
        if shape.len() == 1 {
            Ok(lit)
        } else {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).map_err(|e| anyhow!("reshape param: {e:?}"))
        }
    }

    /// Run one SGD step *in the artifact*; updates `state` in place and
    /// returns the minibatch loss.
    pub fn train_step(
        &self,
        state: &mut ModelState,
        x: &Matrix,
        y: &[i32],
        soft: Option<&Matrix>,
        hyper: &Hyper,
        seed: u32,
    ) -> Result<f32> {
        assert_eq!(self.graph, Graph::Train, "not a train graph");
        let spec = &self.spec;
        assert_eq!(x.rows, spec.batch, "batch mismatch");
        let n_p = spec.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(2 * n_p + 8);
        for (p, info) in state.params.iter().zip(&spec.params) {
            args.push(Self::param_literal(p, &info.shape)?);
        }
        for (m, info) in state.momenta.iter().zip(&spec.params) {
            args.push(Self::param_literal(m, &info.shape)?);
        }
        args.push(Self::mat_literal(x)?);
        args.push(xla::Literal::vec1(y));
        if spec.uses_soft_targets {
            let s = soft.ok_or_else(|| anyhow!("artifact expects soft targets"))?;
            args.push(Self::mat_literal(s)?);
        }
        args.push(xla::Literal::scalar(seed));
        args.push(xla::Literal::scalar(hyper.lr));
        args.push(xla::Literal::scalar(hyper.momentum));
        args.push(xla::Literal::scalar(hyper.keep_prob));
        if spec.uses_soft_targets {
            args.push(xla::Literal::scalar(hyper.lam));
            args.push(xla::Literal::scalar(hyper.temp));
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute train_step: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let mut outs = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if outs.len() != 2 * n_p + 1 {
            return Err(anyhow!("expected {} outputs, got {}", 2 * n_p + 1, outs.len()));
        }
        let loss: f32 = outs
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        for (i, lit) in outs.drain(..).enumerate() {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("param out {i}: {e:?}"))?;
            if i < n_p {
                state.params[i].copy_from_slice(&v);
            } else {
                state.momenta[i - n_p].copy_from_slice(&v);
            }
        }
        Ok(loss)
    }

    /// Run the forward pass; `x` must have `spec.batch` rows (use
    /// [`Executable::predict_all`] for arbitrary row counts).
    pub fn predict(&self, state: &ModelState, x: &Matrix) -> Result<Matrix> {
        assert_eq!(self.graph, Graph::Predict, "not a predict graph");
        let spec = &self.spec;
        assert_eq!(x.rows, spec.batch);
        let mut args: Vec<xla::Literal> = Vec::with_capacity(spec.params.len() + 1);
        for (p, info) in state.params.iter().zip(&spec.params) {
            args.push(Self::param_literal(p, &info.shape)?);
        }
        args.push(Self::mat_literal(x)?);
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute predict: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let logits = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let out = spec.dims[spec.dims.len() - 1];
        let v = logits.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok(Matrix::from_vec(spec.batch, out, v))
    }

    /// Batched prediction over any number of rows (pads the tail batch).
    pub fn predict_all(&self, state: &ModelState, x: &Matrix) -> Result<Matrix> {
        let b = self.spec.batch;
        let out_dim = self.spec.dims[self.spec.dims.len() - 1];
        let mut out = Matrix::zeros(x.rows, out_dim);
        let mut chunk = Matrix::zeros(b, x.cols);
        let mut r = 0;
        while r < x.rows {
            let take = b.min(x.rows - r);
            for i in 0..b {
                let src = if i < take { r + i } else { r + take - 1 }; // pad w/ last row
                chunk.row_mut(i).copy_from_slice(x.row(src));
            }
            let logits = self.predict(state, &chunk)?;
            for i in 0..take {
                out.row_mut(r + i).copy_from_slice(logits.row(i));
            }
            r += take;
        }
        Ok(out)
    }
}
