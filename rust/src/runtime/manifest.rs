//! `artifacts/manifest.json` parsing — the contract between `aot.py`
//! and the Rust coordinator.
//!
//! This is a **compat shim** over the model subsystem: an
//! [`ArtifactSpec`] carries the PJRT-specific extras (graph file names,
//! per-tensor init stds, lowered batch size) and converts into the
//! canonical [`ModelSpec`] via [`ArtifactSpec::to_model_spec`]. New
//! code should take `ModelSpec`/`ModelBundle`; only the artifact
//! runtime needs the manifest.

use crate::model::{Method, ModelSpec};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One stored parameter tensor of an artifact.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub init_std: f32,
}

impl ParamInfo {
    pub fn count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered configuration (a `NetSpec` on the Python side).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub method: Method,
    pub dims: Vec<usize>,
    pub budgets: Vec<usize>,
    pub batch: usize,
    pub seed_base: u32,
    pub uses_soft_targets: bool,
    pub params: Vec<ParamInfo>,
    pub stored_params: usize,
    pub virtual_params: usize,
    /// (train file, predict file) relative to the artifact dir.
    pub graphs: (String, String),
    /// Nominal compression factor (1.0 for expansion configs).
    pub compression: f64,
    /// Fig. 4 expansion factor, when applicable.
    pub expansion: Option<usize>,
    /// Equivalent hidden width (NN/DK baselines).
    pub hidden_equivalent: Option<usize>,
}

impl ArtifactSpec {
    /// The canonical model identity of this artifact — everything the
    /// rest of the system needs; the manifest extras (graph files,
    /// init stds) stay behind in the shim.
    pub fn to_model_spec(&self) -> ModelSpec {
        ModelSpec {
            name: self.name.clone(),
            method: self.method,
            dims: self.dims.clone(),
            budgets: self.budgets.clone(),
            seed_base: self.seed_base,
            batch: self.batch.max(1),
        }
    }
}

/// The full artifact registry.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub n_in: usize,
    by_name: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let n_in = v.req_f64("n_in").map_err(|e| anyhow!(e))? as usize;
        let mut by_name = BTreeMap::new();
        for a in v.req_arr("artifacts").map_err(|e| anyhow!(e))? {
            let spec = Self::parse_artifact(a).map_err(|e| anyhow!("artifact: {e}"))?;
            by_name.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { n_in, by_name })
    }

    fn parse_artifact(a: &Json) -> Result<ArtifactSpec, String> {
        let usize_arr = |key: &str| -> Result<Vec<usize>, String> {
            Ok(a.req_arr(key)?.iter().filter_map(Json::as_usize).collect())
        };
        let params = a
            .req_arr("params")?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.req_str("name")?.to_string(),
                    shape: p.req_arr("shape")?.iter().filter_map(Json::as_usize).collect(),
                    init_std: p.req_f64("init_std")? as f32,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let graphs = a.get("graphs").ok_or("missing graphs")?;
        Ok(ArtifactSpec {
            name: a.req_str("name")?.to_string(),
            method: Method::parse(a.req_str("method")?).map_err(|e| e.to_string())?,
            dims: usize_arr("dims")?,
            budgets: usize_arr("budgets")?,
            batch: a.req_f64("batch")? as usize,
            seed_base: a.req_f64("seed_base")? as u32,
            uses_soft_targets: a
                .get("uses_soft_targets")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            stored_params: a.req_f64("stored_params")? as usize,
            virtual_params: a.req_f64("virtual_params")? as usize,
            params,
            graphs: (
                graphs.req_str("train")?.to_string(),
                graphs.req_str("predict")?.to_string(),
            ),
            compression: a.get("compression").and_then(Json::as_f64).unwrap_or(1.0),
            expansion: a.get("expansion").and_then(Json::as_usize),
            hidden_equivalent: a.get("hidden_equivalent").and_then(Json::as_usize),
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.by_name.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(String::as_str)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.by_name.values()
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "n_in": 784, "eval_batch": 200,
      "artifacts": [{
        "name": "hashnet_3l_h32_o10_c1-4", "method": "hashnet",
        "dims": [784, 32, 10], "budgets": [6280, 83], "batch": 50,
        "seed_base": 2654435769, "uses_soft_targets": false,
        "depth": 3, "hidden": 32, "out": 10, "compression": 0.25,
        "compression_name": "1-4", "virtual_params": 25450,
        "params": [
          {"name": "w0", "shape": [6280], "init_std": 0.0504},
          {"name": "w1", "shape": [83], "init_std": 0.246}
        ],
        "stored_params": 6363, "raw_params": 6363,
        "train_inputs": ["w0","w1","m_w0","m_w1","x","y","seed","lr","momentum","keep_prob"],
        "predict_inputs": ["w0","w1","x"],
        "graphs": {"train": "a.train.hlo.txt", "predict": "a.predict.hlo.txt"}
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.n_in, 784);
        assert_eq!(m.len(), 1);
        let a = m.get("hashnet_3l_h32_o10_c1-4").unwrap();
        assert_eq!(a.method, Method::Hashnet);
        assert_eq!(a.dims, vec![784, 32, 10]);
        assert_eq!(a.params.len(), 2);
        assert_eq!(a.params[0].count(), 6280);
        assert_eq!(a.graphs.0, "a.train.hlo.txt");
        assert!(!a.uses_soft_targets);
        assert_eq!(a.compression, 0.25);
        assert_eq!(a.expansion, None);
    }

    #[test]
    fn unknown_method_is_a_clean_parse_error() {
        let text = SAMPLE.replace("\"hashnet\"", "\"blobnet\"");
        let err = Manifest::parse(&text).unwrap_err();
        assert!(format!("{err:#}").contains("unknown method 'blobnet'"), "{err:#}");
    }

    #[test]
    fn artifact_converts_to_model_spec() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let spec = m.get("hashnet_3l_h32_o10_c1-4").unwrap().to_model_spec();
        spec.validate().unwrap();
        assert_eq!(spec.method, Method::Hashnet);
        assert_eq!(spec.dims, vec![784, 32, 10]);
        assert_eq!(spec.budgets, vec![6280, 83]);
        assert_eq!(spec.batch, 50);
        // storage accounting agrees with the manifest's own numbers
        assert_eq!(spec.stored_params(), 6363);
    }

    #[test]
    fn real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(!m.is_empty());
            for a in m.iter() {
                assert_eq!(a.dims.len() - 1, a.budgets.len(), "{}", a.name);
                assert!(!a.params.is_empty(), "{}", a.name);
                if a.method == Method::Hashnet {
                    let stored: usize = a.params.iter().map(ParamInfo::count).sum();
                    assert_eq!(stored, a.stored_params, "{}", a.name);
                }
            }
        }
    }
}
