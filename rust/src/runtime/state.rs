//! Host-side model state: the stored parameter vectors + momentum
//! buffers of one artifact, with init, checkpointing and accounting.
//!
//! Like the manifest, this is a **compat shim**: a `ModelState` is the
//! PJRT-side view of the parameters (split tensors + momenta). The
//! canonical persistence format is [`crate::model::ModelBundle`];
//! [`ModelState::to_bundle`] / [`ModelState::from_bundle`] convert
//! losslessly (momenta are training state and are not persisted).

use super::manifest::ArtifactSpec;
use crate::model::{ModelBundle, ModelError};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::path::Path;

impl ArtifactSpec {
    /// He-init a state from this artifact's per-tensor `init_std`s —
    /// the one sanctioned construction path outside checkpoint/bundle
    /// loading (wraps [`ModelState::init`]).
    pub fn init_state(&self, seed: u64) -> ModelState {
        ModelState::init(self, seed)
    }

    /// Resolve this artifact's parameters as a [`ModelBundle`]: from a
    /// checkpoint or bundle file when given, else seed-initialized.
    /// The shape check happens in the bundle conversion, so a wrong
    /// file is a clean [`ModelError`] instead of a late panic.
    pub fn resolve_bundle(
        &self,
        params_file: Option<&Path>,
        seed: u64,
    ) -> Result<ModelBundle> {
        match params_file {
            Some(p) => {
                let state = ModelState::load_any(p)
                    .map_err(|e| anyhow!("loading params {}: {e:#}", p.display()))?;
                Ok(state.to_bundle(self)?)
            }
            None => Ok(self.init_state(seed).to_bundle(self)?),
        }
    }
}

/// Parameters + momenta for one artifact (layouts match the manifest).
#[derive(Debug, Clone)]
pub struct ModelState {
    pub params: Vec<Vec<f32>>,
    pub momenta: Vec<Vec<f32>>,
}

impl ModelState {
    /// He-init from the manifest's `init_std`s, deterministic in `seed`.
    pub fn init(spec: &ArtifactSpec, seed: u64) -> ModelState {
        let mut rng = Pcg32::new(seed, 0x1217);
        let params = spec
            .params
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.count()];
                rng.fill_normal(&mut v, p.init_std);
                v
            })
            .collect::<Vec<_>>();
        let momenta = params.iter().map(|p| vec![0.0; p.len()]).collect();
        ModelState { params, momenta }
    }

    /// Stored parameter count (== manifest stored_params except RER).
    pub fn n_params(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }

    /// Serialized checkpoint size in bytes (f32 params only — momenta
    /// are training state, not model storage).
    pub fn storage_bytes(&self) -> usize {
        4 * self.n_params()
    }

    /// Package the parameters as a validated [`ModelBundle`] under the
    /// artifact's [`crate::model::ModelSpec`] — the conversion every
    /// caller above the runtime shim uses.
    pub fn to_bundle(&self, spec: &ArtifactSpec) -> Result<ModelBundle, ModelError> {
        ModelBundle::new(spec.to_model_spec(), self.params.clone())
    }

    /// The inverse of [`ModelState::to_bundle`]: adopt a bundle's
    /// tensors as artifact state (momenta reset to zero).
    pub fn from_bundle(bundle: &ModelBundle) -> ModelState {
        let params = bundle.params.clone();
        let momenta = params.iter().map(|p| vec![0.0; p.len()]).collect();
        ModelState { params, momenta }
    }

    /// Load parameters from either format: a legacy `HNCK` checkpoint
    /// or a `HNMB` model bundle (whose spec is ignored here — shape
    /// validation happens when the state meets a spec).
    pub fn load_any(path: &Path) -> Result<ModelState> {
        let mut magic = [0u8; 4];
        {
            let mut f = std::fs::File::open(path)?;
            f.read_exact(&mut magic)
                .map_err(|_| anyhow!("file too short for any model format"))?;
        }
        if &magic == b"HNMB" {
            let bundle = ModelBundle::load(path)?;
            Ok(ModelState::from_bundle(&bundle))
        } else {
            ModelState::load(path)
        }
    }

    /// Save params (not momenta) in a simple binary format:
    /// magic, #tensors, then per tensor: len(u32) + f32 data.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"HNCK")?;
        f.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for p in &self.params {
            f.write_all(&(p.len() as u32).to_le_bytes())?;
            let bytes: Vec<u8> = p.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    /// Load params saved by [`ModelState::save`]; momenta reset to zero.
    pub fn load(path: &Path) -> Result<ModelState> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..4] != b"HNCK" {
            return Err(anyhow!("bad checkpoint magic"));
        }
        let n_tensors = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let mut off = 8;
        let mut params = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            if off + 4 > bytes.len() {
                return Err(anyhow!("truncated checkpoint"));
            }
            let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            if off + 4 * len > bytes.len() {
                return Err(anyhow!("truncated checkpoint tensor"));
            }
            let mut v = Vec::with_capacity(len);
            for i in 0..len {
                v.push(f32::from_le_bytes(bytes[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
            }
            off += 4 * len;
            params.push(v);
        }
        let momenta = params.iter().map(|p| vec![0.0; p.len()]).collect();
        Ok(ModelState { params, momenta })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, ParamInfo};

    fn spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            method: crate::model::Method::Hashnet,
            dims: vec![8, 4, 2],
            budgets: vec![9, 3],
            batch: 2,
            seed_base: 1,
            uses_soft_targets: false,
            params: vec![
                ParamInfo { name: "w0".into(), shape: vec![9], init_std: 0.5 },
                ParamInfo { name: "w1".into(), shape: vec![3], init_std: 0.9 },
            ],
            stored_params: 12,
            virtual_params: 46,
            graphs: ("a".into(), "b".into()),
            compression: 0.25,
            expansion: None,
            hidden_equivalent: None,
        }
    }

    #[test]
    fn init_deterministic_and_scaled() {
        let a = ModelState::init(&spec(), 7);
        let b = ModelState::init(&spec(), 7);
        let c = ModelState::init(&spec(), 8);
        assert_eq!(a.params, b.params);
        assert_ne!(a.params, c.params);
        assert_eq!(a.n_params(), 12);
        assert!(a.momenta.iter().all(|m| m.iter().all(|&v| v == 0.0)));
        let std0 = crate::util::stddev(&a.params[0].iter().map(|&v| v as f64).collect::<Vec<_>>());
        assert!(std0 > 0.2 && std0 < 0.9, "std {std0}");
    }

    #[test]
    fn save_load_roundtrip() {
        let st = ModelState::init(&spec(), 3);
        let path = std::env::temp_dir().join(format!("hn_ck_{}.bin", std::process::id()));
        st.save(&path).unwrap();
        let st2 = ModelState::load(&path).unwrap();
        assert_eq!(st.params, st2.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join(format!("hn_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"nope").unwrap();
        assert!(ModelState::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn keeps_unused_import_warning_away() {
        // touch Manifest so the import is used in tests
        assert!(Manifest::default().is_empty());
    }

    #[test]
    fn bundle_conversion_roundtrips() {
        let st = ModelState::init(&spec(), 4);
        let bundle = st.to_bundle(&spec()).unwrap();
        assert_eq!(bundle.spec.name, "t");
        let back = ModelState::from_bundle(&bundle);
        assert_eq!(back.params, st.params);
        assert!(back.momenta.iter().all(|m| m.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn load_any_reads_both_formats() {
        let st = ModelState::init(&spec(), 6);
        let dir = std::env::temp_dir();
        let ckpt = dir.join(format!("hn_any_ck_{}.bin", std::process::id()));
        let bnd = dir.join(format!("hn_any_mb_{}.hnb", std::process::id()));
        st.save(&ckpt).unwrap();
        st.to_bundle(&spec()).unwrap().save(&bnd).unwrap();
        assert_eq!(ModelState::load_any(&ckpt).unwrap().params, st.params);
        assert_eq!(ModelState::load_any(&bnd).unwrap().params, st.params);
        std::fs::remove_file(&ckpt).ok();
        std::fs::remove_file(&bnd).ok();
    }

    #[test]
    fn resolve_bundle_seed_inits_without_file() {
        let b = spec().resolve_bundle(None, 3).unwrap();
        assert_eq!(b.params, ModelState::init(&spec(), 3).params);
    }
}
