//! `hashednets` CLI — the Layer-3 entry point.
//!
//! Subcommands:
//!   train    — train a model and save it as a self-describing bundle.
//!              Two sources for the model identity:
//!                --config <artifact>        (manifest + PJRT artifact path)
//!                --method/--dims/--budgets  (pure ModelSpec, native engine,
//!                                            no artifacts required)
//!              `--threads N` parallelizes the native backward (0 = auto);
//!              `--reduction ordered` makes the result bit-identical
//!              across thread counts (default `fast`); `--block-rows`
//!              tunes the ordered-mode block height. The same three
//!              flags apply to `repro` and `hpo`.
//!   eval     — evaluate a bundle (--bundle m.hnb, native) or an
//!              artifact + checkpoint (--config/--checkpoint, PJRT);
//!              `--frontier` prints the size/accuracy table across
//!              quantization codecs (f32, int8, codebook K)
//!   repro    — regenerate a paper experiment (fig2|fig3|table1|table2|fig4,
//!              plus the tile_sweep accuracy-vs-tile-size extension);
//!              without artifacts/ the non-DK cells run on the native
//!              engine (specs re-derived by coordinator::sizing), so the
//!              grids work on a fresh checkout with no Python toolchain
//!   hpo      — random-search hyperparameters for an artifact
//!   serve    — batched inference server over bundles (--bundle a.hnb,b.hnb)
//!              and/or manifest artifacts (--config a,b); hot-(re)load
//!              models at runtime via {"cmd":"load"|"unload"|"reload"}
//!   compress — dense → HashedNet in one call (compress_network):
//!              --bundle dense.hnb --budgets k0,k1 (or the manifest pair
//!              --from nn_… --to hashnet_… --checkpoint ck);
//!              `--method hashed_tile [--tile THxTW]` targets the
//!              block-structured representation instead; add
//!              `--quantize int8|codebook[K]` to re-encode the saved
//!              tensors with a v2 quantization codec
//!   list     — manifest artifacts + *.hnb bundles with method, storage,
//!              compression ratio and bundle version
//!   selftest — artifact ↔ native engine cross-validation
//!   smoke    — tiny end-to-end train → bundle → serve → hot-load loop
//!
//! Unknown `--options` warn on stderr; add `--strict` to make them
//! errors.

use anyhow::{anyhow, Result};
use hashednets::coordinator::{hpo, repro, trainer};
use hashednets::data::{generate, Kind, Split};
use hashednets::model::{BagMode, Method, ModelBundle, ModelSpec, QuantSpec, BUNDLE_VERSION};
use hashednets::nn::{EmbedBag, Network, TrainOptions};
use hashednets::runtime::{Graph, Hyper, Manifest, ModelState, Runtime};
use hashednets::serve::{serve, Backend, Client, ModelConfig, PollerKind, ServeOptions, Server};
use hashednets::util::args::Args;
use hashednets::util::rng::Pcg32;
use std::path::{Path, PathBuf};

const KNOWN_TRAIN: &[&str] = &[
    "config", "artifacts", "dataset", "n-train", "n-test", "epochs", "lr", "momentum",
    "keep-prob", "lam", "temp", "seed", "teacher", "patience", "save", "method", "dims",
    "budgets", "compression", "name", "seed-base", "batch", "spec-json", "threads",
    "block-rows", "reduction", "bag-mode", "tile", "strict",
];
const KNOWN_EVAL: &[&str] = &[
    "config", "artifacts", "checkpoint", "bundle", "dataset", "n-test", "seed", "frontier",
    "strict",
];
const KNOWN_REPRO: &[&str] = &[
    "experiment", "artifacts", "results", "hidden", "exp-base", "n-train", "n-test", "epochs",
    "teacher-epochs", "workers", "seed", "scale", "threads", "block-rows", "reduction", "strict",
];
const KNOWN_HPO: &[&str] = &[
    "config", "artifacts", "dataset", "n-train", "epochs", "trials", "seed", "threads",
    "block-rows", "reduction", "strict",
];
const KNOWN_SERVE: &[&str] = &[
    "config", "bundle", "checkpoint", "artifacts", "addr", "backend", "workers",
    "max-wait-us", "max-requests", "max-pending", "timeout-ms", "poller", "strict",
];
const KNOWN_COMPRESS: &[&str] = &[
    "from", "to", "checkpoint", "artifacts", "save", "bundle", "budgets", "name", "quantize",
    "method", "tile", "strict",
];
const KNOWN_LIST: &[&str] = &["artifacts", "strict"];
const KNOWN_SELFTEST: &[&str] = &["config", "artifacts", "strict"];
const KNOWN_SMOKE: &[&str] = &["dir", "keep", "strict"];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    type Cmd = fn(&Args) -> Result<()>;
    let (cmd, known): (Cmd, &[&str]) = match args.subcommand.as_deref() {
        Some("train") => (cmd_train, KNOWN_TRAIN),
        Some("eval") => (cmd_eval, KNOWN_EVAL),
        Some("repro") => (cmd_repro, KNOWN_REPRO),
        Some("hpo") => (cmd_hpo, KNOWN_HPO),
        Some("serve") => (cmd_serve, KNOWN_SERVE),
        Some("compress") => (cmd_compress, KNOWN_COMPRESS),
        Some("list") => (cmd_list, KNOWN_LIST),
        Some("selftest") => (cmd_selftest, KNOWN_SELFTEST),
        Some("smoke") => (cmd_smoke, KNOWN_SMOKE),
        _ => {
            eprintln!(
                "usage: hashednets <train|eval|repro|hpo|serve|compress|list|selftest|smoke> [--options]"
            );
            eprintln!("see rust/src/main.rs docs for the full flag list");
            return Ok(());
        }
    };
    check_flags(&args, known)?;
    cmd(&args)
}

/// Warn (or, with `--strict`, error) on options no subcommand handler
/// will ever read — `Args::parse` itself accepts anything.
fn check_flags(args: &Args, known: &[&str]) -> Result<()> {
    let unknown = args.unknown_keys(known);
    if unknown.is_empty() {
        return Ok(());
    }
    if args.has_flag("strict") {
        return Err(anyhow!("unknown option(s): --{}", unknown.join(", --")));
    }
    for k in &unknown {
        eprintln!("warning: ignoring unknown option --{k} (use --strict to make this an error)");
    }
    Ok(())
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn dataset_kind(args: &Args) -> Result<Kind> {
    let name = args.get_or("dataset", "basic");
    Kind::parse(name).ok_or_else(|| anyhow!("unknown dataset '{name}'"))
}

fn hyper_from(args: &Args, base: Hyper) -> Hyper {
    Hyper {
        lr: args.get_f32("lr", base.lr),
        momentum: args.get_f32("momentum", base.momentum),
        keep_prob: args.get_f32("keep-prob", base.keep_prob),
        lam: args.get_f32("lam", base.lam),
        temp: args.get_f32("temp", base.temp),
    }
}

/// Training execution policy from the shared `--threads N`
/// (0 = auto), `--block-rows R` and `--reduction fast|ordered` flags —
/// one knob set governing the whole training path (`train`, `repro`,
/// `hpo`), resolved once here and threaded down to `Layer::backward`.
fn train_options_from(args: &Args) -> Result<TrainOptions> {
    let reduction = args.get_or("reduction", "fast");
    let deterministic = match reduction {
        "fast" => false,
        "ordered" => true,
        other => return Err(anyhow!("--reduction must be fast|ordered, got '{other}'")),
    };
    Ok(TrainOptions {
        threads: args.get_usize("threads", 1),
        block_rows: args.get_usize("block-rows", 0),
        deterministic,
    })
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|e| anyhow!("bad number '{}': {e}", t.trim()))
        })
        .collect()
}

/// Build a [`ModelSpec`] straight from CLI options — the manifest-free
/// path: `--spec-json '{…}'`, or `--method --dims [--budgets]`
/// (budgets default to `--compression` × the virtual size per layer).
fn spec_from_args(args: &Args) -> Result<ModelSpec> {
    if let Some(text) = args.get("spec-json") {
        return Ok(ModelSpec::from_json_str(text)?);
    }
    let method_name = args.get_or("method", "hashnet");
    if method_name == "hashed_embedding" {
        return embedding_spec_from_args(args);
    }
    if method_name == "hashed_tile" {
        return tile_spec_from_args(args);
    }
    let method = Method::parse(method_name)?;
    let dims = parse_usize_list(args.get("dims").ok_or_else(|| {
        anyhow!("--dims 784,100,10 required (or --config <artifact> / --spec-json)")
    })?)?;
    if dims.len() < 2 {
        return Err(anyhow!("--dims needs at least input and output widths"));
    }
    let budgets = match args.get("budgets") {
        Some(b) => parse_usize_list(b)?,
        None => {
            let c = args.get_f32("compression", 0.125) as f64;
            (0..dims.len() - 1)
                .map(|l| {
                    let (m, n) = (dims[l], dims[l + 1]);
                    match method {
                        Method::Nn | Method::Dk => n * m + n,
                        _ => ((c * (n * (m + 1)) as f64).round() as usize).max(1),
                    }
                })
                .collect()
        }
    };
    let name = match args.get("name") {
        Some(n) => n.to_string(),
        None => format!(
            "{method}_{}",
            dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
        ),
    };
    Ok(ModelSpec::new(
        name,
        method,
        dims,
        budgets,
        args.get_u64("seed-base", hashednets::hash::DEFAULT_SEED_BASE as u64) as u32,
        args.get_usize("batch", 50),
    )?)
}

/// `--method hashed_embedding --dims <num_categories>,<dim>`: the
/// bucket budget comes from a single `--budgets k` (default
/// `--compression` × the virtual table size) and `--bag-mode sum|mean`
/// picks the bag reduction.
fn embedding_spec_from_args(args: &Args) -> Result<ModelSpec> {
    let dims = parse_usize_list(args.get("dims").ok_or_else(|| {
        anyhow!("--dims <num_categories>,<dim> required for hashed_embedding")
    })?)?;
    let [nc, dim] = dims[..] else {
        return Err(anyhow!(
            "hashed_embedding takes exactly --dims <num_categories>,<dim>, got {} entries",
            dims.len()
        ));
    };
    let k = match args.get("budgets") {
        Some(b) => {
            let ks = parse_usize_list(b)?;
            let [k] = ks[..] else {
                return Err(anyhow!("hashed_embedding takes a single --budgets k"));
            };
            k
        }
        None => {
            let c = args.get_f32("compression", 0.125) as f64;
            ((c * (nc * dim) as f64).round() as usize).max(1)
        }
    };
    let mode = BagMode::parse(args.get_or("bag-mode", "sum"))?;
    let name = match args.get("name") {
        Some(n) => n.to_string(),
        None => format!("embed_{nc}x{dim}_{}", mode.as_str()),
    };
    Ok(ModelSpec::embedding(
        name,
        nc,
        dim,
        k,
        mode,
        args.get_u64("seed-base", hashednets::hash::DEFAULT_SEED_BASE as u64) as u32,
        args.get_usize("batch", 50),
    )?)
}

/// `--method hashed_tile --dims … [--tile THxTW]`: block-structured
/// hashing. Identical sizing rules to the per-cell methods, except each
/// default budget is clamped up to the tile area so the spec validates
/// at extreme compression ratios.
fn tile_spec_from_args(args: &Args) -> Result<ModelSpec> {
    let tile = Method::parse_tile(args.get_or("tile", "1x8"))?;
    let dims = parse_usize_list(args.get("dims").ok_or_else(|| {
        anyhow!("--dims 784,100,10 required (or --config <artifact> / --spec-json)")
    })?)?;
    if dims.len() < 2 {
        return Err(anyhow!("--dims needs at least input and output widths"));
    }
    let budgets = match args.get("budgets") {
        Some(b) => parse_usize_list(b)?,
        None => {
            let c = args.get_f32("compression", 0.125) as f64;
            (0..dims.len() - 1)
                .map(|l| {
                    let (m, n) = (dims[l], dims[l + 1]);
                    ((c * (n * (m + 1)) as f64).round() as usize).max(tile.0 * tile.1)
                })
                .collect()
        }
    };
    let name = match args.get("name") {
        Some(n) => n.to_string(),
        None => format!(
            "hashed_tile{}x{}_{}",
            tile.0,
            tile.1,
            dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
        ),
    };
    Ok(ModelSpec::new(
        name,
        Method::HashedTile { tile },
        dims,
        budgets,
        args.get_u64("seed-base", hashednets::hash::DEFAULT_SEED_BASE as u64) as u32,
        args.get_usize("batch", 50),
    )?)
}

/// Deterministic synthetic bag workload for the embedding demo paths:
/// `n` bags of 1–8 uniform-random category ids in CSR form.
fn synth_bags(rng: &mut Pcg32, num_categories: usize, n: usize) -> (Vec<u32>, Vec<u32>) {
    let mut indices = Vec::new();
    let mut offsets = Vec::with_capacity(n);
    for _ in 0..n {
        offsets.push(indices.len() as u32);
        let len = 1 + (rng.next_u32() % 8) as usize;
        for _ in 0..len {
            indices.push(rng.next_u32() % num_categories as u32);
        }
    }
    (indices, offsets)
}

fn save_bundle(bundle: &ModelBundle, out: &str) -> Result<()> {
    bundle.save(Path::new(out))?;
    if bundle.is_quantized() {
        println!(
            "model bundle -> {out} ({} stored params, {} B encoded / {} B as f32, format v{})",
            bundle.n_params(),
            bundle.encoded_param_bytes(),
            bundle.param_bytes(),
            bundle.version
        );
    } else {
        println!(
            "model bundle -> {out} ({} stored params, {} B payload, format v{})",
            bundle.n_params(),
            bundle.param_bytes(),
            bundle.version
        );
    }
    Ok(())
}

/// `--quantize f32|int8|codebook[K]`: re-encode every tensor with the
/// requested codec before saving. Returns the bundle unchanged when the
/// flag is absent. The quantized bundle carries dequantized `params`, so
/// anything downstream (reports, eval) sees exactly what a loader will.
fn apply_quantize(args: &Args, bundle: ModelBundle) -> Result<ModelBundle> {
    let Some(q) = args.get("quantize") else { return Ok(bundle) };
    let spec = QuantSpec::parse(q)?;
    let quantized = bundle.quantize(spec)?;
    println!(
        "quantize {}: {} B -> {} B ({:.2}x payload)",
        spec.name(),
        bundle.param_bytes(),
        quantized.encoded_param_bytes(),
        bundle.param_bytes() as f64 / quantized.encoded_param_bytes().max(1) as f64
    );
    Ok(quantized)
}

fn cmd_train(args: &Args) -> Result<()> {
    let Some(artifact) = args.get("config") else {
        return cmd_train_native(args);
    };
    let rt = Runtime::open(artifacts_dir(args))?;
    let spec = rt.manifest.get(artifact).ok_or_else(|| anyhow!("unknown artifact"))?.clone();
    let method_default = repro::default_hyper(spec.method);
    let dataset = dataset_kind(args)?;
    let cfg = trainer::TrainConfig {
        artifact: artifact.to_string(),
        dataset,
        n_train: args.get_usize("n-train", 3000),
        n_test: args.get_usize("n-test", 2000),
        epochs: args.get_usize("epochs", 12),
        hyper: hyper_from(args, method_default),
        seed: args.get_u64("seed", 0x5EED),
        teacher: args.get("teacher").map(String::from),
        patience: args.get_usize("patience", 0),
        train: train_options_from(args)?,
    };
    // DK flow: train/load teacher, build soft targets
    let soft = if spec.uses_soft_targets {
        let teacher = cfg
            .teacher
            .clone()
            .ok_or_else(|| anyhow!("--teacher <artifact> required for DK methods"))?;
        let train = generate(dataset, Split::Train, cfg.n_train, cfg.seed);
        eprintln!("training teacher {teacher}...");
        let tstate =
            trainer::train_teacher(&rt, &teacher, &train, cfg.epochs, cfg.seed, &cfg.train)?;
        Some(trainer::soft_targets(&rt, &teacher, &tstate, &train.images, cfg.hyper.temp)?)
    } else {
        None
    };
    let res = trainer::run(&rt, &cfg, soft.as_ref())?;
    println!(
        "{artifact} on {}: test error {:.2}% (val {:.2}%), {} stored params, {:.1}s ({:.0} steps/s)",
        dataset.name(), res.test_error * 100.0, res.val_error * 100.0,
        res.stored_params, res.wall_s, res.steps_per_s
    );
    if let Some(out) = args.get("save") {
        save_bundle(&res.bundle()?, out)?;
    }
    Ok(())
}

/// `train` without `--config`: the model identity comes entirely from
/// the CLI spec and training runs on the native engine — spec to
/// checkpointed bundle with zero artifacts.
fn cmd_train_native(args: &Args) -> Result<()> {
    let spec = spec_from_args(args)?;
    if spec.embedding_shape().is_some() {
        return cmd_train_embedding(args, &spec);
    }
    let dataset = dataset_kind(args)?;
    let cfg = trainer::TrainConfig {
        artifact: spec.name.clone(),
        dataset,
        n_train: args.get_usize("n-train", 3000),
        n_test: args.get_usize("n-test", 2000),
        epochs: args.get_usize("epochs", 12),
        hyper: hyper_from(args, Hyper { lam: 1.0, ..Hyper::default() }),
        seed: args.get_u64("seed", 0x5EED),
        teacher: None,
        patience: args.get_usize("patience", 0),
        train: train_options_from(args)?,
    };
    let res = trainer::run_native(&spec, &cfg)?;
    println!(
        "{} [native, {} thread{}] on {}: test error {:.2}% (val {:.2}%), {} stored / {} virtual params, {:.1}s ({:.0} steps/s)",
        spec.name,
        res.threads,
        if res.threads == 1 { "" } else { "s" },
        dataset.name(),
        res.test_error * 100.0,
        res.val_error * 100.0,
        res.stored_params,
        res.virtual_params,
        res.wall_s,
        res.steps_per_s
    );
    if let Some(out) = args.get("save") {
        save_bundle(&res.bundle()?, out)?;
    }
    Ok(())
}

/// `train --method hashed_embedding`: a self-contained sparse-lookup
/// demo with no image dataset. A wider-budget "teacher" bag (different
/// seed base) defines the regression targets; the student learns to
/// reproduce its bag reductions through the hash collisions via the
/// Eq. 12-style sequential bucket accumulation in
/// [`EmbedBag::sgd_step`]. Resident parameters stay `k` floats while
/// the virtual table is `num_categories × dim`.
fn cmd_train_embedding(args: &Args, spec: &ModelSpec) -> Result<()> {
    let (nc, dim, k, mode) = spec.embedding_shape().expect("caller checked");
    let train = train_options_from(args)?;
    let epochs = args.get_usize("epochs", 12);
    let n_train = args.get_usize("n-train", 3000);
    let seed = args.get_u64("seed", 0x5EED);
    let lr = args.get_f32("lr", 0.05);
    let batch = spec.batch.max(1);

    let mut bag = EmbedBag::new(nc, dim, k, mode, spec.seed_base);
    bag.init(&mut Pcg32::new(seed, 0xE3BA));
    let teacher_k = (k.saturating_mul(4)).min(nc.saturating_mul(dim)).max(k);
    let mut teacher = EmbedBag::new(nc, dim, teacher_k, mode, spec.seed_base ^ 0x5A5A_5A5A);
    teacher.init(&mut Pcg32::new(seed ^ 1, 0x7EAC));

    let t0 = std::time::Instant::now();
    let steps = (n_train / batch).max(1);
    let mut first_loss = 0.0f64;
    let mut last_loss = 0.0f64;
    for epoch in 0..epochs {
        let mut rng = Pcg32::new(seed.wrapping_add(epoch as u64), 0xBA65);
        let mut total = 0.0f64;
        for _ in 0..steps {
            let (indices, offsets) = synth_bags(&mut rng, nc, batch);
            let targets = teacher.forward(&indices, &offsets);
            total += bag.sgd_step(&indices, &offsets, &targets, lr, &train) as f64;
        }
        let mean = total / steps as f64;
        if epoch == 0 {
            first_loss = mean;
        }
        last_loss = mean;
        println!("epoch {epoch}: mean bag loss {mean:.5}");
    }
    println!(
        "{} [native, {} thread{}]: loss {first_loss:.5} -> {last_loss:.5} over {epochs} epochs, \
         {} stored / {} virtual params, {:.1}s",
        spec.name,
        train.resolved_threads(),
        if train.resolved_threads() == 1 { "" } else { "s" },
        spec.stored_params(),
        spec.virtual_params(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(out) = args.get("save") {
        save_bundle(&bag.to_bundle(spec)?, out)?;
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    if let Some(bpath) = args.get("bundle") {
        let bundle = ModelBundle::load(Path::new(bpath))?;
        if bundle.spec.embedding_shape().is_some() {
            // No image dataset for embeddings: run the deterministic
            // synthetic bag workload through the served representation
            // to prove the bundle round trip and report throughput.
            let bag = EmbedBag::from_bundle(&bundle)?;
            let n = args.get_usize("n-test", 2000);
            let mut rng = Pcg32::new(args.get_u64("seed", 0x5EED), 0xE7A1);
            let (indices, offsets) = synth_bags(&mut rng, bag.num_categories, n);
            let t0 = std::time::Instant::now();
            let z = bag.forward(&indices, &offsets);
            let wall = t0.elapsed().as_secs_f64();
            let mean_sq = z.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
                / z.rows.max(1) as f64;
            println!(
                "{} (bundle v{}): {} bags ({} ids) through the {}x{} virtual table \
                 ({} buckets resident) in {:.1} ms [native engine], mean ||bag||^2 {:.4}",
                bundle.spec.name,
                bundle.version,
                n,
                indices.len(),
                bag.num_categories,
                bag.dim,
                bag.k(),
                wall * 1e3,
                mean_sq
            );
            return Ok(());
        }
        let net = Network::from_bundle(&bundle)?;
        let ds = generate(
            dataset_kind(args)?,
            Split::Test,
            args.get_usize("n-test", 2000),
            args.get_u64("seed", 0x5EED),
        );
        if net.n_in() != ds.images.cols {
            return Err(anyhow!(
                "bundle '{}' takes {} inputs, dataset rows have {}",
                bundle.spec.name,
                net.n_in(),
                ds.images.cols
            ));
        }
        if args.has_flag("frontier") {
            return eval_frontier(&bundle, &net, &ds);
        }
        let err = net.error_rate(&ds.images, &ds.labels);
        println!(
            "{} (bundle v{}) on {}: test error {:.2}% [native engine]",
            bundle.spec.name,
            bundle.version,
            ds.kind.name(),
            err * 100.0
        );
        return Ok(());
    }
    let artifact = args.get("config").ok_or_else(|| anyhow!("--bundle or --config required"))?;
    let ckpt = args.get("checkpoint").ok_or_else(|| anyhow!("--checkpoint required"))?;
    let rt = Runtime::open(artifacts_dir(args))?;
    let state = ModelState::load_any(Path::new(ckpt))?;
    let ds = generate(dataset_kind(args)?, Split::Test, args.get_usize("n-test", 2000),
                      args.get_u64("seed", 0x5EED));
    let err = trainer::evaluate(&rt, artifact, &state, &ds)?;
    println!("{artifact} on {}: test error {:.2}%", ds.kind.name(), err * 100.0);
    Ok(())
}

/// `eval --bundle m.hnb --frontier`: the size/accuracy frontier across
/// quantization codecs — the Table 2 analogue for bundle storage. Each
/// codec re-encodes the same trained weights; the evaluated network is
/// rebuilt from the *decoded* tensors, so the reported error is exactly
/// what a loader of that saved file would see.
fn eval_frontier(
    bundle: &ModelBundle,
    f32_net: &Network,
    ds: &hashednets::data::Dataset,
) -> Result<()> {
    let base_pred = f32_net.predict(&ds.images).argmax_rows();
    let base_bytes = bundle.quantize(QuantSpec::F32)?.to_bytes().len();
    println!(
        "{} quantization frontier on {} ({} rows):",
        bundle.spec.name,
        ds.kind.name(),
        ds.labels.len()
    );
    println!(
        "{:<12} {:>12} {:>7} {:>12} {:>12}",
        "codec", "file bytes", "ratio", "test error", "agree(f32)"
    );
    for spec in [
        QuantSpec::F32,
        QuantSpec::Int8,
        QuantSpec::Codebook(256),
        QuantSpec::Codebook(64),
        QuantSpec::Codebook(16),
    ] {
        let q = bundle.quantize(spec)?;
        let bytes = q.to_bytes().len();
        let net = Network::from_bundle(&q)?;
        let err = net.error_rate(&ds.images, &ds.labels);
        let pred = net.predict(&ds.images).argmax_rows();
        let agree = pred.iter().zip(&base_pred).filter(|(a, b)| a == b).count() as f64
            / base_pred.len().max(1) as f64;
        println!(
            "{:<12} {:>12} {:>6.2}x {:>11.2}% {:>11.1}%",
            spec.name(),
            bytes,
            base_bytes as f64 / bytes.max(1) as f64,
            err * 100.0,
            agree * 100.0
        );
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let experiment = args
        .get("experiment")
        .ok_or_else(|| anyhow!("--experiment fig2|fig3|table1|table2|fig4|tile_sweep required"))?;
    let mut opt = repro::ReproOptions {
        artifacts_dir: artifacts_dir(args),
        results_dir: args.get_or("results", "results").into(),
        hidden: args.get_usize("hidden", 100),
        exp_base: args.get_usize("exp-base", 50),
        n_train: args.get_usize("n-train", 3000),
        n_test: args.get_usize("n-test", 2000),
        epochs: args.get_usize("epochs", 12),
        teacher_epochs: args.get_usize("teacher-epochs", 12),
        workers: args.get_usize("workers", repro::ReproOptions::default().workers),
        seed: args.get_u64("seed", 0x5EED),
        train: train_options_from(args)?,
    };
    if args.get_or("scale", "bench") == "paper" {
        opt.hidden = 1000;
        opt.n_train = 12000;
        opt.n_test = 50000;
        opt.epochs = 100;
        opt.teacher_epochs = 100;
    }
    if experiment == "all" {
        for e in ["fig2", "fig3", "table1", "table2", "fig4"] {
            repro::run_experiment(e, &opt)?;
        }
        Ok(())
    } else {
        repro::run_experiment(experiment, &opt)
    }
}

fn cmd_hpo(args: &Args) -> Result<()> {
    let artifact = args.get("config").ok_or_else(|| anyhow!("--config required"))?;
    let rt = Runtime::open(artifacts_dir(args))?;
    let train = generate(dataset_kind(args)?, Split::Train,
                         args.get_usize("n-train", 3000), args.get_u64("seed", 0x5EED));
    let res = hpo::search(&rt, artifact, &train, args.get_usize("epochs", 12),
                          args.get_usize("trials", 12), args.get_u64("seed", 0x5EED),
                          &train_options_from(args)?)?;
    println!(
        "best: lr={:.4} momentum={} keep_prob={} (val error {:.2}%) over {} scored trials",
        res.best.lr, res.best.momentum, res.best.keep_prob,
        res.best_val_error * 100.0, res.trials.len()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Models come from bundle files (--bundle a.hnb,b.hnb — fully
    // self-describing, no manifest) and/or manifest artifacts
    // (--config a,b with --checkpoint matching positionally, "-" =
    // seed init). More can be hot-loaded later via {"cmd":"load"}.
    let mut models: Vec<ModelConfig> = Vec::new();
    if let Some(bundles) = args.get("bundle") {
        for p in bundles.split(',') {
            models.push(ModelConfig::bundle(p.trim()));
        }
    }
    if let Some(configs) = args.get("config") {
        let ckpts: Vec<&str> =
            args.get("checkpoint").map(|c| c.split(',').collect()).unwrap_or_default();
        let n_models = configs.split(',').count();
        // positional matching is silent-failure-prone: demand one entry per
        // model (seed-init a model explicitly with "-") so nobody serves
        // random weights because a list was one short
        if !ckpts.is_empty() && ckpts.len() != n_models {
            return Err(anyhow!(
                "--checkpoint lists {} entries for {} models; give one per model (use '-' for seed init)",
                ckpts.len(),
                n_models
            ));
        }
        for (i, artifact) in configs.split(',').enumerate() {
            let mut mc = ModelConfig::new(artifact.trim());
            let ck = ckpts.get(i).copied().unwrap_or("");
            if !ck.is_empty() && ck != "-" {
                mc = mc.with_checkpoint(PathBuf::from(ck));
            }
            models.push(mc);
        }
    }
    if models.is_empty() {
        return Err(anyhow!(
            "--bundle <file.hnb[,…]> or --config <artifact[,…]> required"
        ));
    }
    let backend_name = args.get_or("backend", "auto");
    let backend = Backend::parse(backend_name)
        .ok_or_else(|| anyhow!("--backend must be native|runtime|auto, got '{backend_name}'"))?;
    let poller = PollerKind::parse(args.get_or("poller", "auto"))?;
    serve(ServeOptions {
        artifacts_dir: artifacts_dir(args),
        models,
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        backend,
        workers: args.get_usize("workers", 2),
        max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 2000)),
        max_requests: args.get_u64("max-requests", 0),
        max_pending: args.get_usize("max-pending", 256),
        default_timeout: std::time::Duration::from_millis(args.get_u64("timeout-ms", 10_000).max(1)),
        poller,
    })
}

fn cmd_compress(args: &Args) -> Result<()> {
    let out = args.get_or("save", "compressed.hnb");

    // One-call path: a dense bundle + target budgets, nothing else.
    if let Some(bpath) = args.get("bundle") {
        let budgets = parse_usize_list(
            args.get("budgets")
                .ok_or_else(|| anyhow!("--budgets k0,k1,… required with --bundle"))?,
        )?;
        let bundle = ModelBundle::load(Path::new(bpath))?;
        if bundle.spec.method != Method::Nn {
            return Err(anyhow!(
                "--bundle must be a dense (nn) model, got '{}'",
                bundle.spec.method
            ));
        }
        let dnet = Network::from_bundle(&bundle)?;
        // `--method hashed_tile [--tile THxTW]` switches the target
        // representation from per-cell buckets to tile runs.
        let target = args.get_or("method", "hashnet");
        let hashed = match target {
            "hashnet" => {
                let name = args.get_or("name", "hashnet_compressed").to_string();
                hashednets::compress::compress_network(&dnet, &budgets, name)?
            }
            "hashed_tile" => {
                let tile = Method::parse_tile(args.get_or("tile", "1x8"))?;
                let name = args.get_or("name", "hashed_tile_compressed").to_string();
                hashednets::compress::compress_network_tiled(&dnet, &budgets, tile, name)?
            }
            other => {
                return Err(anyhow!(
                    "--method must be hashnet|hashed_tile for compression, got '{other}'"
                ))
            }
        };
        for (l, err) in hashednets::compress::reconstruction_report(&dnet, &hashed)?
            .iter()
            .enumerate()
        {
            println!("layer {l}: -> {} weights, recon error {err:.3}", budgets[l]);
        }
        return save_bundle(&apply_quantize(args, hashed)?, out);
    }

    // Manifest pair path (compat): dims + budgets come from the target
    // hashnet artifact, parameters from a dense checkpoint/bundle.
    let from = args.get("from").ok_or_else(|| {
        anyhow!("--bundle <dense.hnb> --budgets k0,… — or --from <nn artifact> --to <hashnet artifact>")
    })?;
    let to = args.get("to").ok_or_else(|| anyhow!("--to <hashnet artifact> required"))?;
    let ckpt = args.get("checkpoint").ok_or_else(|| anyhow!("--checkpoint required"))?;
    let manifest = Manifest::load(&artifacts_dir(args).join("manifest.json"))?;
    let dspec = manifest.get(from).ok_or_else(|| anyhow!("unknown artifact {from}"))?;
    let hspec = manifest.get(to).ok_or_else(|| anyhow!("unknown artifact {to}"))?;
    if dspec.method != Method::Nn || !matches!(hspec.method, Method::Hashnet | Method::HashnetDk)
    {
        return Err(anyhow!("--from must be an nn artifact and --to a hashnet artifact"));
    }
    if dspec.dims != hspec.dims {
        return Err(anyhow!("dims mismatch: {:?} vs {:?}", dspec.dims, hspec.dims));
    }
    if dspec.seed_base != hspec.seed_base {
        return Err(anyhow!(
            "seed_base mismatch: {} vs {}",
            dspec.seed_base,
            hspec.seed_base
        ));
    }
    let state = ModelState::load_any(Path::new(ckpt))?;
    let dnet = Network::from_bundle(&state.to_bundle(dspec)?)?;
    let mut hashed =
        hashednets::compress::compress_network(&dnet, &hspec.budgets, hspec.name.clone())?;
    hashed.spec.batch = hspec.batch.max(1);
    for (l, err) in hashednets::compress::reconstruction_report(&dnet, &hashed)?
        .iter()
        .enumerate()
    {
        println!("layer {l}: -> {} weights, recon error {err:.3}", hspec.budgets[l]);
    }
    save_bundle(&apply_quantize(args, hashed)?, out)
}

fn cmd_list(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let header = format!(
        "{:<40} {:>10} {:>8} {:>10} {:>9} {:>7}",
        "model", "method", "stored", "virtual", "ratio", "bundle"
    );
    let mut printed = false;
    let manifest_path = dir.join("manifest.json");
    if manifest_path.exists() {
        let manifest = Manifest::load(&manifest_path)?;
        println!("manifest artifacts in {}:", dir.display());
        println!("{header}");
        for a in manifest.iter() {
            let spec = a.to_model_spec();
            println!(
                "{:<40} {:>10} {:>8} {:>10} {:>9.4} {:>7}",
                spec.name,
                spec.method.as_str(),
                spec.stored_params(),
                spec.virtual_params(),
                spec.compression(),
                format!("v{BUNDLE_VERSION}")
            );
        }
        printed = true;
    }
    let mut bundles: Vec<PathBuf> = std::fs::read_dir(&dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| p.extension().map(|e| e == "hnb").unwrap_or(false))
                .collect()
        })
        .unwrap_or_default();
    bundles.sort();
    if !bundles.is_empty() {
        println!("model bundles in {}:", dir.display());
        println!("{header}");
        for path in bundles {
            match ModelBundle::load(&path) {
                Ok(b) => println!(
                    "{:<40} {:>10} {:>8} {:>10} {:>9.4} {:>7}",
                    format!("{} ({})", b.spec.name, path.file_name().unwrap().to_string_lossy()),
                    b.spec.method.as_str(),
                    b.spec.stored_params(),
                    b.spec.virtual_params(),
                    b.spec.compression(),
                    format!("v{}", b.version)
                ),
                Err(e) => println!("{:<40} unreadable: {e}", path.display()),
            }
        }
        printed = true;
    }
    if !printed {
        println!("no manifest.json or *.hnb bundles in {}", dir.display());
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    // artifact predict vs native engine on identical params — the
    // cross-stack bit-compatibility check (hash must agree everywhere).
    let rt = Runtime::open(artifacts_dir(args))?;
    let name = args.get_or("config", "hashnet_3l_h32_o10_c1-4");
    let spec = rt.manifest.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?.clone();
    let state = spec.init_state(7);
    let exe = rt.load(name, Graph::Predict)?;
    let ds = generate(Kind::Basic, Split::Test, spec.batch, 3);
    let artifact_logits = exe.predict(&state, &ds.images)?;
    let net = Network::from_bundle(&state.to_bundle(&spec)?)?;
    let native_logits = net.predict(&ds.images);
    let mut max_d = 0f32;
    for (a, b) in artifact_logits.data.iter().zip(&native_logits.data) {
        max_d = max_d.max((a - b).abs());
    }
    println!("selftest {name}: max |artifact - native| = {max_d:.2e}");
    if max_d < 1e-3 {
        println!("OK — stacks agree");
        Ok(())
    } else {
        Err(anyhow!("stacks disagree (max diff {max_d})"))
    }
}

/// Tiny end-to-end loop on the native stack, no artifacts required:
/// train a HashedNet from a pure spec, bundle it, serve the bundle,
/// classify over TCP, train a second model and hot-load it into the
/// running server, reload, unload, shut down. `make smoke` runs this.
fn cmd_smoke(args: &Args) -> Result<()> {
    let dir = match args.get("dir") {
        Some(d) => PathBuf::from(d),
        None => std::env::temp_dir().join(format!("hn_smoke_{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir)?;

    println!("[1/5] train: hashnet 784-16-10 at ~1/32, native engine");
    let spec_a = ModelSpec::new(
        "smoke_hashnet",
        Method::Hashnet,
        vec![784, 16, 10],
        vec![400, 60],
        hashednets::hash::DEFAULT_SEED_BASE,
        16,
    )?;
    let cfg = trainer::TrainConfig {
        artifact: spec_a.name.clone(),
        dataset: Kind::Basic,
        n_train: 600,
        n_test: 300,
        epochs: 3,
        hyper: Hyper { lr: 0.08, keep_prob: 1.0, lam: 1.0, ..Hyper::default() },
        seed: 7,
        ..Default::default()
    };
    let res = trainer::run_native(&spec_a, &cfg)?;
    let path_a = dir.join("smoke_hashnet.hnb");
    let bundle_a = res.bundle()?;
    bundle_a.save(&path_a)?;
    println!(
        "      test error {:.2}%, bundle {} B -> {}",
        res.test_error * 100.0,
        bundle_a.param_bytes(),
        path_a.display()
    );

    println!("[2/5] serve: bundle on an ephemeral port, 2 workers");
    let srv = Server::bind(ServeOptions {
        artifacts_dir: dir.clone(),
        models: vec![ModelConfig::bundle(&path_a)],
        addr: "127.0.0.1:0".into(),
        backend: Backend::Native,
        workers: 2,
        ..Default::default()
    })?;
    let addr = srv.local_addr().to_string();
    let server = std::thread::spawn(move || srv.run());

    println!("[3/5] query: 32 live classifications over TCP");
    let test = generate(Kind::Basic, Split::Test, 32, 9);
    let mut client = Client::connect(&addr)?;
    let mut correct = 0;
    for i in 0..32 {
        let (class, probs, _lat) = client.classify(test.images.row(i))?;
        if probs.len() != 10 {
            return Err(anyhow!("expected 10 probs, got {}", probs.len()));
        }
        if class == test.labels[i] as usize {
            correct += 1;
        }
    }
    println!("      live accuracy {correct}/32");

    println!("[4/5] hot-load: train a dense model, {{\"cmd\":\"load\"}} it, reload, unload");
    let spec_b = ModelSpec::new(
        "smoke_dense",
        Method::Nn,
        vec![784, 8, 10],
        vec![6280, 90],
        hashednets::hash::DEFAULT_SEED_BASE,
        16,
    )?;
    let res_b = trainer::run_native(&spec_b, &cfg)?;
    let path_b = dir.join("smoke_dense.hnb");
    res_b.bundle()?.save(&path_b)?;
    client.load_model(path_b.to_str().unwrap())?;
    let (_, probs_b, _) = client.classify_model(Some("smoke_dense"), test.images.row(0))?;
    if probs_b.len() != 10 {
        return Err(anyhow!("hot-loaded model returned {} probs", probs_b.len()));
    }
    // the original model keeps serving after the load
    client.classify_model(Some("smoke_hashnet"), test.images.row(1))?;
    client.reload()?;
    client.classify_model(Some("smoke_dense"), test.images.row(2))?;
    client.unload_model("smoke_dense")?;
    if client.classify_model(Some("smoke_dense"), test.images.row(3)).is_ok() {
        return Err(anyhow!("unloaded model still serving"));
    }
    client.classify_model(Some("smoke_hashnet"), test.images.row(4))?;

    println!("[5/5] shutdown");
    client.shutdown()?;
    server.join().unwrap()?;
    if !args.has_flag("keep") && args.get("dir").is_none() {
        std::fs::remove_dir_all(&dir).ok();
    }
    println!("smoke OK");
    Ok(())
}
