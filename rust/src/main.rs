//! `hashednets` CLI — the Layer-3 entry point.
//!
//! Subcommands:
//!   train    — train one artifact on one dataset, report test error
//!   eval     — evaluate a checkpoint on a dataset split
//!   repro    — regenerate a paper experiment (fig2|fig3|table1|table2|fig4)
//!   hpo      — random-search hyperparameters for an artifact
//!   serve    — run the batched inference server on one or more
//!              checkpoints (--config a,b --backend native|runtime|auto
//!              --workers N)
//!   compress — compress a trained dense checkpoint into a HashedNet
//!   list     — list artifacts in the manifest
//!   selftest — artifact ↔ native engine cross-validation
//!
//! Run `hashednets <cmd> --help-args` for per-command options.

use anyhow::{anyhow, Result};
use hashednets::coordinator::{hpo, native, repro, trainer};
use hashednets::data::{generate, Kind, Split};
use hashednets::runtime::{Graph, Hyper, ModelState, Runtime};
use hashednets::serve::{serve, Backend, ModelConfig, ServeOptions};
use hashednets::util::args::Args;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("repro") => cmd_repro(&args),
        Some("hpo") => cmd_hpo(&args),
        Some("serve") => cmd_serve(&args),
        Some("compress") => cmd_compress(&args),
        Some("list") => cmd_list(&args),
        Some("selftest") => cmd_selftest(&args),
        _ => {
            eprintln!("usage: hashednets <train|eval|repro|hpo|serve|compress|list|selftest> [--options]");
            eprintln!("see rust/src/main.rs docs for the full flag list");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn dataset_kind(args: &Args) -> Result<Kind> {
    let name = args.get_or("dataset", "basic");
    Kind::parse(name).ok_or_else(|| anyhow!("unknown dataset '{name}'"))
}

fn hyper_from(args: &Args, base: Hyper) -> Hyper {
    Hyper {
        lr: args.get_f32("lr", base.lr),
        momentum: args.get_f32("momentum", base.momentum),
        keep_prob: args.get_f32("keep-prob", base.keep_prob),
        lam: args.get_f32("lam", base.lam),
        temp: args.get_f32("temp", base.temp),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let artifact = args.get("config").ok_or_else(|| anyhow!("--config <artifact> required"))?;
    let rt = Runtime::open(artifacts_dir(args))?;
    let spec = rt.manifest.get(artifact).ok_or_else(|| anyhow!("unknown artifact"))?.clone();
    let method_default = repro::default_hyper(&spec.method);
    let dataset = dataset_kind(args)?;
    let cfg = trainer::TrainConfig {
        artifact: artifact.to_string(),
        dataset,
        n_train: args.get_usize("n-train", 3000),
        n_test: args.get_usize("n-test", 2000),
        epochs: args.get_usize("epochs", 12),
        hyper: hyper_from(args, method_default),
        seed: args.get_u64("seed", 0x5EED),
        teacher: args.get("teacher").map(String::from),
        patience: args.get_usize("patience", 0),
    };
    // DK flow: train/load teacher, build soft targets
    let soft = if spec.uses_soft_targets {
        let teacher = cfg
            .teacher
            .clone()
            .ok_or_else(|| anyhow!("--teacher <artifact> required for DK methods"))?;
        let train = generate(dataset, Split::Train, cfg.n_train, cfg.seed);
        eprintln!("training teacher {teacher}...");
        let tstate = trainer::train_teacher(&rt, &teacher, &train, cfg.epochs, cfg.seed)?;
        Some(trainer::soft_targets(&rt, &teacher, &tstate, &train.images, cfg.hyper.temp)?)
    } else {
        None
    };
    let res = trainer::run(&rt, &cfg, soft.as_ref())?;
    println!(
        "{artifact} on {}: test error {:.2}% (val {:.2}%), {} stored params, {:.1}s ({:.0} steps/s)",
        dataset.name(), res.test_error * 100.0, res.val_error * 100.0,
        res.stored_params, res.wall_s, res.steps_per_s
    );
    if let Some(out) = args.get("save") {
        res.state.save(std::path::Path::new(out))?;
        println!("checkpoint -> {out} ({} bytes)", res.state.storage_bytes());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let artifact = args.get("config").ok_or_else(|| anyhow!("--config required"))?;
    let ckpt = args.get("checkpoint").ok_or_else(|| anyhow!("--checkpoint required"))?;
    let rt = Runtime::open(artifacts_dir(args))?;
    let state = ModelState::load(std::path::Path::new(ckpt))?;
    let ds = generate(dataset_kind(args)?, Split::Test, args.get_usize("n-test", 2000),
                      args.get_u64("seed", 0x5EED));
    let err = trainer::evaluate(&rt, artifact, &state, &ds)?;
    println!("{artifact} on {}: test error {:.2}%", ds.kind.name(), err * 100.0);
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let experiment = args
        .get("experiment")
        .ok_or_else(|| anyhow!("--experiment fig2|fig3|table1|table2|fig4 required"))?;
    let mut opt = repro::ReproOptions {
        artifacts_dir: artifacts_dir(args),
        results_dir: args.get_or("results", "results").into(),
        hidden: args.get_usize("hidden", 100),
        exp_base: args.get_usize("exp-base", 50),
        n_train: args.get_usize("n-train", 3000),
        n_test: args.get_usize("n-test", 2000),
        epochs: args.get_usize("epochs", 12),
        teacher_epochs: args.get_usize("teacher-epochs", 12),
        workers: args.get_usize("workers", repro::ReproOptions::default().workers),
        seed: args.get_u64("seed", 0x5EED),
    };
    if args.get_or("scale", "bench") == "paper" {
        opt.hidden = 1000;
        opt.n_train = 12000;
        opt.n_test = 50000;
        opt.epochs = 100;
        opt.teacher_epochs = 100;
    }
    if experiment == "all" {
        for e in ["fig2", "fig3", "table1", "table2", "fig4"] {
            repro::run_experiment(e, &opt)?;
        }
        Ok(())
    } else {
        repro::run_experiment(experiment, &opt)
    }
}

fn cmd_hpo(args: &Args) -> Result<()> {
    let artifact = args.get("config").ok_or_else(|| anyhow!("--config required"))?;
    let rt = Runtime::open(artifacts_dir(args))?;
    let train = generate(dataset_kind(args)?, Split::Train,
                         args.get_usize("n-train", 3000), args.get_u64("seed", 0x5EED));
    let res = hpo::search(&rt, artifact, &train, args.get_usize("epochs", 12),
                          args.get_usize("trials", 12), args.get_u64("seed", 0x5EED))?;
    println!(
        "best: lr={:.4} momentum={} keep_prob={} (val error {:.2}%) over {} scored trials",
        res.best.lr, res.best.momentum, res.best.keep_prob,
        res.best_val_error * 100.0, res.trials.len()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // --config takes a comma-separated artifact list (one process, many
    // models); --checkpoint matches positionally ("-" = seed init).
    let configs = args.get("config").ok_or_else(|| anyhow!("--config <artifact[,artifact…]> required"))?;
    let ckpts: Vec<&str> = args.get("checkpoint").map(|c| c.split(',').collect()).unwrap_or_default();
    let n_models = configs.split(',').count();
    // positional matching is silent-failure-prone: demand one entry per
    // model (seed-init a model explicitly with "-") so nobody serves
    // random weights because a list was one short
    if !ckpts.is_empty() && ckpts.len() != n_models {
        return Err(anyhow!(
            "--checkpoint lists {} entries for {} models; give one per model (use '-' for seed init)",
            ckpts.len(),
            n_models
        ));
    }
    let models: Vec<ModelConfig> = configs
        .split(',')
        .enumerate()
        .map(|(i, artifact)| {
            let mut mc = ModelConfig::new(artifact.trim());
            let ck = ckpts.get(i).copied().unwrap_or("");
            if !ck.is_empty() && ck != "-" {
                mc = mc.with_checkpoint(PathBuf::from(ck));
            }
            mc
        })
        .collect();
    let backend_name = args.get_or("backend", "auto");
    let backend = Backend::parse(backend_name)
        .ok_or_else(|| anyhow!("--backend must be native|runtime|auto, got '{backend_name}'"))?;
    serve(ServeOptions {
        artifacts_dir: artifacts_dir(args),
        models,
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        backend,
        workers: args.get_usize("workers", 2),
        max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 2000)),
        max_requests: args.get_u64("max-requests", 0),
    })
}

fn cmd_compress(args: &Args) -> Result<()> {
    // Compress a dense checkpoint (nn artifact) into a hashed artifact's
    // parameter layout via bucket-averaging (compress/ module).
    let from = args.get("from").ok_or_else(|| anyhow!("--from <dense artifact> required"))?;
    let to = args.get("to").ok_or_else(|| anyhow!("--to <hashnet artifact> required"))?;
    let ckpt = args.get("checkpoint").ok_or_else(|| anyhow!("--checkpoint required"))?;
    let out = args.get_or("save", "compressed.ckpt");
    let rt = Runtime::open(artifacts_dir(args))?;
    let dspec = rt.manifest.get(from).ok_or_else(|| anyhow!("unknown artifact {from}"))?;
    let hspec = rt.manifest.get(to).ok_or_else(|| anyhow!("unknown artifact {to}"))?;
    if dspec.method != "nn" || !hspec.method.starts_with("hashnet") {
        return Err(anyhow!("--from must be an nn artifact and --to a hashnet artifact"));
    }
    if dspec.dims != hspec.dims {
        return Err(anyhow!("dims mismatch: {:?} vs {:?}", dspec.dims, hspec.dims));
    }
    let dstate = ModelState::load(std::path::Path::new(ckpt))?;
    let mut dnet = native::network_from_spec(dspec);
    native::load_params(&mut dnet, dspec, &dstate);
    let mut hstate = ModelState::init(hspec, 0);
    for (l, layer) in dnet.layers.iter().enumerate() {
        // dense V (n×m) + b -> (n×(m+1)) with bias column appended
        let v = layer.virtual_matrix();
        let nm = layer.n * layer.m;
        let bias = layer.params[nm..].to_vec();
        let mut vb = hashednets::tensor::Matrix::zeros(layer.n, layer.m + 1);
        for i in 0..layer.n {
            vb.row_mut(i)[..layer.m].copy_from_slice(v.row(i));
            vb.row_mut(i)[layer.m] = bias[i];
        }
        let k = hspec.budgets[l];
        hstate.params[l] =
            hashednets::compress::compress_dense(&vb, k, l as u32, hspec.seed_base);
        let err = hashednets::compress::reconstruction_error(&vb, k, l as u32, hspec.seed_base);
        println!("layer {l}: {} -> {} weights, recon error {:.3}", vb.data.len(), k, err);
    }
    hstate.save(std::path::Path::new(out))?;
    println!("compressed checkpoint -> {out} ({} bytes)", hstate.storage_bytes());
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    println!("{:<40} {:>8} {:>10} {:>9}", "artifact", "stored", "virtual", "ratio");
    for a in rt.manifest.iter() {
        println!(
            "{:<40} {:>8} {:>10} {:>9.4}",
            a.name, a.stored_params, a.virtual_params,
            a.stored_params as f64 / a.virtual_params as f64
        );
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    // artifact predict vs native engine on identical params — the
    // cross-stack bit-compatibility check (hash must agree everywhere).
    let rt = Runtime::open(artifacts_dir(args))?;
    let name = args.get_or("config", "hashnet_3l_h32_o10_c1-4");
    let spec = rt.manifest.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?.clone();
    let state = ModelState::init(&spec, 7);
    let exe = rt.load(name, Graph::Predict)?;
    let ds = generate(Kind::Basic, Split::Test, spec.batch, 3);
    let artifact_logits = exe.predict(&state, &ds.images)?;
    let mut net = native::network_from_spec(&spec);
    native::load_params(&mut net, &spec, &state);
    let native_logits = net.predict(&ds.images);
    let mut max_d = 0f32;
    for (a, b) in artifact_logits.data.iter().zip(&native_logits.data) {
        max_d = max_d.max((a - b).abs());
    }
    println!("selftest {name}: max |artifact - native| = {max_d:.2e}");
    if max_d < 1e-3 {
        println!("OK — stacks agree");
        Ok(())
    } else {
        Err(anyhow!("stacks disagree (max diff {max_d})"))
    }
}
