//! `HashPlan` — the precomputed, sign-packed hash mapping of one hashed
//! layer, shared immutably across threads.
//!
//! # Memory layout
//!
//! The plan stores **one `u32` per virtual cell**, row-major over the
//! virtual matrix `V (n × (m+1))`:
//!
//! ```text
//!   bit 31      bits 30..0
//!   ┌────┐      ┌─────────────────────────┐
//!   │ ξ<0 │     │ bucket id  h(i,j) ∈ [0,K) │
//!   └────┘      └─────────────────────────┘
//! ```
//!
//! The sign factor ξ(i,j) ∈ {+1, −1} occupies the top bit (`1` = negative),
//! which is exactly the IEEE-754 sign-bit position of an `f32`; applying
//! the sign to a weight is therefore a single XOR of the payload bits
//! ([`HashPlan::apply_sign`]) — no multiply, no second array.
//!
//! This halves plan memory versus the previous id cache (`u32` bucket +
//! `f32` sign = 8 bytes/cell) to **4 bytes/cell**, and halves hot-loop
//! memory traffic. Versus the paper's storage claim: the *model* is still
//! the `K` real weights (4·K bytes — Eq. 7's point); the plan is a
//! runtime acceleration structure that can always be rebuilt from the
//! two per-layer seeds, so it never needs to be shipped or checkpointed.
//! Packing requires `K < 2^31`, asserted at build time (the largest
//! paper configuration is K ≈ 2.4 M).
//!
//! # Kernel-variant selection (see `nn::layers`)
//!
//! Three forward kernels read the plan; [`crate::nn::Layer::forward`]
//! picks one per call:
//!
//! * **scratch-row** (`forward_hashed_scratch`) — decompress each
//!   virtual row once into a scratch buffer, then run a dense unrolled
//!   dot across the whole batch; the K-gather is amortized over B rows.
//!   Chosen for B ≥ 2; parallelized over output-row blocks with
//!   `std::thread::scope` when the layer is large enough.
//! * **bucket-major** (`forward_hashed_bucket`, paper Eq. 10) —
//!   scatter-accumulate ξ·aⱼ into a K-sized accumulator, then one dense
//!   dot with `w`. Chosen for B = 1 when `K ≤ m+1` (streaming beats
//!   gathering once the accumulator is smaller than the row).
//! * **gather** (`forward_hashed_gather`) — the legacy per-cell gather
//!   `w[h(i,j)]` (paper Eq. 8 evaluated literally), kept as the B = 1
//!   large-K fallback and as the bench baseline.
//!
//! The backward pass reads the same plan: Eq. 11's input gradient uses
//! `decompress_row_into` (one row of Eq. 7 per output unit), and
//! Eq. 12's weight gradient is one gather pass per row scattering
//! `ξ(i,j) · Σ_b a_bj δ_bi` into the bucket gradient — batch-amortized
//! and, since PR 4, parallelized over output-row blocks with
//! per-block partials (`nn::layers` documents the reduction and its
//! determinism contract).
//!
//! Plans are built eagerly at layer construction/load time and shared
//! via `Arc<HashPlan>`, which is what lets `Layer::forward` /
//! `Network::predict` take `&self`, many serving threads share one
//! model, and all backward workers read one plan — without locks or
//! clones in either direction.

use super::{bucket_sign, layer_seeds};

/// Immutable, sign-packed decompression plan for one hashed layer.
#[derive(Clone, PartialEq)]
pub struct HashPlan {
    /// Output rows of the virtual matrix (layer fan-out `n`).
    pub n: usize,
    /// Columns of the virtual matrix (`m + 1`, bias column included).
    pub m1: usize,
    /// Number of real (stored) weights the plan indexes into.
    pub k: usize,
    /// `n * m1` packed entries, row-major: `bucket | (ξ<0) << 31`.
    packed: Vec<u32>,
}

impl HashPlan {
    /// IEEE-754 / plan sign-bit position.
    pub const SIGN_BIT: u32 = 1 << 31;
    /// Mask selecting the bucket id.
    pub const BUCKET_MASK: u32 = !Self::SIGN_BIT;

    /// Build the plan for layer `layer_index` of a network seeded with
    /// `seed_base` (bit-identical to `bucket_sign` over every cell).
    pub fn build(n: usize, m1: usize, k: usize, layer_index: u32, seed_base: u32) -> HashPlan {
        assert!(k >= 1, "hashed layer needs at least one real weight");
        assert!(
            (k as u64) < (1u64 << 31),
            "bucket id must fit in 31 bits to leave room for the sign (k = {k})"
        );
        let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
        let mut packed = Vec::with_capacity(n * m1);
        for i in 0..n as u32 {
            for j in 0..m1 as u32 {
                let (b, sg) = bucket_sign(i, j, m1 as u32, k as u32, s_h, s_xi);
                packed.push(b | if sg < 0.0 { Self::SIGN_BIT } else { 0 });
            }
        }
        HashPlan { n, m1, k, packed }
    }

    /// Packed entries of virtual row `i` (length `m1`).
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.packed[i * self.m1..(i + 1) * self.m1]
    }

    /// Bucket id of a packed entry.
    #[inline(always)]
    pub fn bucket(entry: u32) -> usize {
        (entry & Self::BUCKET_MASK) as usize
    }

    /// Apply the entry's ξ sign to an f32 by XOR-ing the packed sign bit
    /// into the payload's IEEE-754 sign bit.
    #[inline(always)]
    pub fn apply_sign(entry: u32, value: f32) -> f32 {
        f32::from_bits(value.to_bits() ^ (entry & Self::SIGN_BIT))
    }

    /// Decompress virtual row `i` into `out` (`out.len() == m1`):
    /// `out[j] = ξ(i,j) · w[h(i,j)]` (paper Eq. 7).
    #[inline]
    pub fn decompress_row_into(&self, i: usize, params: &[f32], out: &mut [f32]) {
        for (o, &e) in out.iter_mut().zip(self.row(i)) {
            *o = Self::apply_sign(e, params[Self::bucket(e)]);
        }
    }

    /// Plan memory footprint in bytes (4 per virtual cell).
    pub fn bytes(&self) -> usize {
        self.packed.len() * std::mem::size_of::<u32>()
    }
}

impl std::fmt::Debug for HashPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HashPlan")
            .field("n", &self.n)
            .field("m1", &self.m1)
            .field("k", &self.k)
            .field("bytes", &self.bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::DEFAULT_SEED_BASE;

    #[test]
    fn packing_matches_bucket_sign() {
        let (n, m1, k) = (9usize, 13usize, 17usize);
        let plan = HashPlan::build(n, m1, k, 3, DEFAULT_SEED_BASE);
        let (s_h, s_xi) = layer_seeds(3, DEFAULT_SEED_BASE);
        for i in 0..n {
            for (j, &e) in plan.row(i).iter().enumerate() {
                let (b, sg) = bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
                assert_eq!(HashPlan::bucket(e), b as usize, "bucket at ({i},{j})");
                let applied = HashPlan::apply_sign(e, 2.5);
                assert_eq!(applied, 2.5 * sg, "sign at ({i},{j})");
            }
        }
    }

    #[test]
    fn sign_xor_equals_multiply() {
        for &v in &[0.0f32, -0.0, 1.5, -3.25, f32::MIN_POSITIVE] {
            assert_eq!(HashPlan::apply_sign(0, v), v);
            assert_eq!(HashPlan::apply_sign(HashPlan::SIGN_BIT, v), -v);
            assert_eq!(HashPlan::apply_sign(HashPlan::SIGN_BIT | 42, v), -v);
        }
    }

    #[test]
    fn decompress_row_matches_eq7() {
        let (n, m1, k) = (4usize, 6usize, 5usize);
        let plan = HashPlan::build(n, m1, k, 0, DEFAULT_SEED_BASE);
        let params: Vec<f32> = (0..k).map(|i| 0.5 + i as f32).collect();
        let mut out = vec![0.0f32; m1];
        for i in 0..n {
            plan.decompress_row_into(i, &params, &mut out);
            for (j, &e) in plan.row(i).iter().enumerate() {
                let want = params[HashPlan::bucket(e)]
                    * if e & HashPlan::SIGN_BIT != 0 { -1.0 } else { 1.0 };
                assert_eq!(out[j], want);
            }
        }
    }

    #[test]
    fn four_bytes_per_cell() {
        let plan = HashPlan::build(10, 21, 7, 0, DEFAULT_SEED_BASE);
        assert_eq!(plan.bytes(), 4 * 10 * 21);
    }

    #[test]
    #[should_panic(expected = "31 bits")]
    fn oversized_k_panics() {
        let _ = HashPlan::build(1, 1, 1usize << 31, 0, DEFAULT_SEED_BASE);
    }
}
