//! `HashPlan` — the precomputed, sign-packed hash mapping of one hashed
//! layer, shared immutably across threads.
//!
//! # Memory layout
//!
//! The plan stores **one `u32` per virtual cell**, row-major over the
//! virtual matrix `V (n × (m+1))`:
//!
//! ```text
//!   bit 31      bits 30..0
//!   ┌────┐      ┌─────────────────────────┐
//!   │ ξ<0 │     │ bucket id  h(i,j) ∈ [0,K) │
//!   └────┘      └─────────────────────────┘
//! ```
//!
//! The sign factor ξ(i,j) ∈ {+1, −1} occupies the top bit (`1` = negative),
//! which is exactly the IEEE-754 sign-bit position of an `f32`; applying
//! the sign to a weight is therefore a single XOR of the payload bits
//! ([`HashPlan::apply_sign`]) — no multiply, no second array.
//!
//! This halves plan memory versus the previous id cache (`u32` bucket +
//! `f32` sign = 8 bytes/cell) to **4 bytes/cell**, and halves hot-loop
//! memory traffic. Versus the paper's storage claim: the *model* is still
//! the `K` real weights (4·K bytes — Eq. 7's point); the plan is a
//! runtime acceleration structure that can always be rebuilt from the
//! two per-layer seeds, so it never needs to be shipped or checkpointed.
//! Packing requires `K < 2^31`, asserted at build time (the largest
//! paper configuration is K ≈ 2.4 M).
//!
//! # Kernel-variant selection (see `nn::layers`)
//!
//! Four forward kernels read the plan; [`crate::nn::Layer::forward`]
//! picks one per call:
//!
//! * **scratch-row** (`forward_hashed_scratch`) — decompress each
//!   virtual row once into a scratch buffer, then run a dense unrolled
//!   dot across the whole batch; the K-gather is amortized over B rows.
//!   Chosen for B ≥ 2; parallelized over output-row blocks on the
//!   shared [`crate::rt::PoolExec`] when the layer is large enough.
//! * **inverse** (`forward_hashed_inverse`) — walk the lazily-built
//!   [`InversePlan`] bucket by bucket, adding `ξ(i,j)·w_k·a_j` for
//!   every cell of bucket `k`: the stored weights stream **in order**
//!   and the random traffic is confined to the small `z`/`a` vectors.
//!   The B = 1 serving default.
//! * **bucket-major** (`forward_hashed_bucket`, paper Eq. 10) —
//!   scatter-accumulate ξ·aⱼ into a K-sized accumulator, then one dense
//!   dot with `w`. Kept as a bench variant for the B = 1, `K ≤ m+1`
//!   regime it used to own.
//! * **gather** (`forward_hashed_gather`) — the legacy per-cell gather
//!   `w[h(i,j)]` (paper Eq. 8 evaluated literally), the bench baseline.
//!
//! The backward pass reads both views: Eq. 11's input gradient uses
//! `decompress_row_into` (one row of Eq. 7 per output unit), and
//! Eq. 12's weight gradient walks the [`InversePlan`] — one
//! *sequential* write per bucket (`∂w_k += Σ ξ·S_{ij}` over the
//! bucket's cells), parallel over disjoint bucket ranges with no
//! partial buffers and a thread-count-independent result (`nn::layers`
//! documents the kernels and the determinism contract).
//!
//! Plans are built eagerly at layer construction/load time and shared
//! via `Arc<HashPlan>`, which is what lets `Layer::forward` /
//! `Network::predict` take `&self`, many serving threads share one
//! model, and all backward workers read one plan — without locks or
//! clones in either direction. The inverse view is built **lazily** on
//! first use and cached behind a `OnceLock`, so a model that only ever
//! runs the batch≥2 scratch kernel never pays for it.

use super::{bucket_sign, layer_seeds};
use std::sync::OnceLock;

/// Immutable, sign-packed decompression plan for one hashed layer.
#[derive(Clone)]
pub struct HashPlan {
    /// Output rows of the virtual matrix (layer fan-out `n`).
    pub n: usize,
    /// Columns of the virtual matrix (`m + 1`, bias column included).
    pub m1: usize,
    /// Number of real (stored) weights the plan indexes into.
    pub k: usize,
    /// `n * m1` packed entries, row-major: `bucket | (ξ<0) << 31`.
    packed: Vec<u32>,
    /// Lazily-built CSR-by-bucket inverse view (see [`InversePlan`]).
    inverse: OnceLock<InversePlan>,
}

impl PartialEq for HashPlan {
    /// Plan identity is the mapping itself; the lazily-built inverse
    /// cache is derived state and excluded from comparison.
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n && self.m1 == other.m1 && self.k == other.k && self.packed == other.packed
    }
}

/// The CSR-by-bucket **inverse** of a [`HashPlan`]: every virtual cell
/// `(i, j)`, grouped by the bucket `h(i,j)` it maps to.
///
/// Unstructured hashing's run-time tax is memory incoherence — Eq. 12's
/// weight gradient does one *random* write per cell when driven from
/// the forward (row-major) plan. Grouping cells by bucket (the CSR-style
/// index-grouped layout of Deep Compression, and the locality fix
/// Structured Multi-Hashing argues for) turns that into one sequential
/// write per bucket, and gives batch-1 forward a kernel that streams
/// the stored weights in order.
///
/// Built once per plan by counting sort ([`HashPlan::inverse`]) and
/// cached; it is an exact permutation of the forward plan's cells
/// (asserted by property tests in `rust/tests/kernels.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct InversePlan {
    /// `k + 1` prefix offsets into `cells`: bucket `b`'s cells are
    /// `cells[bucket_offsets[b] as usize .. bucket_offsets[b+1] as usize]`.
    pub bucket_offsets: Vec<u32>,
    /// Sign-packed cells grouped by bucket: bits 30..0 hold the
    /// row-major flat index `i·m1 + j` of the virtual cell, bit 31 the
    /// ξ sign — the same packing convention as the forward plan (so
    /// [`HashPlan::apply_sign`] works on these entries too). Within a
    /// bucket, cells are in ascending `(i, j)` order, which fixes the
    /// per-bucket float summation order independently of how bucket
    /// ranges are partitioned across threads.
    pub cells: Vec<u32>,
}

impl InversePlan {
    /// Cells of bucket `b` (all `(i,j)` with `h(i,j) = b`).
    #[inline]
    pub fn cells_of(&self, b: usize) -> &[u32] {
        &self.cells[self.bucket_offsets[b] as usize..self.bucket_offsets[b + 1] as usize]
    }

    /// Bucket count (`k` of the owning plan).
    pub fn n_buckets(&self) -> usize {
        self.bucket_offsets.len() - 1
    }

    /// Inverse-view memory footprint in bytes.
    pub fn bytes(&self) -> usize {
        (self.cells.len() + self.bucket_offsets.len()) * std::mem::size_of::<u32>()
    }

    /// Bucket-index boundaries splitting the cell population into
    /// `n_ranges` spans of roughly equal cell count (monotone,
    /// `bounds[0] = 0`, `bounds[n_ranges] = k`). Used to load-balance
    /// the gradient pass: bucket populations are hash-distributed and
    /// uneven, so splitting by bucket *index* alone would skew work.
    pub fn balanced_ranges(&self, n_ranges: usize) -> Vec<usize> {
        let k = self.n_buckets();
        let total = self.cells.len();
        let n_ranges = n_ranges.max(1);
        let mut bounds = Vec::with_capacity(n_ranges + 1);
        bounds.push(0usize);
        for t in 1..n_ranges {
            let target = (total * t / n_ranges) as u32;
            let b = self.bucket_offsets.partition_point(|&o| o < target);
            bounds.push(b.min(k).max(*bounds.last().unwrap()));
        }
        bounds.push(k);
        bounds
    }
}

impl HashPlan {
    /// IEEE-754 / plan sign-bit position.
    pub const SIGN_BIT: u32 = 1 << 31;
    /// Mask selecting the bucket id.
    pub const BUCKET_MASK: u32 = !Self::SIGN_BIT;

    /// Build the plan for layer `layer_index` of a network seeded with
    /// `seed_base` (bit-identical to `bucket_sign` over every cell).
    pub fn build(n: usize, m1: usize, k: usize, layer_index: u32, seed_base: u32) -> HashPlan {
        assert!(k >= 1, "hashed layer needs at least one real weight");
        assert!(
            (k as u64) < (1u64 << 31),
            "bucket id must fit in 31 bits to leave room for the sign (k = {k})"
        );
        let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
        let mut packed = Vec::with_capacity(n * m1);
        for i in 0..n as u32 {
            for j in 0..m1 as u32 {
                let (b, sg) = bucket_sign(i, j, m1 as u32, k as u32, s_h, s_xi);
                packed.push(b | if sg < 0.0 { Self::SIGN_BIT } else { 0 });
            }
        }
        HashPlan { n, m1, k, packed, inverse: OnceLock::new() }
    }

    /// The CSR-by-bucket inverse view, built on first use by counting
    /// sort over the packed entries and cached for the plan's lifetime
    /// (the plan is shared via `Arc`, so one build serves every thread
    /// and every clone of the owning layer). Requires the flat cell
    /// index to fit in 31 bits next to the sign — `n·(m+1) < 2³¹`,
    /// which holds for any model whose plan fits in memory at
    /// 4 bytes/cell.
    pub fn inverse(&self) -> &InversePlan {
        self.inverse.get_or_init(|| {
            assert!(
                (self.packed.len() as u64) < (1u64 << 31),
                "inverse plan needs the flat cell index to fit in 31 bits \
                 (n·m1 = {})",
                self.packed.len()
            );
            let mut offsets = vec![0u32; self.k + 1];
            for &e in &self.packed {
                offsets[Self::bucket(e) + 1] += 1;
            }
            for b in 1..=self.k {
                offsets[b] += offsets[b - 1];
            }
            let mut cursor = offsets.clone();
            let mut cells = vec![0u32; self.packed.len()];
            for (idx, &e) in self.packed.iter().enumerate() {
                let b = Self::bucket(e);
                cells[cursor[b] as usize] = idx as u32 | (e & Self::SIGN_BIT);
                cursor[b] += 1;
            }
            InversePlan { bucket_offsets: offsets, cells }
        })
    }

    /// Packed entries of virtual row `i` (length `m1`).
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.packed[i * self.m1..(i + 1) * self.m1]
    }

    /// Bucket id of a packed entry.
    #[inline(always)]
    pub fn bucket(entry: u32) -> usize {
        (entry & Self::BUCKET_MASK) as usize
    }

    /// Apply the entry's ξ sign to an f32 by XOR-ing the packed sign bit
    /// into the payload's IEEE-754 sign bit.
    #[inline(always)]
    pub fn apply_sign(entry: u32, value: f32) -> f32 {
        f32::from_bits(value.to_bits() ^ (entry & Self::SIGN_BIT))
    }

    /// Decompress virtual row `i` into `out` (`out.len() == m1`):
    /// `out[j] = ξ(i,j) · w[h(i,j)]` (paper Eq. 7).
    #[inline]
    pub fn decompress_row_into(&self, i: usize, params: &[f32], out: &mut [f32]) {
        for (o, &e) in out.iter_mut().zip(self.row(i)) {
            *o = Self::apply_sign(e, params[Self::bucket(e)]);
        }
    }

    /// Plan memory footprint in bytes (4 per virtual cell).
    pub fn bytes(&self) -> usize {
        self.packed.len() * std::mem::size_of::<u32>()
    }
}

impl std::fmt::Debug for HashPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HashPlan")
            .field("n", &self.n)
            .field("m1", &self.m1)
            .field("k", &self.k)
            .field("bytes", &self.bytes())
            .finish()
    }
}

/// Block-structured hashing plan (`Method::HashedTile`): the virtual
/// matrix is carved into a grid of `th × tw` **tiles**, and the hash
/// maps each *tile* — not each cell — to a contiguous *run* of
/// `th · tw` stored weights, with one ξ sign for the whole tile.
///
/// Per-cell hashing (Eq. 8) defeats vectorization by construction:
/// every virtual cell gathers a random bucket. Structured Multi-Hashing
/// (Eban et al.) and Functional Hashing (Shi et al.) observe that
/// hashing *blocks* preserves the compression behaviour (runs still
/// collide pseudo-randomly across tiles) while making the inner loop
/// contiguous. Concretely, cell `(i, j)` of the virtual matrix maps to
///
/// ```text
///   V[i][j] = ξ(tr, tc) · w[ base(tr, tc) + (i mod th)·tw + (j mod tw) ]
///   where (tr, tc) = (i / th, j / tw)
/// ```
///
/// so a decompressed virtual row is `tiles_c` *contiguous* `tw`-length
/// copies from the stored weights — an 8-wide SIMD load when
/// `tw` is a multiple of [`crate::tensor::simd::LANES`] — instead of
/// `m+1` random gathers. Runs from different tiles overlap arbitrarily
/// (bases are hashed into `[0, k − th·tw]`), which is exactly the
/// weight-sharing collision structure of the per-cell scheme at tile
/// granularity.
///
/// # Memory layout
///
/// One packed `u32` per **tile**, row-major over the tile grid:
/// bits 30..0 hold the run base, bit 31 the tile's ξ sign (same
/// convention as [`HashPlan`], so [`HashPlan::apply_sign`] works on
/// these entries). Edge tiles whose cells fall outside `n × m1` are
/// still full runs; out-of-range cells are simply never read by the
/// row-level accessors. At 4 bytes per `th·tw` cells the plan is
/// `th·tw ×` smaller than the per-cell plan.
///
/// Requires `k ≥ th·tw` (a run must fit) — enforced here and in
/// `ModelSpec::validate`.
#[derive(Clone)]
pub struct TilePlan {
    /// Output rows of the virtual matrix (layer fan-out `n`).
    pub n: usize,
    /// Columns of the virtual matrix (`m + 1`, bias column included).
    pub m1: usize,
    /// Number of real (stored) weights the runs index into.
    pub k: usize,
    /// Tile shape `(th, tw)` in virtual cells.
    pub tile: (usize, usize),
    /// Tile-grid rows (`ceil(n / th)`).
    tiles_r: usize,
    /// Tile-grid columns (`ceil(m1 / tw)`).
    tiles_c: usize,
    /// `tiles_r * tiles_c` packed entries, row-major over the grid:
    /// `run_base | (ξ<0) << 31`.
    packed: Vec<u32>,
}

impl PartialEq for TilePlan {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.m1 == other.m1
            && self.k == other.k
            && self.tile == other.tile
            && self.packed == other.packed
    }
}

impl TilePlan {
    /// Build the plan for layer `layer_index` of a network seeded with
    /// `seed_base`. Deterministic: tile `(tr, tc)` hashes through the
    /// same `bucket_sign` primitive as the per-cell plan, with the tile
    /// grid standing in for the cell grid and the base drawn from
    /// `[0, k − th·tw]` so every run fits.
    pub fn build(
        n: usize,
        m1: usize,
        k: usize,
        tile: (usize, usize),
        layer_index: u32,
        seed_base: u32,
    ) -> TilePlan {
        let (th, tw) = tile;
        assert!(th >= 1 && tw >= 1, "tile dims must be at least 1×1 (got {th}×{tw})");
        let run = th * tw;
        assert!(
            k >= run,
            "bucket budget k = {k} must be at least the tile area {th}×{tw} = {run}"
        );
        assert!(
            (k as u64) < (1u64 << 31),
            "run base must fit in 31 bits to leave room for the sign (k = {k})"
        );
        let tiles_r = n.div_ceil(th);
        let tiles_c = m1.div_ceil(tw);
        let n_bases = (k - run + 1) as u32;
        let (s_h, s_xi) = layer_seeds(layer_index, seed_base);
        let mut packed = Vec::with_capacity(tiles_r * tiles_c);
        for tr in 0..tiles_r as u32 {
            for tc in 0..tiles_c as u32 {
                let (base, sg) = bucket_sign(tr, tc, tiles_c as u32, n_bases, s_h, s_xi);
                packed.push(base | if sg < 0.0 { HashPlan::SIGN_BIT } else { 0 });
            }
        }
        TilePlan { n, m1, k, tile, tiles_r, tiles_c, packed }
    }

    /// Tile grid shape `(tiles_r, tiles_c)`.
    #[inline]
    pub fn tiles(&self) -> (usize, usize) {
        (self.tiles_r, self.tiles_c)
    }

    /// Stored weights per tile (`th · tw`).
    #[inline]
    pub fn run_len(&self) -> usize {
        self.tile.0 * self.tile.1
    }

    /// Width of a tile-padded virtual row (`tiles_c · tw ≥ m1`). The
    /// SIMD kernels decompress rows at this width so the inner loop has
    /// no edge branches; callers pad activations with zeros to match.
    #[inline]
    pub fn padded_width(&self) -> usize {
        self.tiles_c * self.tile.1
    }

    /// Packed entry of tile `(tr, tc)`.
    #[inline(always)]
    pub fn tile_entry(&self, tr: usize, tc: usize) -> u32 {
        self.packed[tr * self.tiles_c + tc]
    }

    /// Packed entries of tile-row `tr` (length `tiles_c`).
    #[inline]
    pub fn row_tiles(&self, tr: usize) -> &[u32] {
        &self.packed[tr * self.tiles_c..(tr + 1) * self.tiles_c]
    }

    /// Run base of a packed entry.
    #[inline(always)]
    pub fn base(entry: u32) -> usize {
        (entry & HashPlan::BUCKET_MASK) as usize
    }

    /// Decompress virtual row `i` at padded width into `out`
    /// (`out.len() == padded_width()`): `tiles_c` contiguous sign-applied
    /// `tw`-length copies out of the stored weights. Columns `≥ m1` get
    /// the (well-defined) hashed values of the edge tiles' out-of-range
    /// cells; pairing with zero-padded activations makes them inert.
    #[inline]
    pub fn decompress_padded_row_into(&self, i: usize, params: &[f32], out: &mut [f32]) {
        let (th, tw) = self.tile;
        debug_assert_eq!(out.len(), self.padded_width());
        let in_tile = (i % th) * tw;
        for (chunk, &e) in out.chunks_exact_mut(tw).zip(self.row_tiles(i / th)) {
            let run = &params[Self::base(e) + in_tile..Self::base(e) + in_tile + tw];
            for (o, &w) in chunk.iter_mut().zip(run) {
                *o = HashPlan::apply_sign(e, w);
            }
        }
    }

    /// Decompress virtual row `i` into `out` (`out.len() == m1`) — the
    /// Eq. 7 view at true width, used by `virtual_matrix` and the
    /// per-cell reference tests.
    pub fn decompress_row_into(&self, i: usize, params: &[f32], out: &mut [f32]) {
        let (th, tw) = self.tile;
        let in_tile = (i % th) * tw;
        for (j, o) in out.iter_mut().enumerate() {
            let e = self.tile_entry(i / th, j / tw);
            *o = HashPlan::apply_sign(e, params[Self::base(e) + in_tile + j % tw]);
        }
    }

    /// Plan memory footprint in bytes (4 per tile).
    pub fn bytes(&self) -> usize {
        self.packed.len() * std::mem::size_of::<u32>()
    }
}

impl std::fmt::Debug for TilePlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TilePlan")
            .field("n", &self.n)
            .field("m1", &self.m1)
            .field("k", &self.k)
            .field("tile", &self.tile)
            .field("tiles", &(self.tiles_r, self.tiles_c))
            .field("bytes", &self.bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::DEFAULT_SEED_BASE;

    #[test]
    fn packing_matches_bucket_sign() {
        let (n, m1, k) = (9usize, 13usize, 17usize);
        let plan = HashPlan::build(n, m1, k, 3, DEFAULT_SEED_BASE);
        let (s_h, s_xi) = layer_seeds(3, DEFAULT_SEED_BASE);
        for i in 0..n {
            for (j, &e) in plan.row(i).iter().enumerate() {
                let (b, sg) = bucket_sign(i as u32, j as u32, m1 as u32, k as u32, s_h, s_xi);
                assert_eq!(HashPlan::bucket(e), b as usize, "bucket at ({i},{j})");
                let applied = HashPlan::apply_sign(e, 2.5);
                assert_eq!(applied, 2.5 * sg, "sign at ({i},{j})");
            }
        }
    }

    #[test]
    fn sign_xor_equals_multiply() {
        for &v in &[0.0f32, -0.0, 1.5, -3.25, f32::MIN_POSITIVE] {
            assert_eq!(HashPlan::apply_sign(0, v), v);
            assert_eq!(HashPlan::apply_sign(HashPlan::SIGN_BIT, v), -v);
            assert_eq!(HashPlan::apply_sign(HashPlan::SIGN_BIT | 42, v), -v);
        }
    }

    #[test]
    fn decompress_row_matches_eq7() {
        let (n, m1, k) = (4usize, 6usize, 5usize);
        let plan = HashPlan::build(n, m1, k, 0, DEFAULT_SEED_BASE);
        let params: Vec<f32> = (0..k).map(|i| 0.5 + i as f32).collect();
        let mut out = vec![0.0f32; m1];
        for i in 0..n {
            plan.decompress_row_into(i, &params, &mut out);
            for (j, &e) in plan.row(i).iter().enumerate() {
                let want = params[HashPlan::bucket(e)]
                    * if e & HashPlan::SIGN_BIT != 0 { -1.0 } else { 1.0 };
                assert_eq!(out[j], want);
            }
        }
    }

    #[test]
    fn four_bytes_per_cell() {
        let plan = HashPlan::build(10, 21, 7, 0, DEFAULT_SEED_BASE);
        assert_eq!(plan.bytes(), 4 * 10 * 21);
    }

    #[test]
    #[should_panic(expected = "31 bits")]
    fn oversized_k_panics() {
        let _ = HashPlan::build(1, 1, 1usize << 31, 0, DEFAULT_SEED_BASE);
    }

    #[test]
    fn inverse_is_a_permutation_of_the_forward_plan() {
        for (n, m1, k) in [(9usize, 13usize, 17usize), (6, 5, 1), (4, 7, 100)] {
            let plan = HashPlan::build(n, m1, k, 2, DEFAULT_SEED_BASE);
            let inv = plan.inverse();
            assert_eq!(inv.n_buckets(), k);
            assert_eq!(inv.cells.len(), n * m1, "every cell appears");
            assert_eq!(inv.bucket_offsets[0], 0);
            assert_eq!(*inv.bucket_offsets.last().unwrap() as usize, n * m1);
            let mut seen = vec![false; n * m1];
            for b in 0..k {
                let mut prev = None;
                for &cell in inv.cells_of(b) {
                    let idx = (cell & HashPlan::BUCKET_MASK) as usize;
                    assert!(!seen[idx], "cell {idx} appears twice");
                    seen[idx] = true;
                    // ascending (i, j) within a bucket — fixes the
                    // per-bucket summation order
                    if let Some(p) = prev {
                        assert!(p < idx, "bucket {b} not sorted");
                    }
                    prev = Some(idx);
                    let (i, j) = (idx / m1, idx % m1);
                    let fwd = plan.row(i)[j];
                    assert_eq!(HashPlan::bucket(fwd), b, "bucket disagrees at ({i},{j})");
                    assert_eq!(
                        cell & HashPlan::SIGN_BIT,
                        fwd & HashPlan::SIGN_BIT,
                        "sign disagrees at ({i},{j})"
                    );
                }
            }
            assert!(seen.iter().all(|&s| s), "every cell exactly once");
        }
    }

    #[test]
    fn inverse_is_cached_and_survives_clone() {
        let plan = HashPlan::build(5, 6, 4, 1, DEFAULT_SEED_BASE);
        let a = plan.inverse() as *const InversePlan;
        let b = plan.inverse() as *const InversePlan;
        assert_eq!(a, b, "OnceLock caches the build");
        let clone = plan.clone();
        assert_eq!(clone.inverse(), plan.inverse());
        assert_eq!(clone, plan, "equality ignores the cache");
    }

    #[test]
    fn balanced_ranges_are_monotone_and_cover_all_buckets() {
        let plan = HashPlan::build(40, 21, 64, 0, DEFAULT_SEED_BASE);
        let inv = plan.inverse();
        for n_ranges in [1usize, 2, 3, 7, 64, 100] {
            let bounds = inv.balanced_ranges(n_ranges);
            assert_eq!(bounds.len(), n_ranges + 1);
            assert_eq!(bounds[0], 0);
            assert_eq!(*bounds.last().unwrap(), 64);
            assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "monotone: {bounds:?}");
            // ranges partition the cells exactly
            let total: usize = bounds.windows(2).map(|w| (w[0]..w[1]).map(|b| inv.cells_of(b).len()).sum::<usize>()).sum();
            assert_eq!(total, 40 * 21);
        }
    }

    #[test]
    fn tile_packing_matches_bucket_sign_over_the_grid() {
        let (n, m1, k) = (9usize, 13usize, 100usize);
        let tile = (8usize, 8usize);
        let plan = TilePlan::build(n, m1, k, tile, 3, DEFAULT_SEED_BASE);
        let (tiles_r, tiles_c) = plan.tiles();
        assert_eq!((tiles_r, tiles_c), (2, 2), "ceil(9/8) × ceil(13/8)");
        let (s_h, s_xi) = layer_seeds(3, DEFAULT_SEED_BASE);
        let n_bases = (k - 64 + 1) as u32;
        for tr in 0..tiles_r {
            for tc in 0..tiles_c {
                let e = plan.tile_entry(tr, tc);
                let (b, sg) =
                    bucket_sign(tr as u32, tc as u32, tiles_c as u32, n_bases, s_h, s_xi);
                assert_eq!(TilePlan::base(e), b as usize, "base at ({tr},{tc})");
                assert!(TilePlan::base(e) + plan.run_len() <= k, "run fits at ({tr},{tc})");
                assert_eq!(HashPlan::apply_sign(e, 2.5), 2.5 * sg, "sign at ({tr},{tc})");
            }
        }
    }

    #[test]
    fn tile_decompress_row_matches_per_cell_formula() {
        // Odd (non-multiple) dims exercise the edge tiles.
        for tile in [(1usize, 8usize), (8, 8), (2, 4)] {
            let (n, m1, k) = (9usize, 13usize, 77usize);
            let plan = TilePlan::build(n, m1, k, tile, 1, DEFAULT_SEED_BASE);
            let params: Vec<f32> = (0..k).map(|i| 0.25 + i as f32).collect();
            let (th, tw) = tile;
            let mut out = vec![0.0f32; m1];
            for i in 0..n {
                plan.decompress_row_into(i, &params, &mut out);
                for j in 0..m1 {
                    let e = plan.tile_entry(i / th, j / tw);
                    let off = TilePlan::base(e) + (i % th) * tw + (j % tw);
                    let want = params[off]
                        * if e & HashPlan::SIGN_BIT != 0 { -1.0 } else { 1.0 };
                    assert_eq!(out[j], want, "tile {tile:?} cell ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn tile_padded_row_agrees_with_true_width_prefix() {
        let (n, m1, k) = (5usize, 11usize, 40usize);
        let plan = TilePlan::build(n, m1, k, (1, 8), 0, DEFAULT_SEED_BASE);
        assert_eq!(plan.padded_width(), 16);
        let params: Vec<f32> = (0..k).map(|i| (i as f32 - 7.0) * 0.5).collect();
        let mut padded = vec![0.0f32; plan.padded_width()];
        let mut narrow = vec![0.0f32; m1];
        for i in 0..n {
            plan.decompress_padded_row_into(i, &params, &mut padded);
            plan.decompress_row_into(i, &params, &mut narrow);
            assert_eq!(&padded[..m1], &narrow[..], "row {i} prefix");
        }
    }

    #[test]
    fn tile_plan_is_four_bytes_per_tile() {
        let plan = TilePlan::build(16, 24, 70, (8, 8), 0, DEFAULT_SEED_BASE);
        assert_eq!(plan.bytes(), 4 * 2 * 3);
    }

    #[test]
    #[should_panic(expected = "tile area")]
    fn tile_budget_smaller_than_run_panics() {
        let _ = TilePlan::build(8, 8, 63, (8, 8), 0, DEFAULT_SEED_BASE);
    }

    #[test]
    fn tile_plans_differ_across_layers_and_seeds() {
        let a = TilePlan::build(16, 16, 100, (1, 8), 0, DEFAULT_SEED_BASE);
        let b = TilePlan::build(16, 16, 100, (1, 8), 1, DEFAULT_SEED_BASE);
        let c = TilePlan::build(16, 16, 100, (1, 8), 0, DEFAULT_SEED_BASE ^ 0xABCD);
        assert_ne!(a, b, "layer index changes the mapping");
        assert_ne!(a, c, "seed base changes the mapping");
        assert_eq!(a, TilePlan::build(16, 16, 100, (1, 8), 0, DEFAULT_SEED_BASE), "deterministic");
    }
}
