//! xxh32 (Yann Collet's xxHash, 32-bit variant) — spec-complete, plus the
//! 4-byte-key specialization used on the hot path.

const PRIME32_1: u32 = 0x9E37_79B1;
const PRIME32_2: u32 = 0x85EB_CA77;
const PRIME32_3: u32 = 0xC2B2_AE3D;
const PRIME32_4: u32 = 0x27D4_EB2F;
const PRIME32_5: u32 = 0x1656_67B1;

#[inline]
fn round(acc: u32, lane: u32) -> u32 {
    acc.wrapping_add(lane.wrapping_mul(PRIME32_2))
        .rotate_left(13)
        .wrapping_mul(PRIME32_1)
}

#[inline]
fn avalanche(mut acc: u32) -> u32 {
    acc ^= acc >> 15;
    acc = acc.wrapping_mul(PRIME32_2);
    acc ^= acc >> 13;
    acc = acc.wrapping_mul(PRIME32_3);
    acc ^= acc >> 16;
    acc
}

/// xxh32 over an arbitrary byte slice.
pub fn xxh32(data: &[u8], seed: u32) -> u32 {
    let n = data.len();
    let mut pos = 0usize;
    let mut acc: u32;
    if n >= 16 {
        let mut v1 = seed.wrapping_add(PRIME32_1).wrapping_add(PRIME32_2);
        let mut v2 = seed.wrapping_add(PRIME32_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME32_1);
        while pos + 16 <= n {
            let w = |o: usize| u32::from_le_bytes(data[pos + o..pos + o + 4].try_into().unwrap());
            v1 = round(v1, w(0));
            v2 = round(v2, w(4));
            v3 = round(v3, w(8));
            v4 = round(v4, w(12));
            pos += 16;
        }
        acc = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
    } else {
        acc = seed.wrapping_add(PRIME32_5);
    }
    acc = acc.wrapping_add(n as u32);
    while pos + 4 <= n {
        let lane = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
        acc = acc
            .wrapping_add(lane.wrapping_mul(PRIME32_3))
            .rotate_left(17)
            .wrapping_mul(PRIME32_4);
        pos += 4;
    }
    while pos < n {
        acc = acc
            .wrapping_add((data[pos] as u32).wrapping_mul(PRIME32_5))
            .rotate_left(11)
            .wrapping_mul(PRIME32_1);
        pos += 1;
    }
    avalanche(acc)
}

/// xxh32 of one little-endian u32 key — the `len == 4` fast path, fully
/// inlined and branch-free. This is the hash on the virtual-matrix hot
/// path; the Pallas kernel computes exactly this expression in SIMD.
#[inline(always)]
pub fn xxh32_u32(key: u32, seed: u32) -> u32 {
    let acc = seed
        .wrapping_add(PRIME32_5)
        .wrapping_add(4)
        .wrapping_add(key.wrapping_mul(PRIME32_3))
        .rotate_left(17)
        .wrapping_mul(PRIME32_4);
    avalanche(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_bytes_across_lengths() {
        // exercise the 16-byte stripe loop, the 4-byte tail and byte tail
        let data: Vec<u8> = (0u8..64).collect();
        let mut distinct = std::collections::HashSet::new();
        for len in 0..=64 {
            distinct.insert(xxh32(&data[..len], 0));
        }
        assert_eq!(distinct.len(), 65, "lengths must hash distinctly");
    }

    /// Reference vectors for the official xxh32 algorithm, covering the
    /// empty input, sub-16-byte inputs (no stripe loop), a >16-byte
    /// input (stripe loop + tails), an exactly-one-stripe input, and
    /// non-zero seeds. Cross-checked against an independent
    /// implementation; the empty-input and spammish-repetition values
    /// are the published xxHash reference constants. Pinning these
    /// keeps tile-plan and cell-plan hashing from silently diverging
    /// from the spec under refactors.
    #[test]
    fn reference_vectors() {
        // (input, seed, expected)
        let cases: &[(&[u8], u32, u32)] = &[
            (b"", 0, 0x02CC_5D05),
            (b"", PRIME32_1, 0x36B7_8AE7),
            (b"a", 0, 0x550D_7456),
            (b"abc", 0, 0x32D1_53FF),
            (b"abc", 1, 0xAA3D_A8FF),
            (b"Nobody inspects the spammish repetition", 0, 0xE229_3B2F),
            (b"Nobody inspects the spammish repetition", PRIME32_5, 0xBC35_58F0),
        ];
        for &(input, seed, want) in cases {
            assert_eq!(
                xxh32(input, seed),
                want,
                "xxh32({:?}, {seed:#010x})",
                String::from_utf8_lossy(input)
            );
        }
        // Exactly one 16-byte stripe: bytes 0x00..0x0F.
        let stripe: Vec<u8> = (0u8..16).collect();
        assert_eq!(xxh32(&stripe, 0), 0xB728_37F4);
        // The 4-byte specialization against pinned values (not just
        // against our own byte-path implementation).
        assert_eq!(xxh32_u32(0xDEAD_BEEF, 0), 0xE4AA_E6D1);
        assert_eq!(xxh32_u32(0xDEAD_BEEF, 7), 0x2238_F8F3);
    }

    #[test]
    fn seed_changes_hash() {
        assert_ne!(xxh32(b"hashednets", 0), xxh32(b"hashednets", 1));
        assert_ne!(xxh32_u32(7, 0), xxh32_u32(7, 1));
    }

    #[test]
    fn avalanche_flips_many_bits() {
        // single-bit input changes should flip ~16 of 32 output bits
        let mut total = 0u32;
        for bit in 0..32 {
            let a = xxh32_u32(0, 0);
            let b = xxh32_u32(1 << bit, 0);
            total += (a ^ b).count_ones();
        }
        let avg = total as f64 / 32.0;
        assert!((12.0..20.0).contains(&avg), "weak avalanche: {avg}");
    }
}
