//! IDX (MNIST) file loader: if the real MNIST files are placed under
//! `data/mnist/` (`train-images-idx3-ubyte`, `train-labels-idx1-ubyte`,
//! `t10k-images-idx3-ubyte`, `t10k-labels-idx1-ubyte`), the MNIST
//! dataset uses them instead of the synthetic digits.

use super::{Dataset, Kind, Split, N_PIXELS};
use crate::tensor::Matrix;
use std::path::Path;

/// Attempt to load real MNIST; `None` if files are absent or malformed.
pub fn try_load_mnist(split: Split, n: usize) -> Option<Dataset> {
    let dir = std::env::var("HASHEDNETS_MNIST_DIR").unwrap_or_else(|_| "data/mnist".into());
    let (img_name, lbl_name) = match split {
        Split::Train => ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
        Split::Test => ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte"),
    };
    let images = read_idx_images(&Path::new(&dir).join(img_name))?;
    let labels = read_idx_labels(&Path::new(&dir).join(lbl_name))?;
    if images.len() != labels.len() {
        return None;
    }
    let n = n.min(labels.len());
    let mut m = Matrix::zeros(n, N_PIXELS);
    for i in 0..n {
        for (dst, &b) in m.row_mut(i).iter_mut().zip(&images[i]) {
            *dst = b as f32 / 255.0;
        }
    }
    Some(Dataset { kind: Kind::Mnist, images: m, labels: labels[..n].to_vec(), n_classes: 10 })
}

fn read_u32_be(b: &[u8], off: usize) -> Option<u32> {
    b.get(off..off + 4).map(|s| u32::from_be_bytes(s.try_into().unwrap()))
}

fn read_idx_images(path: &Path) -> Option<Vec<Vec<u8>>> {
    let bytes = std::fs::read(path).ok()?;
    if read_u32_be(&bytes, 0)? != 0x0000_0803 {
        return None;
    }
    let n = read_u32_be(&bytes, 4)? as usize;
    let rows = read_u32_be(&bytes, 8)? as usize;
    let cols = read_u32_be(&bytes, 12)? as usize;
    if rows * cols != N_PIXELS || bytes.len() < 16 + n * N_PIXELS {
        return None;
    }
    Some((0..n).map(|i| bytes[16 + i * N_PIXELS..16 + (i + 1) * N_PIXELS].to_vec()).collect())
}

fn read_idx_labels(path: &Path) -> Option<Vec<u8>> {
    let bytes = std::fs::read(path).ok()?;
    if read_u32_be(&bytes, 0)? != 0x0000_0801 {
        return None;
    }
    let n = read_u32_be(&bytes, 4)? as usize;
    if bytes.len() < 8 + n {
        return None;
    }
    Some(bytes[8..8 + n].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn loads_wellformed_idx() {
        let dir = std::env::temp_dir().join(format!("hn_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // two 28x28 images
        let mut img = vec![];
        img.extend_from_slice(&0x0803u32.to_be_bytes());
        img.extend_from_slice(&2u32.to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        img.extend(std::iter::repeat(128u8).take(2 * N_PIXELS));
        std::fs::File::create(dir.join("train-images-idx3-ubyte"))
            .unwrap().write_all(&img).unwrap();
        let mut lbl = vec![];
        lbl.extend_from_slice(&0x0801u32.to_be_bytes());
        lbl.extend_from_slice(&2u32.to_be_bytes());
        lbl.extend_from_slice(&[3u8, 7u8]);
        std::fs::File::create(dir.join("train-labels-idx1-ubyte"))
            .unwrap().write_all(&lbl).unwrap();

        std::env::set_var("HASHEDNETS_MNIST_DIR", &dir);
        let ds = try_load_mnist(Split::Train, 10).expect("should load");
        std::env::remove_var("HASHEDNETS_MNIST_DIR");
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.labels, vec![3, 7]);
        assert!((ds.images.at(0, 0) - 128.0 / 255.0).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("hn_idx_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train-images-idx3-ubyte"), [0u8; 32]).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), [0u8; 32]).unwrap();
        std::env::set_var("HASHEDNETS_MNIST_DIR", &dir);
        assert!(try_load_mnist(Split::Train, 10).is_none());
        std::env::remove_var("HASHEDNETS_MNIST_DIR");
        std::fs::remove_dir_all(&dir).ok();
    }
}
