//! RECT and CONVEX: the two binary 28×28 shape-discrimination datasets
//! (Larochelle et al. 2007). Both are procedurally *defined* tasks, so
//! our generators follow the published constructions directly.

use super::{Dataset, Kind, IMG_SIDE, N_PIXELS};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// RECT: a single white rectangle *outline* on black; label 0 if the
/// rectangle is wider than tall, 1 if taller than wide.
pub fn rectangles(n: usize, rng: &mut Pcg32) -> Dataset {
    let mut images = Matrix::zeros(n, N_PIXELS);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        // draw dimensions; re-draw until clearly wide or tall
        let (w, h) = loop {
            let w = 4 + rng.below(20) as usize;
            let h = 4 + rng.below(20) as usize;
            if w.abs_diff(h) >= 2 {
                break (w, h);
            }
        };
        let x0 = rng.below((IMG_SIDE - w) as u32) as usize;
        let y0 = rng.below((IMG_SIDE - h) as u32) as usize;
        let img = images.row_mut(i);
        for x in x0..x0 + w {
            img[y0 * IMG_SIDE + x] = 1.0;
            img[(y0 + h - 1) * IMG_SIDE + x] = 1.0;
        }
        for y in y0..y0 + h {
            img[y * IMG_SIDE + x0] = 1.0;
            img[y * IMG_SIDE + x0 + w - 1] = 1.0;
        }
        labels.push(if h > w { 1 } else { 0 });
    }
    Dataset { kind: Kind::Rect, images, labels, n_classes: 2 }
}

/// CONVEX: a filled white region on black; label 1 if the region is
/// convex, 0 otherwise.
///
/// Convex samples are filled convex polygons (hull of random points).
/// Non-convex samples are unions of two overlapping convex polygons
/// whose union has a concavity (verified by the row-interval test: a
/// filled set is convex iff every row and every column of lit pixels is
/// a single interval — we additionally require the violation to be
/// present so labels are never ambiguous).
pub fn convex(n: usize, rng: &mut Pcg32) -> Dataset {
    let mut images = Matrix::zeros(n, N_PIXELS);
    let mut labels = Vec::with_capacity(n);
    let mut buf = vec![0.0f32; N_PIXELS];
    for i in 0..n {
        let make_convex = rng.below(2) == 0;
        loop {
            buf.iter_mut().for_each(|v| *v = 0.0);
            if make_convex {
                let poly = random_convex_poly(rng, (14.0, 14.0), 11.0);
                fill_poly(&poly, &mut buf);
            } else {
                // two offset convex blobs — union generally non-convex
                let c1 = (8.0 + rng.next_f32() * 5.0, 8.0 + rng.next_f32() * 5.0);
                let c2 = (15.0 + rng.next_f32() * 5.0, 15.0 + rng.next_f32() * 5.0);
                let p1 = random_convex_poly(rng, c1, 5.5);
                let p2 = random_convex_poly(rng, c2, 5.5);
                fill_poly(&p1, &mut buf);
                fill_poly(&p2, &mut buf);
            }
            let lit = buf.iter().filter(|&&v| v > 0.5).count();
            if lit < 30 {
                continue; // too small, resample
            }
            let convex_now = is_convex_raster(&buf);
            if convex_now == make_convex {
                break;
            }
        }
        images.row_mut(i).copy_from_slice(&buf);
        labels.push(if make_convex { 1 } else { 0 });
    }
    Dataset { kind: Kind::Convex, images, labels, n_classes: 2 }
}

/// Random convex polygon: hull of points on a jittered circle.
fn random_convex_poly(rng: &mut Pcg32, center: (f32, f32), max_r: f32) -> Vec<(f32, f32)> {
    let k = 5 + rng.below(5) as usize;
    let base_r = max_r * rng.range_f32(0.55, 1.0);
    let mut pts: Vec<(f32, f32)> = (0..k)
        .map(|j| {
            let t = (j as f32 + rng.next_f32() * 0.6) / k as f32 * std::f32::consts::TAU;
            let r = base_r * rng.range_f32(0.7, 1.0);
            (center.0 + r * t.cos(), center.1 + r * t.sin())
        })
        .collect();
    // convex hull (gift wrapping on few points)
    pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    convex_hull(&pts)
}

fn cross(o: (f32, f32), a: (f32, f32), b: (f32, f32)) -> f32 {
    (a.0 - o.0) * (b.1 - o.1) - (a.1 - o.1) * (b.0 - o.0)
}

/// Andrew's monotone chain convex hull.
fn convex_hull(pts: &[(f32, f32)]) -> Vec<(f32, f32)> {
    let n = pts.len();
    if n < 3 {
        return pts.to_vec();
    }
    let mut hull: Vec<(f32, f32)> = Vec::with_capacity(2 * n);
    for &p in pts {
        while hull.len() >= 2 && cross(hull[hull.len() - 2], hull[hull.len() - 1], p) <= 0.0 {
            hull.pop();
        }
        hull.push(p);
    }
    let lower = hull.len() + 1;
    for &p in pts.iter().rev() {
        while hull.len() >= lower && cross(hull[hull.len() - 2], hull[hull.len() - 1], p) <= 0.0 {
            hull.pop();
        }
        hull.push(p);
    }
    hull.pop();
    hull
}

/// Scanline polygon fill (even-odd) into a 28×28 buffer.
fn fill_poly(poly: &[(f32, f32)], out: &mut [f32]) {
    if poly.len() < 3 {
        return;
    }
    for py in 0..IMG_SIDE {
        let y = py as f32 + 0.5;
        let mut xs: Vec<f32> = Vec::new();
        for i in 0..poly.len() {
            let (x1, y1) = poly[i];
            let (x2, y2) = poly[(i + 1) % poly.len()];
            if (y1 <= y && y2 > y) || (y2 <= y && y1 > y) {
                xs.push(x1 + (y - y1) / (y2 - y1) * (x2 - x1));
            }
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for pair in xs.chunks(2) {
            if pair.len() == 2 {
                let lo = pair[0].max(0.0).ceil() as usize;
                let hi = (pair[1].min((IMG_SIDE - 1) as f32)).floor() as usize;
                for px in lo..=hi.min(IMG_SIDE - 1) {
                    out[py * IMG_SIDE + px] = 1.0;
                }
            }
        }
    }
}

/// Raster convexity test: a lit set is convex iff every row *and* every
/// column of lit pixels forms one contiguous interval, and the region is
/// connected row-to-row. (Necessary-and-sufficient on axis directions;
/// strict enough to keep labels unambiguous for learning.)
fn is_convex_raster(img: &[f32]) -> bool {
    let lit = |x: usize, y: usize| img[y * IMG_SIDE + x] > 0.5;
    for y in 0..IMG_SIDE {
        let mut runs = 0;
        let mut prev = false;
        for x in 0..IMG_SIDE {
            let v = lit(x, y);
            if v && !prev {
                runs += 1;
            }
            prev = v;
        }
        if runs > 1 {
            return false;
        }
    }
    for x in 0..IMG_SIDE {
        let mut runs = 0;
        let mut prev = false;
        for y in 0..IMG_SIDE {
            let v = lit(x, y);
            if v && !prev {
                runs += 1;
            }
            prev = v;
        }
        if runs > 1 {
            return false;
        }
    }
    // diagonal direction checks (45°) to reject L-shapes aligned to axes
    for s in 0..(2 * IMG_SIDE - 1) {
        let mut runs = 0;
        let mut prev = false;
        for x in 0..IMG_SIDE {
            let y = s as isize - x as isize;
            if y < 0 || y >= IMG_SIDE as isize {
                continue;
            }
            let v = lit(x, y as usize);
            if v && !prev {
                runs += 1;
            }
            prev = v;
        }
        if runs > 1 {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_labels_match_geometry() {
        let mut rng = Pcg32::new(1, 1);
        let ds = rectangles(50, &mut rng);
        for i in 0..ds.len() {
            // recompute bounding box of lit pixels
            let img = ds.images.row(i);
            let (mut x0, mut x1, mut y0, mut y1) = (IMG_SIDE, 0, IMG_SIDE, 0);
            for y in 0..IMG_SIDE {
                for x in 0..IMG_SIDE {
                    if img[y * IMG_SIDE + x] > 0.5 {
                        x0 = x0.min(x);
                        x1 = x1.max(x);
                        y0 = y0.min(y);
                        y1 = y1.max(y);
                    }
                }
            }
            let (w, h) = (x1 - x0 + 1, y1 - y0 + 1);
            assert_eq!(ds.labels[i] == 1, h > w, "sample {i}: {w}x{h}");
        }
    }

    #[test]
    fn rect_is_outline_not_filled() {
        let mut rng = Pcg32::new(2, 1);
        let ds = rectangles(10, &mut rng);
        for i in 0..ds.len() {
            let lit = ds.images.row(i).iter().filter(|&&v| v > 0.5).count();
            assert!(lit < 120, "sample {i} looks filled: {lit} px");
        }
    }

    #[test]
    fn convex_labels_verified_by_independent_test() {
        let mut rng = Pcg32::new(3, 1);
        let ds = convex(40, &mut rng);
        for i in 0..ds.len() {
            let got = is_convex_raster(ds.images.row(i));
            assert_eq!(got, ds.labels[i] == 1, "sample {i}");
        }
        // both labels occur
        assert!(ds.labels.iter().any(|&l| l == 0));
        assert!(ds.labels.iter().any(|&l| l == 1));
    }

    #[test]
    fn hull_of_square_is_square() {
        let pts = vec![(0.0, 0.0), (0.0, 1.0), (1.0, 0.0), (1.0, 1.0), (0.5, 0.5)];
        let mut sorted = pts.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let hull = convex_hull(&sorted);
        assert_eq!(hull.len(), 4);
    }
}
