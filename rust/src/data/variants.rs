//! The Larochelle-2007 MNIST variant transformations: rotation and
//! background superimposition, applied to our procedural digits exactly
//! as the originals applied them to MNIST digits.

use super::{Dataset, IMG_SIDE};
use crate::util::rng::Pcg32;

/// Rotate every image by an independent uniform angle in [0, 2π)
/// (ROT / BG-IMG-ROT construction), bilinear resampling around center.
pub fn rotate_all(ds: &mut Dataset, rng: &mut Pcg32) {
    let mut buf = vec![0.0f32; IMG_SIDE * IMG_SIDE];
    for i in 0..ds.len() {
        let angle = rng.range_f32(0.0, std::f32::consts::TAU);
        rotate_into(ds.images.row(i), angle, &mut buf);
        ds.images.row_mut(i).copy_from_slice(&buf);
    }
}

/// Rotate one 28×28 image by `angle` into `out` (bilinear, zero-fill).
pub fn rotate_into(src: &[f32], angle: f32, out: &mut [f32]) {
    let c = (IMG_SIDE as f32 - 1.0) / 2.0;
    let (cs, sn) = (angle.cos(), angle.sin());
    for py in 0..IMG_SIDE {
        for px in 0..IMG_SIDE {
            // inverse-map output pixel to source coordinates
            let (dx, dy) = (px as f32 - c, py as f32 - c);
            let sx = c + cs * dx + sn * dy;
            let sy = c - sn * dx + cs * dy;
            out[py * IMG_SIDE + px] = bilinear(src, sx, sy);
        }
    }
}

fn bilinear(src: &[f32], x: f32, y: f32) -> f32 {
    if x < 0.0 || y < 0.0 || x > (IMG_SIDE - 1) as f32 || y > (IMG_SIDE - 1) as f32 {
        return 0.0;
    }
    let (x0, y0) = (x.floor() as usize, y.floor() as usize);
    let (x1, y1) = ((x0 + 1).min(IMG_SIDE - 1), (y0 + 1).min(IMG_SIDE - 1));
    let (fx, fy) = (x - x0 as f32, y - y0 as f32);
    let at = |xx: usize, yy: usize| src[yy * IMG_SIDE + xx];
    at(x0, y0) * (1.0 - fx) * (1.0 - fy)
        + at(x1, y0) * fx * (1.0 - fy)
        + at(x0, y1) * (1.0 - fx) * fy
        + at(x1, y1) * fx * fy
}

/// BG-RAND: uniform random noise behind the digit. Original protocol:
/// background pixels get U(0,1) noise; digit pixels keep their value
/// (digit occludes background).
pub fn background_random(ds: &mut Dataset, rng: &mut Pcg32) {
    for i in 0..ds.len() {
        let row = ds.images.row_mut(i);
        for v in row.iter_mut() {
            let noise = rng.next_f32();
            *v = v.max(noise * 0.95 * (1.0 - *v) + *v * *v);
            // digit (v≈1) dominates; background (v≈0) becomes noise
        }
    }
}

/// BG-IMG: textured background patches (the originals cut patches from
/// 20 natural images; we synthesize multi-octave value noise, which has
/// the same smooth-structured statistics).
pub fn background_image(ds: &mut Dataset, rng: &mut Pcg32) {
    let mut tex = vec![0.0f32; IMG_SIDE * IMG_SIDE];
    for i in 0..ds.len() {
        value_noise(&mut tex, rng);
        let row = ds.images.row_mut(i);
        for (v, &t) in row.iter_mut().zip(&tex) {
            // digit occludes texture; elsewhere texture shows through
            *v = *v + (1.0 - *v) * t;
        }
    }
}

/// Multi-octave value noise in [0, ~0.8] — smooth "natural image" patch.
fn value_noise(out: &mut [f32], rng: &mut Pcg32) {
    out.iter_mut().for_each(|v| *v = 0.0);
    let mut amp = 0.45;
    for octave in 0..3 {
        let cells = 3usize << octave; // 3, 6, 12 grid cells
        let mut grid = vec![0.0f32; (cells + 1) * (cells + 1)];
        for g in grid.iter_mut() {
            *g = rng.next_f32();
        }
        for py in 0..IMG_SIDE {
            let gy = py as f32 / (IMG_SIDE - 1) as f32 * cells as f32;
            let (y0, fy) = (gy.floor() as usize, gy.fract());
            let y1 = (y0 + 1).min(cells);
            for px in 0..IMG_SIDE {
                let gx = px as f32 / (IMG_SIDE - 1) as f32 * cells as f32;
                let (x0, fx) = (gx.floor() as usize, gx.fract());
                let x1 = (x0 + 1).min(cells);
                let at = |xx: usize, yy: usize| grid[yy * (cells + 1) + xx];
                // smoothstep interpolation
                let (ux, uy) = (fx * fx * (3.0 - 2.0 * fx), fy * fy * (3.0 - 2.0 * fy));
                let v = at(x0, y0) * (1.0 - ux) * (1.0 - uy)
                    + at(x1, y0) * ux * (1.0 - uy)
                    + at(x0, y1) * (1.0 - ux) * uy
                    + at(x1, y1) * ux * uy;
                out[py * IMG_SIDE + px] += amp * v;
            }
        }
        amp *= 0.5;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{digits, Kind, Split};
    use crate::util::rng::Pcg32;

    #[test]
    fn rotation_preserves_ink_roughly() {
        let mut rng = Pcg32::new(1, 1);
        let mut ds = digits::render_digits(10, &mut rng);
        let before: f32 = ds.images.data.iter().sum();
        rotate_all(&mut ds, &mut rng);
        let after: f32 = ds.images.data.iter().sum();
        // bilinear + clipping loses a little mass at corners only
        assert!(after > before * 0.6 && after < before * 1.2, "{before} -> {after}");
    }

    #[test]
    fn rotate_zero_is_near_identity() {
        let mut rng = Pcg32::new(2, 1);
        let ds = digits::render_digits(1, &mut rng);
        let mut out = vec![0.0; ds.images.cols];
        rotate_into(ds.images.row(0), 0.0, &mut out);
        let max_d = ds.images.row(0).iter().zip(&out)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d < 1e-4, "max_d {max_d}");
    }

    #[test]
    fn backgrounds_fill_empty_space() {
        let mut rng = Pcg32::new(3, 1);
        let mut ds = digits::render_digits(5, &mut rng);
        let zeros_before = ds.images.data.iter().filter(|&&v| v < 0.01).count();
        background_image(&mut ds, &mut rng);
        let zeros_after = ds.images.data.iter().filter(|&&v| v < 0.01).count();
        assert!(zeros_after < zeros_before / 3);
        assert!(ds.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn bg_variants_keep_digit_visible() {
        // the brightest pixels should still correlate with the clean digit
        let gen = |kind| crate::data::generate(kind, Split::Train, 8, 11);
        let clean = gen(Kind::Basic);
        let noisy = gen(Kind::BgRand);
        // same seed/stream family isn't shared across kinds, so just check
        // noisy images retain high-intensity structure
        assert!(noisy.images.data.iter().filter(|&&v| v > 0.9).count() > 0);
        assert!(clean.images.data.iter().filter(|&&v| v > 0.9).count() > 0);
    }
}
