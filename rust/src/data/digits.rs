//! Procedural digit renderer: MNIST-like 28×28 grayscale digits from
//! per-class stroke skeletons with per-sample jitter.
//!
//! Each class 0–9 is a set of polylines in normalized [0,1]² coordinates.
//! A sample applies a random affine transform (translation, anisotropic
//! scale, shear, small rotation), draws the strokes with a random
//! thickness using a distance-field (anti-aliased), then adds weak pixel
//! noise. The result is a deterministic, learnable 10-class problem with
//! the same interface and intra-class variability profile as MNIST
//! (DESIGN.md §3 documents the substitution).

use super::{Dataset, Kind, IMG_SIDE, N_PIXELS};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

type Poly = &'static [(f32, f32)];

/// Stroke skeletons per digit class (polylines, normalized coords).
fn skeleton(class: u8) -> Vec<Vec<(f32, f32)>> {
    fn ellipse(cx: f32, cy: f32, rx: f32, ry: f32, n: usize) -> Vec<(f32, f32)> {
        (0..=n)
            .map(|i| {
                let t = i as f32 / n as f32 * std::f32::consts::TAU;
                (cx + rx * t.cos(), cy + ry * t.sin())
            })
            .collect()
    }
    const P2: fn(Poly) -> Vec<(f32, f32)> = |p| p.to_vec();
    match class {
        0 => vec![ellipse(0.5, 0.5, 0.24, 0.36, 24)],
        1 => vec![
            P2(&[(0.38, 0.28), (0.56, 0.13), (0.56, 0.87)]),
            P2(&[(0.38, 0.87), (0.72, 0.87)]),
        ],
        2 => vec![P2(&[
            (0.28, 0.30), (0.32, 0.17), (0.50, 0.12), (0.66, 0.19), (0.70, 0.33),
            (0.58, 0.50), (0.42, 0.64), (0.28, 0.86), (0.74, 0.86),
        ])],
        3 => vec![P2(&[
            (0.28, 0.19), (0.46, 0.12), (0.64, 0.19), (0.67, 0.32), (0.55, 0.45),
            (0.46, 0.48), (0.58, 0.52), (0.69, 0.63), (0.66, 0.78), (0.48, 0.88),
            (0.28, 0.81),
        ])],
        4 => vec![
            P2(&[(0.64, 0.13), (0.30, 0.62), (0.78, 0.62)]),
            P2(&[(0.64, 0.13), (0.64, 0.88)]),
        ],
        5 => vec![P2(&[
            (0.70, 0.13), (0.32, 0.13), (0.30, 0.45), (0.50, 0.40), (0.66, 0.50),
            (0.69, 0.67), (0.58, 0.83), (0.38, 0.87), (0.27, 0.78),
        ])],
        6 => vec![P2(&[
            (0.62, 0.14), (0.46, 0.12), (0.33, 0.28), (0.28, 0.52), (0.31, 0.74),
            (0.46, 0.88), (0.62, 0.80), (0.67, 0.63), (0.55, 0.51), (0.38, 0.55),
            (0.30, 0.66),
        ])],
        7 => vec![
            P2(&[(0.26, 0.14), (0.73, 0.14), (0.42, 0.87)]),
            P2(&[(0.36, 0.50), (0.62, 0.50)]),
        ],
        8 => vec![
            ellipse(0.50, 0.31, 0.17, 0.18, 18),
            ellipse(0.50, 0.67, 0.21, 0.20, 18),
        ],
        9 => vec![
            ellipse(0.52, 0.33, 0.18, 0.19, 18),
            P2(&[(0.69, 0.35), (0.64, 0.88)]),
        ],
        _ => unreachable!("digit class out of range"),
    }
}

/// Affine jitter parameters for one sample.
struct Jitter {
    dx: f32,
    dy: f32,
    sx: f32,
    sy: f32,
    rot: f32,
    shear: f32,
    thickness: f32,
    intensity: f32,
}

impl Jitter {
    fn sample(rng: &mut Pcg32) -> Jitter {
        Jitter {
            dx: rng.range_f32(-0.09, 0.09),
            dy: rng.range_f32(-0.09, 0.09),
            sx: rng.range_f32(0.72, 1.15),
            sy: rng.range_f32(0.72, 1.15),
            rot: rng.range_f32(-0.35, 0.35),
            shear: rng.range_f32(-0.25, 0.25),
            thickness: rng.range_f32(0.035, 0.095),
            intensity: rng.range_f32(0.7, 1.0),
        }
    }

    fn apply(&self, (x, y): (f32, f32)) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let (sx, sy) = (cx * self.sx + cy * self.shear, cy * self.sy);
        let (c, s) = (self.rot.cos(), self.rot.sin());
        (0.5 + c * sx - s * sy + self.dx, 0.5 + s * sx + c * sy + self.dy)
    }
}

/// Distance from point `p` to segment `ab`.
fn seg_dist(p: (f32, f32), a: (f32, f32), b: (f32, f32)) -> f32 {
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let (wx, wy) = (p.0 - a.0, p.1 - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 <= 1e-12 { 0.0 } else { ((wx * vx + wy * vy) / len2).clamp(0.0, 1.0) };
    let (dx, dy) = (wx - t * vx, wy - t * vy);
    (dx * dx + dy * dy).sqrt()
}

/// Render one digit image into a 784-length buffer.
pub fn render_one(class: u8, rng: &mut Pcg32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), N_PIXELS);
    let jit = Jitter::sample(rng);
    // affine jitter + per-point "hand wobble" so strokes bend sample to
    // sample (the intra-class variability that makes the task MNIST-hard)
    let strokes: Vec<Vec<(f32, f32)>> = skeleton(class)
        .into_iter()
        .map(|poly| {
            poly.into_iter()
                .map(|p| {
                    let (x, y) = jit.apply(p);
                    (x + 0.02 * rng.normal(), y + 0.02 * rng.normal())
                })
                .collect()
        })
        .collect();

    // bounding box of strokes, padded by thickness, to skip empty pixels
    let (mut min_x, mut min_y, mut max_x, mut max_y) = (1f32, 1f32, 0f32, 0f32);
    for poly in &strokes {
        for &(x, y) in poly {
            min_x = min_x.min(x);
            min_y = min_y.min(y);
            max_x = max_x.max(x);
            max_y = max_y.max(y);
        }
    }
    let pad = jit.thickness + 2.0 / IMG_SIDE as f32;

    let aa = 1.2 / IMG_SIDE as f32; // anti-alias falloff width
    for py in 0..IMG_SIDE {
        let y = (py as f32 + 0.5) / IMG_SIDE as f32;
        for px in 0..IMG_SIDE {
            let x = (px as f32 + 0.5) / IMG_SIDE as f32;
            let idx = py * IMG_SIDE + px;
            if x < min_x - pad || x > max_x + pad || y < min_y - pad || y > max_y + pad {
                out[idx] = 0.0;
                continue;
            }
            let mut d = f32::MAX;
            for poly in &strokes {
                for w in poly.windows(2) {
                    d = d.min(seg_dist((x, y), w[0], w[1]));
                }
            }
            let v = 1.0 - ((d - jit.thickness * 0.5) / aa).clamp(0.0, 1.0);
            out[idx] = (v * jit.intensity).clamp(0.0, 1.0);
        }
    }
    // sensor noise everywhere (stronger on ink)
    for v in out.iter_mut() {
        let amp = if *v > 0.0 { 0.10 } else { 0.03 };
        *v = (*v + amp * (rng.next_f32() - 0.5)).clamp(0.0, 1.0);
    }
}

/// Render `n` digits with balanced classes in shuffled order (class
/// counts differ by at most one, like the curated originals).
pub fn render_digits(n: usize, rng: &mut Pcg32) -> Dataset {
    let mut images = Matrix::zeros(n, N_PIXELS);
    let order = rng.permutation(n);
    let mut labels = vec![0u8; n];
    for (pos, &slot) in order.iter().enumerate() {
        let class = (pos % 10) as u8;
        render_one(class, rng, images.row_mut(slot as usize));
        labels[slot as usize] = class;
    }
    Dataset { kind: Kind::Basic, images, labels, n_classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_have_ink_and_background() {
        let mut rng = Pcg32::new(1, 1);
        let mut buf = vec![0.0; N_PIXELS];
        for class in 0..10 {
            render_one(class, &mut rng, &mut buf);
            let ink: usize = buf.iter().filter(|&&v| v > 0.5).count();
            let blank: usize = buf.iter().filter(|&&v| v < 0.1).count();
            assert!(ink > 20, "class {class}: too little ink ({ink})");
            assert!(blank > 300, "class {class}: too little background");
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // average images of different classes should differ substantially
        let mut rng = Pcg32::new(2, 2);
        let mut means = vec![vec![0.0f32; N_PIXELS]; 10];
        let reps = 20;
        let mut buf = vec![0.0; N_PIXELS];
        for class in 0..10u8 {
            for _ in 0..reps {
                render_one(class, &mut rng, &mut buf);
                for (m, &v) in means[class as usize].iter_mut().zip(&buf) {
                    *m += v / reps as f32;
                }
            }
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d: f32 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum();
                assert!(d > 10.0, "classes {a} and {b} too similar: {d}");
            }
        }
    }

    #[test]
    fn jitter_varies_within_class() {
        let mut rng = Pcg32::new(3, 3);
        let mut a = vec![0.0; N_PIXELS];
        let mut b = vec![0.0; N_PIXELS];
        render_one(5, &mut rng, &mut a);
        render_one(5, &mut rng, &mut b);
        assert_ne!(a, b);
    }
}
