//! Dataset substrate: procedural equivalents of the paper's eight
//! benchmark datasets (§6).
//!
//! The paper evaluates on MNIST, four Larochelle-2007 MNIST variants
//! (ROT, BG-RAND, BG-IMG, BG-IMG-ROT) and two binary shape datasets
//! (RECT, CONVEX). The originals are not downloadable in this offline
//! environment, so we synthesize them (DESIGN.md §3):
//!
//! * digits are rendered procedurally from per-class stroke skeletons
//!   with affine/thickness jitter ([`digits`]),
//! * the variants apply the *same transformations* the original datasets
//!   applied — rotation, uniform-noise backgrounds, textured image
//!   backgrounds ([`variants`]),
//! * RECT and CONVEX follow their published constructions exactly
//!   ([`shapes`]).
//!
//! If real MNIST IDX files are present under `data/mnist/`, [`loader`]
//! uses them instead of the synthetic digits.
//!
//! Everything is deterministic in `(kind, split, seed)`.

pub mod digits;
pub mod loader;
pub mod shapes;
pub mod variants;

use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

pub const IMG_SIDE: usize = 28;
pub const N_PIXELS: usize = IMG_SIDE * IMG_SIDE;

/// The eight benchmark datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kind {
    /// Original MNIST (larger train split in the paper).
    Mnist,
    /// MNIST-BASIC: the Larochelle variant protocol with plain digits.
    Basic,
    /// Digits rotated uniformly in [0, 2π).
    Rot,
    /// Uniform random-noise background behind the digit.
    BgRand,
    /// Textured (image-patch) background behind the digit.
    BgImg,
    /// Rotation + textured background.
    BgImgRot,
    /// Wide-vs-tall rectangle outlines (binary).
    Rect,
    /// Convex vs. non-convex white region (binary).
    Convex,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "mnist" => Kind::Mnist,
            "basic" => Kind::Basic,
            "rot" => Kind::Rot,
            "bg-rand" | "bg_rand" | "bgrand" => Kind::BgRand,
            "bg-img" | "bg_img" | "bgimg" => Kind::BgImg,
            "bg-img-rot" | "bg_img_rot" | "bgimgrot" => Kind::BgImgRot,
            "rect" => Kind::Rect,
            "convex" => Kind::Convex,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kind::Mnist => "mnist",
            Kind::Basic => "basic",
            Kind::Rot => "rot",
            Kind::BgRand => "bg-rand",
            Kind::BgImg => "bg-img",
            Kind::BgImgRot => "bg-img-rot",
            Kind::Rect => "rect",
            Kind::Convex => "convex",
        }
    }

    pub fn all() -> [Kind; 8] {
        [
            Kind::Mnist, Kind::Basic, Kind::Rot, Kind::BgRand,
            Kind::BgImg, Kind::BgImgRot, Kind::Rect, Kind::Convex,
        ]
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Kind::Rect | Kind::Convex => 2,
            _ => 10,
        }
    }
}

/// An in-memory labeled image dataset, flattened to `n × 784`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: Kind,
    pub images: Matrix,
    pub labels: Vec<u8>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy minibatch `indices` into `(x, y)` buffers (padding with
    /// wrap-around so fixed-batch artifacts always get full batches).
    pub fn gather_batch(&self, indices: &[u32], batch: usize) -> (Matrix, Vec<i32>) {
        let mut x = Matrix::zeros(batch, self.images.cols);
        let mut y = vec![0i32; batch];
        self.gather_batch_into(indices, &mut x, &mut y);
        (x, y)
    }

    /// Allocation-free variant for hot loops: fill caller-owned buffers.
    pub fn gather_batch_into(&self, indices: &[u32], x: &mut Matrix, y: &mut [i32]) {
        let batch = y.len();
        debug_assert_eq!(x.rows, batch);
        for b in 0..batch {
            let idx = indices[b % indices.len()] as usize;
            x.row_mut(b).copy_from_slice(self.images.row(idx));
            y[b] = self.labels[idx] as i32;
        }
    }

    /// Split off the last `frac` of the data as a validation set
    /// (paper: 20% validation splits for hyperparameter selection).
    pub fn split_validation(&self, frac: f32) -> (Dataset, Dataset) {
        let n_val = ((self.len() as f32) * frac) as usize;
        let n_tr = self.len() - n_val;
        let take = |lo: usize, hi: usize| -> Dataset {
            let mut images = Matrix::zeros(hi - lo, self.images.cols);
            for (r, i) in (lo..hi).enumerate() {
                images.row_mut(r).copy_from_slice(self.images.row(i));
            }
            Dataset {
                kind: self.kind,
                images,
                labels: self.labels[lo..hi].to_vec(),
                n_classes: self.n_classes,
            }
        };
        (take(0, n_tr), take(n_tr, self.len()))
    }
}

/// Which split to synthesize — splits use disjoint PRNG streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// Generate (or load, for MNIST with local IDX files) a dataset split.
///
/// `n` is the number of examples; the paper uses 12000/50000 for the
/// variant datasets and 60000/10000 for MNIST. The benchmark harness
/// scales these down by default (see DESIGN.md §3).
pub fn generate(kind: Kind, split: Split, n: usize, seed: u64) -> Dataset {
    if kind == Kind::Mnist {
        if let Some(ds) = loader::try_load_mnist(split, n) {
            return ds;
        }
    }
    let stream = match split {
        Split::Train => 0x7261_7400,
        Split::Test => 0x7465_7300,
    } + kind_stream(kind);
    let mut rng = Pcg32::new(seed, stream);
    match kind {
        Kind::Rect => shapes::rectangles(n, &mut rng),
        Kind::Convex => shapes::convex(n, &mut rng),
        _ => {
            let mut ds = digits::render_digits(n, &mut rng);
            match kind {
                Kind::Mnist | Kind::Basic => {}
                Kind::Rot => variants::rotate_all(&mut ds, &mut rng),
                Kind::BgRand => variants::background_random(&mut ds, &mut rng),
                Kind::BgImg => variants::background_image(&mut ds, &mut rng),
                Kind::BgImgRot => {
                    variants::rotate_all(&mut ds, &mut rng);
                    variants::background_image(&mut ds, &mut rng);
                }
                Kind::Rect | Kind::Convex => unreachable!(),
            }
            ds.kind = kind;
            ds
        }
    }
}

fn kind_stream(kind: Kind) -> u64 {
    match kind {
        Kind::Mnist => 1,
        Kind::Basic => 2,
        Kind::Rot => 3,
        Kind::BgRand => 4,
        Kind::BgImg => 5,
        Kind::BgImgRot => 6,
        Kind::Rect => 7,
        Kind::Convex => 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_generate() {
        for kind in Kind::all() {
            let ds = generate(kind, Split::Train, 40, 7);
            assert_eq!(ds.len(), 40);
            assert_eq!(ds.images.cols, N_PIXELS);
            assert_eq!(ds.n_classes, kind.n_classes());
            assert!(ds.images.data.iter().all(|&p| (0.0..=1.0).contains(&p)));
            assert!(ds.labels.iter().all(|&l| (l as usize) < ds.n_classes));
            // every class present in a reasonable sample
            let mut seen = vec![false; ds.n_classes];
            for &l in &ds.labels {
                seen[l as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "{kind:?}: missing class");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(Kind::Rot, Split::Train, 16, 3);
        let b = generate(Kind::Rot, Split::Train, 16, 3);
        assert_eq!(a.images.data, b.images.data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn train_test_differ() {
        let a = generate(Kind::Basic, Split::Train, 32, 3);
        let b = generate(Kind::Basic, Split::Test, 32, 3);
        assert_ne!(a.images.data, b.images.data);
    }

    #[test]
    fn gather_batch_pads_with_wraparound() {
        let ds = generate(Kind::Basic, Split::Train, 10, 1);
        let (x, y) = ds.gather_batch(&[0, 1, 2], 5);
        assert_eq!(x.rows, 5);
        assert_eq!(y.len(), 5);
        assert_eq!(x.row(3), x.row(0));
        assert_eq!(y[4], y[1]);
    }

    #[test]
    fn validation_split_sizes() {
        let ds = generate(Kind::Basic, Split::Train, 50, 1);
        let (tr, val) = ds.split_validation(0.2);
        assert_eq!(tr.len(), 40);
        assert_eq!(val.len(), 10);
    }

    #[test]
    fn difficulty_ordering_backgrounds_add_energy() {
        // BG variants should have strictly more non-zero pixels than BASIC
        let basic = generate(Kind::Basic, Split::Train, 30, 5);
        let bg = generate(Kind::BgRand, Split::Train, 30, 5);
        let nz = |ds: &Dataset| {
            ds.images.data.iter().filter(|&&p| p > 0.05).count() as f64
                / ds.images.data.len() as f64
        };
        assert!(nz(&bg) > nz(&basic) * 2.0);
    }
}
