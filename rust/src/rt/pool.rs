//! `PoolExec` — a lazily-initialized, globally shared pool of parked
//! worker threads with a scoped fork/join API.
//!
//! # Why not `std::thread::scope`?
//!
//! `thread::scope` spawns and joins real OS threads on every call. For
//! the kernels in this crate (a few hundred microseconds of work per
//! layer invocation) the spawn/join round trip is pure overhead paid
//! per layer per call, on the forward, backward *and* serve paths.
//! `PoolExec` parks its workers on a condvar once, at first use, and a
//! [`PoolExec::run`] call costs one queue push plus a wakeup
//! (`benches/pool_overhead.rs` measures the difference).
//!
//! # Execution model
//!
//! [`PoolExec::run`]`(n_tasks, f)` executes `f(0) … f(n_tasks - 1)`
//! exactly once each and returns when all of them have finished. The
//! closure may borrow from the caller's stack (the pool erases the
//! lifetime internally and the completion barrier makes that sound —
//! same contract as `thread::scope`). Scheduling is dynamic: the caller
//! itself claims task indices alongside up to
//! `min(workers, n_tasks - 1)` pool workers, so progress never depends
//! on pool availability and nested `run` calls cannot deadlock — a
//! nested caller simply executes its own tasks inline.
//!
//! # Determinism
//!
//! Task *identity* is the index `t`, not the executing thread: a task
//! computes the same partition of the work no matter which worker picks
//! it up. All determinism contracts in the crate (the ordered-reduction
//! mode of [`crate::nn::TrainOptions`], the bit-identical row-parallel
//! matmuls) are therefore preserved verbatim on the pool: they depend
//! only on *which* task computes *what*, which is fixed by the caller's
//! partition, never on scheduling order.
//!
//! # Sizing
//!
//! The global pool holds `min(available_parallelism, 8) - 1` workers
//! (the caller is the `+1`; the kernels are memory-bound, so more than
//! 8 lanes shows diminishing returns — the same cap the old per-site
//! heuristics used). `HASHEDNETS_POOL_THREADS=<n>` overrides the total
//! concurrency, which is what [`max_concurrency`] reports and what
//! `TrainOptions::resolved_threads` / the kernel sizing heuristics in
//! `nn::layers` consult.
//!
//! # Examples
//!
//! ```
//! use hashednets::rt::pool;
//! use std::sync::atomic::{AtomicU32, Ordering};
//!
//! // index-parallel: every task index runs exactly once
//! let hits = AtomicU32::new(0);
//! pool::run(16, |_t| {
//!     hits.fetch_add(1, Ordering::Relaxed);
//! });
//! assert_eq!(hits.load(Ordering::Relaxed), 16);
//!
//! // part-parallel: task `t` takes ownership of part `t` — the usual
//! // way to hand each task a disjoint `&mut` chunk of one output
//! let mut out = vec![0usize; 8];
//! pool::run_parts(out.chunks_mut(2).collect(), |t, chunk: &mut [usize]| {
//!     for (i, v) in chunk.iter_mut().enumerate() {
//!         *v = t * 2 + i;
//!     }
//! });
//! assert_eq!(out, (0..8).collect::<Vec<_>>());
//! assert!(pool::max_concurrency() >= 1);
//! ```

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool concurrency: the kernels are memory-bound, so more
/// lanes than this shows diminishing returns (the same cap the old
/// per-call-site heuristics applied).
pub const MAX_CONCURRENCY: usize = 8;

/// One parallel invocation: a lifetime-erased task closure plus the
/// claim/completion state shared between the caller and the workers.
struct Job {
    /// Lifetime-erased pointer to the caller's `Fn(usize)` closure.
    ///
    /// Validity: the closure lives on the stack frame of
    /// [`PoolExec::run`], which does not return until `done == n_tasks`.
    /// A task index is only claimed via `next.fetch_add`, and `task` is
    /// only dereferenced *after* a successful claim (`t < n_tasks`) —
    /// at which point at least that task is unfinished, so `run` is
    /// still blocked and the closure is still alive. Once all indices
    /// are claimed, late poppers observe `next >= n_tasks` and never
    /// touch the pointer again.
    task: *const (dyn Fn(usize) + Sync),
    n_tasks: usize,
    /// Next unclaimed task index (may grow past `n_tasks`).
    next: AtomicUsize,
    /// Completed-task count; `run` blocks until it reaches `n_tasks`.
    done: Mutex<usize>,
    done_cv: Condvar,
    /// First panic payload from any task; `run` resumes it after the
    /// barrier, so assert/expect messages survive the pool hop just
    /// like they did under `thread::scope`.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

// SAFETY: `task` is only dereferenced under the validity rule documented
// on the field; all other state is atomics/locks.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and execute task indices until none remain. Both pool
    /// workers and the calling thread drain a job through this.
    fn help(&self) {
        loop {
            let t = self.next.fetch_add(1, Ordering::Relaxed);
            if t >= self.n_tasks {
                return;
            }
            // SAFETY: task `t` is claimed but not completed, so the
            // completion barrier in `run` has not been passed and the
            // closure behind `task` is alive (see field docs).
            let task = unsafe { &*self.task };
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(t)))
            {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let mut done = self.done.lock().unwrap();
            *done += 1;
            if *done == self.n_tasks {
                self.done_cv.notify_all();
            }
        }
    }
}

struct Shared {
    /// Help tickets: each entry asks one worker to join the referenced
    /// job. A worker that pops an already-drained job moves on for the
    /// cost of one atomic read.
    queue: Mutex<VecDeque<Arc<Job>>>,
    work_cv: Condvar,
}

/// A pool of parked worker threads. One global instance
/// ([`PoolExec::global`]) serves the whole process; constructing
/// additional pools is only useful in tests.
pub struct PoolExec {
    shared: Arc<Shared>,
    workers: usize,
}

impl PoolExec {
    /// Build a pool with `workers` parked threads (callers participate,
    /// so total concurrency is `workers + 1`).
    fn new(workers: usize) -> PoolExec {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
        });
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("hn-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        PoolExec { shared, workers }
    }

    /// The process-wide pool, spawned on first use (serving a model
    /// that never crosses a parallel threshold never starts a thread).
    pub fn global() -> &'static PoolExec {
        static POOL: OnceLock<PoolExec> = OnceLock::new();
        POOL.get_or_init(|| PoolExec::new(default_workers()))
    }

    /// Maximum useful parallel lanes: pool workers plus the caller.
    /// This is the number the kernel sizing heuristics partition for.
    pub fn max_concurrency(&self) -> usize {
        self.workers + 1
    }

    /// Execute `f(0) … f(n_tasks - 1)`, each exactly once, and return
    /// when all have finished. `f` may borrow from the caller's stack.
    /// Up to `min(workers, n_tasks - 1)` pool workers help; the caller
    /// always participates, so the call makes progress even on a busy
    /// (or zero-worker) pool and nested calls run their tasks inline.
    ///
    /// If any task panicked, the first panic payload is **resumed** on
    /// the caller after all tasks have settled (so borrowed data is
    /// never left aliased by a still-running worker, and assert/expect
    /// messages survive the pool hop like they did under
    /// `thread::scope`).
    pub fn run<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        if n_tasks == 0 {
            return;
        }
        if n_tasks == 1 || self.workers == 0 {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        let fref: &(dyn Fn(usize) + Sync) = &f;
        // Lifetime erasure: sound because this frame outlives every
        // dereference (see the `Job::task` field docs).
        #[allow(clippy::useless_transmute)]
        let task = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(fref)
        };
        let job = Arc::new(Job {
            task,
            n_tasks,
            next: AtomicUsize::new(0),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        let helpers = self.workers.min(n_tasks - 1);
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..helpers {
                q.push_back(Arc::clone(&job));
            }
        }
        self.shared.work_cv.notify_all();
        job.help();
        let mut done = job.done.lock().unwrap();
        while *done < n_tasks {
            done = job.done_cv.wait(done).unwrap();
        }
        drop(done);
        let payload = job.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Run one task per element of `parts`, handing task `t` ownership
    /// of `parts[t]` — the idiom for distributing disjoint `&mut`
    /// chunks of a single output buffer (`chunks_mut(..).collect()`).
    pub fn run_parts<T: Send, F: Fn(usize, T) + Sync>(&self, parts: Vec<T>, f: F) {
        match parts.len() {
            0 => {}
            1 => {
                let mut it = parts.into_iter();
                f(0, it.next().unwrap());
            }
            n => {
                let slots: Vec<Mutex<Option<T>>> =
                    parts.into_iter().map(|p| Mutex::new(Some(p))).collect();
                self.run(n, |t| {
                    let part = slots[t].lock().unwrap().take().expect("part claimed once");
                    f(t, part);
                });
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        job.help();
    }
}

/// Worker count for the global pool: total concurrency minus the
/// caller. `HASHEDNETS_POOL_THREADS` overrides the total.
fn default_workers() -> usize {
    let total = std::env::var("HASHEDNETS_POOL_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(MAX_CONCURRENCY)
        });
    total.clamp(1, 64) - 1
}

/// [`PoolExec::run`] on the global pool.
pub fn run<F: Fn(usize) + Sync>(n_tasks: usize, f: F) {
    PoolExec::global().run(n_tasks, f)
}

/// [`PoolExec::run_parts`] on the global pool.
pub fn run_parts<T: Send, F: Fn(usize, T) + Sync>(parts: Vec<T>, f: F) {
    PoolExec::global().run_parts(parts, f)
}

/// [`PoolExec::max_concurrency`] of the global pool.
pub fn max_concurrency() -> usize {
    PoolExec::global().max_concurrency()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_index_runs_exactly_once() {
        for n_tasks in [0usize, 1, 2, 3, 7, 16, 64, 257] {
            let counts: Vec<AtomicUsize> = (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();
            run(n_tasks, |t| {
                counts[t].fetch_add(1, Ordering::Relaxed);
            });
            for (t, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "task {t} of {n_tasks}");
            }
        }
    }

    #[test]
    fn parts_are_delivered_to_matching_task_index() {
        let mut out = vec![0usize; 40];
        let chunk = 7; // uneven tail chunk
        run_parts(out.chunks_mut(chunk).collect(), |t, part: &mut [usize]| {
            for (i, v) in part.iter_mut().enumerate() {
                *v = t * chunk + i;
            }
        });
        assert_eq!(out, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn borrows_caller_stack_and_observes_writes() {
        let input: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 1000];
        run_parts(out.chunks_mut(128).collect(), |t, part: &mut [f32]| {
            for (i, v) in part.iter_mut().enumerate() {
                *v = input[t * 128 + i] * 2.0;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as f32) * 2.0);
        }
    }

    #[test]
    fn nested_run_completes() {
        let total = AtomicUsize::new(0);
        run(4, |_| {
            run(4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn concurrent_runs_from_many_threads() {
        // serve workers hammer the pool concurrently; every call must
        // still complete all of its own tasks
        let done: Vec<std::thread::JoinHandle<usize>> = (0..6)
            .map(|_| {
                std::thread::spawn(|| {
                    let mut total = 0usize;
                    for _ in 0..50 {
                        let c = AtomicUsize::new(0);
                        run(8, |_| {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                        total += c.load(Ordering::Relaxed);
                    }
                    total
                })
            })
            .collect();
        for h in done {
            assert_eq!(h.join().unwrap(), 400);
        }
    }

    #[test]
    fn task_panic_propagates_to_caller_with_payload() {
        let result = std::panic::catch_unwind(|| {
            run(8, |t| {
                if t == 3 {
                    panic!("boom");
                }
            });
        });
        let payload = result.expect_err("panic must reach the caller");
        // the original message survives the pool hop (resume_unwind)
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "boom");
        // pool is still usable afterwards
        let c = AtomicUsize::new(0);
        run(4, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn max_concurrency_is_capped_and_positive() {
        let c = max_concurrency();
        assert!(c >= 1);
        assert!(c <= 64);
    }

    #[test]
    fn private_pool_with_zero_workers_runs_inline() {
        let pool = PoolExec::new(0);
        assert_eq!(pool.max_concurrency(), 1);
        let c = AtomicUsize::new(0);
        pool.run(5, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 5);
    }
}
