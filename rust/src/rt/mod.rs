//! `rt` — the shared execution runtime.
//!
//! Every CPU-parallel hot path in the system (row-parallel matmuls, the
//! hashed scratch-row forward, the hashed backward, serving predict
//! calls) used to spawn and join fresh OS threads via
//! `std::thread::scope` on **every** layer invocation. At the paper's
//! layer sizes a spawn/join round trip is a measurable fraction of the
//! kernel itself, so the tax was paid per layer per call — exactly the
//! hidden runtime cost the paper's Eq. 8–12 analysis says hashed weight
//! sharing should not have.
//!
//! [`pool::PoolExec`] replaces all of those sites with one
//! lazily-initialized, globally shared pool of parked worker threads
//! and a scoped `run(n_tasks, |t| …)` API: tasks are identified by
//! index, task `t` always computes the same partition of the work
//! regardless of which worker executes it, and `run` does not return
//! until every task has finished — which is what preserves the existing
//! block-partition + ordered-reduction determinism contract
//! (`nn::TrainOptions`) on top of a dynamic scheduler.

pub mod pool;

pub use pool::PoolExec;
