//! # HashedNets — Compressing Neural Networks with the Hashing Trick
//!
//! A full-system reproduction of Chen et al., ICML 2015, as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build-time Python): a Pallas kernel that decompresses
//!   the virtual weight matrix `V_ij = ξ(i,j) · w_{h(i,j)}` on the fly
//!   inside the matmul (`python/compile/kernels/`).
//! * **Layer 2** (build-time Python): the paper's model family — HashNet,
//!   HashNet_DK and the four baselines — lowered once to HLO text
//!   (`python/compile/model.py`, `aot.py`).
//! * **Layer 3** (this crate): the runtime coordinator. Loads the AOT
//!   artifacts through PJRT ([`runtime`]), drives training experiments
//!   ([`coordinator`]), generates the paper's eight datasets
//!   procedurally ([`data`]), re-implements the exact same math natively
//!   for cross-validation ([`nn`]), and serves compressed models with a
//!   dynamic batcher ([`serve`]).
//!
//! One model identity spans all of it: the [`model`] subsystem's typed
//! `ModelSpec` plus the versioned single-file `ModelBundle` — what
//! `train` saves, `compress` produces and `serve` (hot-)loads; the
//! manifest/checkpoint pair in [`runtime`] remains as compat shims.
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quickstart
//!
//! ```bash
//! make artifacts && cargo build --release
//! ./target/release/hashednets train --config hashnet_3l_h100_o10_c1-8 --dataset mnist
//! ```

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod hash;
pub mod model;
pub mod nn;
pub mod rt;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
