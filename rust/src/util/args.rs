//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut a = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.opts.insert(stripped.to_string(), v);
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Every `--key` present on the command line (options and flags).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.opts
            .keys()
            .map(String::as_str)
            .chain(self.flags.iter().map(String::as_str))
    }

    /// Keys that are not in `known` — misspelled or unsupported
    /// options, which `parse` itself accepts silently. Callers warn on
    /// these (or error under `--strict`).
    pub fn unknown_keys(&self, known: &[&str]) -> Vec<String> {
        let mut out: Vec<String> =
            self.keys().filter(|k| !known.contains(k)).map(String::from).collect();
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --config foo --epochs 10 pos1 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("foo"));
        assert_eq!(a.get_usize("epochs", 1), 10);
        assert!(!a.has_flag("config"));
        assert!(a.has_flag("fast"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn bare_token_after_flagish_key_is_its_value() {
        // documented ambiguity: `--fast pos1` parses as fast=pos1; put
        // flags last or use `=` when mixing with positionals
        let a = parse("x --fast pos1");
        assert_eq!(a.get("fast"), Some("pos1"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("repro --experiment=fig2 --force");
        assert_eq!(a.get("experiment"), Some("fig2"));
        assert!(a.has_flag("force"));
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse("x --dry-run --seed 7");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_f32("lr", 0.1), 0.1);
        assert_eq!(a.get_or("host", "127.0.0.1"), "127.0.0.1");
    }

    #[test]
    fn unknown_keys_are_collected_not_swallowed() {
        let a = parse("train --config foo --epochz 10 --fastt --epochs 3");
        let unknown = a.unknown_keys(&["config", "epochs", "strict"]);
        assert_eq!(unknown, vec!["epochz".to_string(), "fastt".to_string()]);
        assert!(a.unknown_keys(&["config", "epochs", "epochz", "fastt"]).is_empty());
        // --strict itself is an ordinary flag the caller whitelists
        let s = parse("train --strict --config foo");
        assert!(s.has_flag("strict"));
        assert!(s.unknown_keys(&["config", "strict"]).is_empty());
    }
}
