//! Offline-friendly utility substrates.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (serde, clap, rand, criterion)
//! are unavailable; these modules provide the small subsets we need,
//! with tests.

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;

/// Round `x` to `d` decimal digits (for stable metric output).
pub fn round_to(x: f64, d: u32) -> f64 {
    let p = 10f64.powi(d as i32);
    (x * p).round() / p
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_to_digits() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(-1.005, 1), -1.0);
    }

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
