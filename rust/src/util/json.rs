//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for `artifacts/manifest.json`, metric
//! logs and the serving protocol; `serde` is not available offline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (adequate for our manifests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Nesting bound for the recursive-descent parser. Without it, hostile
/// input like ten thousand `[`s drives unbounded recursion into a stack
/// overflow — an *abort*, not a catchable panic — and the parser sits
/// on a socket trust boundary. 128 levels is far beyond anything the
/// manifests or the wire protocol produce.
pub const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Parse raw socket bytes: UTF-8 is validated here (with a readable
    /// error) instead of trusting the transport to deliver text.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, String> {
        let s = std::str::from_utf8(b).map_err(|e| format!("invalid utf-8: {e}"))?;
        Json::parse(s)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing str '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing num '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], String> {
        self.get(key).and_then(Json::as_arr).ok_or_else(|| format!("missing arr '{key}'"))
    }

    // -- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building metric records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current container nesting, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(c @ (b'{' | b'[')) => {
                if self.depth >= MAX_DEPTH {
                    return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            // (surrogate pairs unsupported — not used in our data)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar; clamp the advance so a
                    // multi-byte lead truncated at end-of-input errors
                    // instead of slicing past the buffer
                    let start = self.i;
                    let len = utf8_len(self.b[self.i]);
                    self.i = (self.i + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| "invalid utf8")?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{txt}': {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":true,"d":null},"s":"x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_manifest_shapes() {
        let src = r#"{"params":[{"name":"w0","shape":[42],"init_std":0.3}]}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.req_arr("params").unwrap()[0];
        assert_eq!(p.req_str("name").unwrap(), "w0");
        assert_eq!(p.req_arr("shape").unwrap()[0].as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let w = Json::Str("tab\there".into()).to_string();
        assert_eq!(w, r#""tab\there""#);
    }

    #[test]
    fn integers_stay_integral_in_output() {
        assert_eq!(Json::Num(50.0).to_string(), "50");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    // -- trust-boundary properties: the parser reads raw socket input,
    // so malformed bytes must produce Err, never a panic or an abort --

    #[test]
    fn every_truncation_of_valid_input_errors_without_panic() {
        let full = r#"{"a":[1,2.5,{"b":"cA\n"}],"d":-1.5e3,"e":[true,null,false]}"#;
        for cut in 0..full.len() {
            // prefixes are all ASCII-safe cut points; each must return
            // (not panic) — almost all are Err, none are checked for
            // a specific message
            let _ = Json::parse(&full[..cut]);
        }
        assert!(Json::parse("").is_err());
        assert!(Json::parse(r#"{"a""#).is_err());
        assert!(Json::parse(r#""caf\"#).is_err());
        assert!(Json::parse(r#""\u00"#).is_err());
    }

    #[test]
    fn deeply_nested_input_errors_instead_of_overflowing_the_stack() {
        // 100k open brackets would previously recurse ~200k frames deep
        // and abort the process on stack overflow; now it's a plain Err
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).unwrap_err().contains("nesting"));
        let deep_obj = "{\"k\":".repeat(50_000) + "1" + &"}".repeat(50_000);
        assert!(Json::parse(&deep_obj).unwrap_err().contains("nesting"));
        // well inside the bound still parses fine
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(Json::parse(&ok).is_ok());
        // one past the bound is the first rejection
        let edge = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&edge).unwrap_err().contains("nesting"));
    }

    #[test]
    fn nan_and_inf_literals_are_rejected_not_parsed() {
        // JSON has no NaN/Infinity tokens; a client must send null or a
        // string instead, and the parser must refuse cleanly
        for s in [
            "NaN",
            "nan",
            "Infinity",
            "-Infinity",
            "inf",
            "[NaN]",
            r#"{"x":Infinity}"#,
            "-",
            "1e",
            "--5",
        ] {
            assert!(Json::parse(s).is_err(), "{s:?} must be rejected");
        }
    }

    #[test]
    fn arbitrary_bytes_never_panic_the_parser() {
        let mut rng = crate::util::rng::Pcg32::new(0x15A1, 3);
        for _ in 0..500 {
            let n = (rng.next_u32() % 64) as usize;
            let bytes: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xff) as u8).collect();
            let _ = Json::parse_bytes(&bytes); // Err or Ok, never a panic
            let _ = Json::parse(&String::from_utf8_lossy(&bytes));
        }
        assert!(Json::parse_bytes(&[0xff, 0x90, b'"']).unwrap_err().contains("utf-8"));
        // a truncated multi-byte sequence at end of input
        assert!(Json::parse_bytes(b"\"caf\xc3").is_err());
        assert_eq!(Json::parse_bytes(b"{\"a\":1}").unwrap().req_f64("a").unwrap(), 1.0);
    }
}
