//! Benchmark harness (criterion is not available offline).
//!
//! Provides warmup + repeated timed runs with mean/stddev/percentiles,
//! used by every `benches/*.rs` target (declared `harness = false`).

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub throughput: Option<f64>, // items/sec when items_per_iter set
}

impl BenchStats {
    pub fn report(&self) -> String {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        let mut line = format!(
            "{:<44} {:>12} ± {:>10}  (p50 {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt(self.mean_ns),
            fmt(self.stddev_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
            self.iters
        );
        if let Some(tp) = self.throughput {
            line.push_str(&format!("  [{tp:.1}/s]"));
        }
        line
    }
}

/// Bench runner with fixed warmup/measure counts (deterministic wall time).
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub items_per_iter: Option<f64>,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 20, items_per_iter: None, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters, ..Default::default() }
    }

    /// Time `f` and record stats under `name`. Returns the stats.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = crate::util::mean(&samples);
        let stats = BenchStats {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: mean,
            stddev_ns: crate::util::stddev(&samples),
            p50_ns: samples[samples.len() / 2],
            p95_ns: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
            throughput: self.items_per_iter.map(|n| n / (mean / 1e9)),
        };
        println!("{}", stats.report());
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Record externally measured stats (for targets that time whole
    /// request flows rather than a closure, e.g. the serving bench).
    pub fn push(&mut self, stats: BenchStats) {
        self.results.push(stats);
    }

    /// Write all recorded results as a JSON array (one object per case:
    /// name, iters, mean_ns, stddev_ns, p50_ns, p95_ns, throughput).
    /// Bench targets write `BENCH_<name>.json` at the repo root so the
    /// perf trajectory is tracked across PRs.
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        use crate::util::json::{num, obj, Json};
        let arr = Json::Arr(
            self.results
                .iter()
                .map(|s| {
                    obj(vec![
                        ("name", Json::Str(s.name.clone())),
                        ("iters", num(s.iters as f64)),
                        ("mean_ns", num(s.mean_ns)),
                        ("stddev_ns", num(s.stddev_ns)),
                        ("p50_ns", num(s.p50_ns)),
                        ("p95_ns", num(s.p95_ns)),
                        ("throughput", s.throughput.map(num).unwrap_or(Json::Null)),
                    ])
                })
                .collect(),
        );
        std::fs::write(path, arr.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(1, 5);
        let stats = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(stats.mean_ns > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::new(0, 3);
        b.items_per_iter = Some(100.0);
        let s = b.run("tp", || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(s.throughput.unwrap() > 0.0);
    }

    #[test]
    fn write_json_roundtrips() {
        let mut b = Bench::new(0, 2);
        b.run("case_a", || std::hint::black_box(1 + 1));
        b.items_per_iter = Some(10.0);
        b.run("case_b", || std::hint::black_box(2 + 2));
        let path = std::env::temp_dir().join(format!("bench_{}.json", std::process::id()));
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let v = crate::util::json::Json::parse(&text).unwrap();
        let cases = v.as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].req_str("name").unwrap(), "case_a");
        assert!(cases[0].req_f64("mean_ns").unwrap() >= 0.0);
        assert!(cases[0].get("throughput").unwrap().as_f64().is_none());
        assert!(cases[1].get("throughput").unwrap().as_f64().unwrap() > 0.0);
    }
}
