//! Deterministic PRNGs: PCG32 (O'Neill 2014) for data/init streams.
//!
//! Every random decision in the coordinator — dataset synthesis,
//! parameter init, shuffling, hyperparameter draws — flows through
//! [`Pcg32`] seeded from an explicit `(seed, stream)` pair, so whole
//! experiment grids are bit-reproducible. (No `rand` crate offline.)

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, selectable stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next u32, advancing the state.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u32() as f64 + self.next_u32() as f64 * 4294967296.0)
            / 18446744073709551616.0
    }

    /// Unbiased integer in [0, bound) (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u32 + 1) as usize;
            p.swap(i, j);
        }
        p
    }

    /// Fill a slice with N(0, std) values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u32> = (0..8).map({
            let mut r = Pcg32::new(42, 1);
            move |_| r.next_u32()
        }).collect();
        let b: Vec<u32> = (0..8).map({
            let mut r = Pcg32::new(42, 1);
            move |_| r.next_u32()
        }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..16).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg32::new(7, 0);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::new(3, 9);
        let mut counts = [0u32; 10];
        for _ in 0..10_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11, 4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal() as f64).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((s - 1.0).abs() < 0.03, "std {s}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg32::new(5, 5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
