//! Readiness reactor primitives: direct `extern "C"` bindings to
//! `poll(2)` and (on Linux) `epoll(7)`, in the same vendored-stub
//! spirit as the rest of the workspace — no new crate dependencies.
//!
//! The serve front-end (`serve/conn.rs`) is a single-threaded
//! event loop: every listener/connection registers its fd here with a
//! `usize` token, [`Poller::wait`] parks until readiness or timeout, and
//! the loop dispatches on the returned [`Event`]s. Worker threads wake
//! the loop through [`Waker`] (a nonblocking socketpair — the classic
//! self-pipe trick) when they finish a reply.
//!
//! Two backends share one API:
//!
//! * [`PollerKind::Poll`] — portable `poll(2)` over a dense pollfd vec.
//!   O(n) per wait, fine up to a few thousand fds, works everywhere.
//! * [`PollerKind::Epoll`] — Linux `epoll` with O(ready) waits; this is
//!   what the 10k-connection cell of `benches/serve_scale.rs` exercises.
//!
//! [`PollerKind::Auto`] picks epoll on Linux, poll elsewhere.

use std::io;
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

// ---------------------------------------------------------------------------
// raw syscall surface (the only unsafe in the serve layer)
// ---------------------------------------------------------------------------

#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;

// Linux passes epoll_event packed on x86-64 (kernel ABI quirk); other
// architectures use natural alignment.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[cfg(all(target_os = "linux", not(target_arch = "x86_64")))]
#[repr(C)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[cfg(target_os = "linux")]
const EPOLLIN: u32 = 0x001;
#[cfg(target_os = "linux")]
const EPOLLOUT: u32 = 0x004;
#[cfg(target_os = "linux")]
const EPOLLERR: u32 = 0x008;
#[cfg(target_os = "linux")]
const EPOLLHUP: u32 = 0x010;
#[cfg(target_os = "linux")]
const EPOLL_CTL_ADD: i32 = 1;
#[cfg(target_os = "linux")]
const EPOLL_CTL_DEL: i32 = 2;
#[cfg(target_os = "linux")]
const EPOLL_CTL_MOD: i32 = 3;
#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: i32 = 0o2000000;

#[repr(C)]
#[derive(Clone, Copy)]
struct RLimit {
    cur: u64,
    max: u64,
}

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: i32 = 7;
#[cfg(not(target_os = "linux"))]
const RLIMIT_NOFILE: i32 = 8;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout_ms: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    #[cfg(target_os = "linux")]
    fn epoll_create1(flags: i32) -> i32;
    #[cfg(target_os = "linux")]
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    #[cfg(target_os = "linux")]
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
}

fn timeout_to_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis().min(i32::MAX as u128) as i32;
            // round sub-millisecond waits up so a 100µs deadline does not
            // degenerate into a busy loop
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms
            }
        }
    }
}

// ---------------------------------------------------------------------------
// public API
// ---------------------------------------------------------------------------

/// Which readiness backend to use. Parsed from `--poller`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PollerKind {
    /// epoll on Linux, `poll(2)` elsewhere.
    Auto,
    /// Linux `epoll(7)`; errors at construction on other platforms.
    Epoll,
    /// Portable `poll(2)`.
    Poll,
}

impl PollerKind {
    pub fn parse(s: &str) -> anyhow::Result<PollerKind> {
        match s {
            "auto" => Ok(PollerKind::Auto),
            "epoll" => Ok(PollerKind::Epoll),
            "poll" => Ok(PollerKind::Poll),
            other => anyhow::bail!("unknown poller '{other}' (expected auto|epoll|poll)"),
        }
    }

    fn resolve(self) -> PollerKind {
        match self {
            PollerKind::Auto => {
                if cfg!(target_os = "linux") {
                    PollerKind::Epoll
                } else {
                    PollerKind::Poll
                }
            }
            k => k,
        }
    }
}

/// Interest set for a registered fd.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
}

/// One readiness notification: the registered token plus what happened.
/// `readable`/`writable` fold HUP/ERR in, so the owner always observes
/// the condition by performing the I/O (read returns 0 / write errors).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

enum Backend {
    Poll {
        fds: Vec<PollFd>,
        tokens: Vec<usize>,
    },
    #[cfg(target_os = "linux")]
    Epoll {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
        registered: usize,
    },
}

/// A readiness reactor over raw fds. Single-threaded: not `Sync`, owned
/// by the event loop. Worker threads interact only through [`Waker`].
pub struct Poller {
    backend: Backend,
}

impl Poller {
    pub fn new(kind: PollerKind) -> io::Result<Poller> {
        match kind.resolve() {
            PollerKind::Poll => Ok(Poller {
                backend: Backend::Poll { fds: Vec::new(), tokens: Vec::new() },
            }),
            #[cfg(target_os = "linux")]
            PollerKind::Epoll => {
                let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
                if epfd < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(Poller {
                    backend: Backend::Epoll {
                        epfd,
                        buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
                        registered: 0,
                    },
                })
            }
            #[cfg(not(target_os = "linux"))]
            PollerKind::Epoll => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "epoll poller is only available on linux",
            )),
            PollerKind::Auto => unreachable!("resolve() removed Auto"),
        }
    }

    /// Name of the resolved backend ("epoll" or "poll"), for logs/stats.
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Poll { .. } => "poll",
            #[cfg(target_os = "linux")]
            Backend::Epoll { .. } => "epoll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match &mut self.backend {
            Backend::Poll { fds, tokens } => {
                debug_assert!(!fds.iter().any(|p| p.fd == fd), "fd registered twice");
                fds.push(PollFd { fd, events: events_for(interest), revents: 0 });
                tokens.push(token);
                Ok(())
            }
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, registered, .. } => {
                let mut ev = EpollEvent { events: epoll_events_for(interest), data: token as u64 };
                let rc = unsafe { epoll_ctl(*epfd, EPOLL_CTL_ADD, fd, &mut ev) };
                if rc < 0 {
                    return Err(io::Error::last_os_error());
                }
                *registered += 1;
                Ok(())
            }
        }
    }

    /// Update the interest set (and token) of an already-registered fd.
    pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match &mut self.backend {
            Backend::Poll { fds, tokens } => {
                for (p, t) in fds.iter_mut().zip(tokens.iter_mut()) {
                    if p.fd == fd {
                        p.events = events_for(interest);
                        *t = token;
                        return Ok(());
                    }
                }
                Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
            }
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, .. } => {
                let mut ev = EpollEvent { events: epoll_events_for(interest), data: token as u64 };
                let rc = unsafe { epoll_ctl(*epfd, EPOLL_CTL_MOD, fd, &mut ev) };
                if rc < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(())
            }
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match &mut self.backend {
            Backend::Poll { fds, tokens } => {
                if let Some(i) = fds.iter().position(|p| p.fd == fd) {
                    fds.swap_remove(i);
                    tokens.swap_remove(i);
                    Ok(())
                } else {
                    Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
                }
            }
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, registered, .. } => {
                // pre-2.6.9 kernels demand a non-null event even for DEL
                let mut ev = EpollEvent { events: 0, data: 0 };
                let rc = unsafe { epoll_ctl(*epfd, EPOLL_CTL_DEL, fd, &mut ev) };
                if rc < 0 {
                    return Err(io::Error::last_os_error());
                }
                *registered = registered.saturating_sub(1);
                Ok(())
            }
        }
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// elapses, appending readiness to `out`. Returns the number of
    /// events delivered; 0 means timeout. EINTR is treated as timeout.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize> {
        out.clear();
        let ms = timeout_to_ms(timeout);
        match &mut self.backend {
            Backend::Poll { fds, tokens } => {
                if fds.is_empty() {
                    if ms > 0 {
                        std::thread::sleep(Duration::from_millis(ms as u64));
                    }
                    return Ok(0);
                }
                let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, ms) };
                if rc < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        return Ok(0);
                    }
                    return Err(err);
                }
                for (p, &t) in fds.iter().zip(tokens.iter()) {
                    if p.revents != 0 {
                        out.push(Event {
                            token: t,
                            readable: p.revents & (POLLIN | POLLHUP | POLLERR) != 0,
                            writable: p.revents & (POLLOUT | POLLERR) != 0,
                        });
                    }
                }
                Ok(out.len())
            }
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd, buf, registered } => {
                if *registered == 0 {
                    if ms > 0 {
                        std::thread::sleep(Duration::from_millis(ms as u64));
                    }
                    return Ok(0);
                }
                let rc =
                    unsafe { epoll_wait(*epfd, buf.as_mut_ptr(), buf.len() as i32, ms) };
                if rc < 0 {
                    let err = io::Error::last_os_error();
                    if err.kind() == io::ErrorKind::Interrupted {
                        return Ok(0);
                    }
                    return Err(err);
                }
                for ev in buf.iter().take(rc as usize) {
                    let events = ev.events;
                    let data = ev.data;
                    out.push(Event {
                        token: data as usize,
                        readable: events & (EPOLLIN | EPOLLHUP | EPOLLERR) != 0,
                        writable: events & (EPOLLOUT | EPOLLERR) != 0,
                    });
                }
                Ok(out.len())
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        if let Backend::Epoll { epfd, .. } = self.backend {
            unsafe {
                close(epfd);
            }
        }
        // the poll backend owns no fds; suppress unused warning elsewhere
        let _ = close as unsafe extern "C" fn(i32) -> i32;
    }
}

fn events_for(interest: Interest) -> i16 {
    let mut e = 0;
    if interest.readable {
        e |= POLLIN;
    }
    if interest.writable {
        e |= POLLOUT;
    }
    e
}

#[cfg(target_os = "linux")]
fn epoll_events_for(interest: Interest) -> u32 {
    let mut e = 0;
    if interest.readable {
        e |= EPOLLIN;
    }
    if interest.writable {
        e |= EPOLLOUT;
    }
    e
}

// ---------------------------------------------------------------------------
// waker: cross-thread wakeup for the event loop
// ---------------------------------------------------------------------------

/// Wakes a [`Poller`] from another thread. One end of a nonblocking
/// socketpair lives in the event loop (registered readable under a
/// well-known token); worker threads hold the clonable [`WakeHandle`]
/// and write a single byte to interrupt `wait`.
pub struct Waker {
    read_half: UnixStream,
    write_half: UnixStream,
}

/// Cheap clonable handle for worker threads; see [`Waker`].
#[derive(Clone)]
pub struct WakeHandle {
    write_half: std::sync::Arc<UnixStream>,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let (read_half, write_half) = UnixStream::pair()?;
        read_half.set_nonblocking(true)?;
        write_half.set_nonblocking(true)?;
        Ok(Waker { read_half, write_half })
    }

    pub fn fd(&self) -> RawFd {
        self.read_half.as_raw_fd()
    }

    pub fn handle(&self) -> WakeHandle {
        WakeHandle {
            write_half: std::sync::Arc::new(
                self.write_half.try_clone().expect("clone waker socket"),
            ),
        }
    }

    /// Drain every pending wake byte; call once per loop iteration when
    /// the waker token fires. Never blocks (the fd is nonblocking).
    pub fn drain(&self) {
        use std::io::Read;
        let mut buf = [0u8; 64];
        loop {
            match (&self.read_half).read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock: fully drained
            }
        }
    }
}

impl WakeHandle {
    /// Signal the event loop. A full pipe means a wake is already
    /// pending, which is just as good — the error is ignored on purpose.
    pub fn wake(&self) {
        use std::io::Write;
        let _ = (&*self.write_half).write(&[1u8]);
    }
}

// ---------------------------------------------------------------------------
// fd-limit helper for the connection-scale bench
// ---------------------------------------------------------------------------

/// Best-effort raise of `RLIMIT_NOFILE` to at least `want` fds, returning
/// the resulting soft limit. The 10k-connection bench cell needs ~2 fds
/// per loopback connection plus slack; default soft limits (often 1024)
/// would otherwise silently cap the sweep — callers record the returned
/// value so a clamped run is visible in `BENCH_serve_scale.json`.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut lim = RLimit { cur: 0, max: 0 };
    let rc = unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) };
    if rc != 0 {
        return 0;
    }
    if lim.cur >= want {
        return lim.cur;
    }
    let target = want.min(lim.max);
    let new = RLimit { cur: target, max: lim.max };
    let rc = unsafe { setrlimit(RLIMIT_NOFILE, &new) };
    if rc != 0 {
        return lim.cur;
    }
    target
}

/// Put a `TcpStream` into nonblocking mode, mapping the error into the
/// reactor's io::Result vocabulary. Small helper shared by listener
/// accept paths and the bench load generator.
pub fn set_nonblocking(stream: &TcpStream) -> io::Result<()> {
    stream.set_nonblocking(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::time::Instant;

    fn kinds() -> Vec<PollerKind> {
        let mut v = vec![PollerKind::Poll];
        if cfg!(target_os = "linux") {
            v.push(PollerKind::Epoll);
        }
        v
    }

    #[test]
    fn wait_times_out_with_no_ready_fds() {
        for kind in kinds() {
            let mut p = Poller::new(kind).unwrap();
            // register a quiescent socket so epoll has something to watch
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            a.set_nonblocking(true).unwrap();
            let (_srv, _) = listener.accept().unwrap();
            p.register(a.as_raw_fd(), 7, Interest::READ).unwrap();
            let mut events = Vec::new();
            let t0 = Instant::now();
            let n = p.wait(&mut events, Some(Duration::from_millis(30))).unwrap();
            assert_eq!(n, 0, "{:?}: no data should mean timeout", kind);
            assert!(t0.elapsed() >= Duration::from_millis(25), "{:?} returned early", kind);
        }
    }

    #[test]
    fn readable_event_carries_token() {
        for kind in kinds() {
            let mut p = Poller::new(kind).unwrap();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (mut srv, _) = listener.accept().unwrap();
            a.set_nonblocking(true).unwrap();
            p.register(a.as_raw_fd(), 42, Interest::READ).unwrap();
            srv.write_all(b"x").unwrap();
            let mut events = Vec::new();
            let n = p.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
            assert_eq!(n, 1, "{:?}", kind);
            assert_eq!(events[0].token, 42);
            assert!(events[0].readable);
            let mut buf = [0u8; 8];
            assert_eq!((&a).read(&mut buf).unwrap(), 1);
        }
    }

    #[test]
    fn modify_switches_interest_and_token() {
        for kind in kinds() {
            let mut p = Poller::new(kind).unwrap();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (_srv, _) = listener.accept().unwrap();
            a.set_nonblocking(true).unwrap();
            // a fresh socket with empty send buffer is immediately writable
            p.register(a.as_raw_fd(), 1, Interest::READ).unwrap();
            let mut events = Vec::new();
            let n = p.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            assert_eq!(n, 0, "{:?}: read interest only, nothing to read", kind);
            p.modify(a.as_raw_fd(), 9, Interest::WRITE).unwrap();
            let n = p.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
            assert_eq!(n, 1, "{:?}", kind);
            assert_eq!(events[0].token, 9);
            assert!(events[0].writable);
            p.deregister(a.as_raw_fd()).unwrap();
            let n = p.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            assert_eq!(n, 0, "{:?}: deregistered fd must not fire", kind);
        }
    }

    #[test]
    fn waker_interrupts_wait_and_drains() {
        for kind in kinds() {
            let mut p = Poller::new(kind).unwrap();
            let waker = Waker::new().unwrap();
            p.register(waker.fd(), usize::MAX, Interest::READ).unwrap();
            let handle = waker.handle();
            let t = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                handle.wake();
                handle.wake(); // coalesced wakes are fine
            });
            let mut events = Vec::new();
            let n = p.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(n, 1, "{:?}", kind);
            assert_eq!(events[0].token, usize::MAX);
            waker.drain();
            t.join().unwrap();
            // drained: next wait times out instead of spinning on the stale byte
            let n = p.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
            assert_eq!(n, 0, "{:?}: waker byte not drained", kind);
        }
    }

    #[test]
    fn raise_nofile_reports_a_usable_limit() {
        let got = raise_nofile_limit(256);
        assert!(got >= 256 || got > 0, "could not query RLIMIT_NOFILE");
    }
}
