//! Dynamic batcher: groups concurrent inference requests into one
//! fixed-shape artifact call.
//!
//! The queue is a `Mutex<Vec<…>>` paired with a `Condvar` signaled by
//! [`BatcherHandle::submit`]: the batch-forming thread sleeps until a
//! request arrives (or a flush deadline passes) instead of the old
//! 200 µs sleep-poll loop, so an idle server burns no CPU and a new
//! request is picked up immediately.

use crate::tensor::Matrix;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request: input row + reply channel.
pub struct Request {
    pub pixels: Vec<f32>,
    pub reply: mpsc::Sender<Response>,
}

/// Classification reply.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub probs: Vec<f32>,
    /// Time spent queued + in the model, microseconds.
    pub latency_us: u64,
}

/// Counters exposed by the batcher.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub requests: u64,
    pub batches: u64,
    pub batch_fill_sum: u64,
}

impl BatchStats {
    pub fn mean_fill(&self, batch: usize) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_fill_sum as f64 / (self.batches as f64 * batch as f64)
        }
    }
}

/// Shared queue state: pending requests + arrival notification.
struct BatchQueue {
    queue: Mutex<Vec<(Request, Instant)>>,
    arrived: Condvar,
}

/// Collects requests and forms padded batches.
///
/// The executor closure runs the model on a `(batch × n_in)` matrix and
/// returns `(batch × n_out)` logits; the batcher owns queuing, padding,
/// softmax and scatter.
pub struct DynamicBatcher {
    shared: Arc<BatchQueue>,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub stats: BatchStats,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> DynamicBatcher {
        DynamicBatcher {
            shared: Arc::new(BatchQueue {
                queue: Mutex::new(Vec::new()),
                arrived: Condvar::new(),
            }),
            max_batch,
            max_wait,
            stats: BatchStats::default(),
        }
    }

    /// Handle used by producer threads to enqueue requests.
    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle { shared: self.shared.clone() }
    }

    /// Form the next batch: returns when `max_batch` requests are
    /// waiting or `max_wait` passed since the oldest arrival (None
    /// after `idle_poll` with no batch formed). Blocks on the condvar
    /// between arrivals — no busy-waiting.
    pub fn next_batch(&mut self, idle_poll: Duration) -> Option<Vec<(Request, Instant)>> {
        let deadline = Instant::now() + idle_poll;
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            let oldest = q.first().map(|(_, t)| *t);
            let flush = oldest
                .map(|t| now.duration_since(t) >= self.max_wait)
                .unwrap_or(false);
            if q.len() >= self.max_batch || flush {
                let take = q.len().min(self.max_batch);
                let batch: Vec<_> = q.drain(..take).collect();
                self.stats.requests += batch.len() as u64;
                self.stats.batches += 1;
                self.stats.batch_fill_sum += batch.len() as u64;
                return Some(batch);
            }
            if now >= deadline {
                return None;
            }
            // Sleep until whichever comes first: the oldest request's
            // flush deadline or the idle deadline; submit() wakes us
            // early when a request lands.
            let wake_at = match oldest {
                Some(t) => (t + self.max_wait).min(deadline),
                None => deadline,
            };
            let wait = wake_at.saturating_duration_since(now);
            let (guard, _res) = self.shared.arrived.wait_timeout(q, wait).unwrap();
            q = guard;
        }
    }

    /// Run one batch through `exec` and scatter responses.
    pub fn dispatch<F>(&mut self, batch: Vec<(Request, Instant)>, n_in: usize, exec: F)
    where
        F: FnOnce(&Matrix) -> anyhow::Result<Matrix>,
    {
        let n = batch.len();
        let model_batch = self.max_batch;
        let mut x = Matrix::zeros(model_batch, n_in);
        for (b, (req, _)) in batch.iter().enumerate() {
            let len = req.pixels.len().min(n_in);
            x.row_mut(b)[..len].copy_from_slice(&req.pixels[..len]);
        }
        match exec(&x) {
            Ok(logits) => {
                let probs = logits.softmax_rows();
                let classes = logits.argmax_rows();
                for (b, (req, t_in)) in batch.into_iter().enumerate() {
                    let _ = req.reply.send(Response {
                        class: classes[b],
                        probs: probs.row(b).to_vec(),
                        latency_us: t_in.elapsed().as_micros() as u64,
                    });
                }
            }
            Err(e) => {
                eprintln!("batch of {n} failed: {e:#}");
                // drop reply senders -> receivers observe disconnect
            }
        }
    }
}

/// Cloneable enqueue handle.
#[derive(Clone)]
pub struct BatcherHandle {
    shared: Arc<BatchQueue>,
}

impl BatcherHandle {
    /// Enqueue a request and wake the batch former; returns the
    /// receiver for the reply.
    pub fn submit(&self, pixels: Vec<f32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push((Request { pixels, reply: tx }, Instant::now()));
        }
        self.shared.arrived.notify_one();
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_exec(x: &Matrix) -> anyhow::Result<Matrix> {
        // "logits" = first 3 pixels
        Ok(Matrix::from_fn(x.rows, 3, |i, j| x.at(i, j)))
    }

    #[test]
    fn batches_fill_up_to_max() {
        let mut b = DynamicBatcher::new(4, Duration::from_millis(50));
        let h = b.handle();
        let rxs: Vec<_> = (0..6).map(|i| h.submit(vec![i as f32, 0.0, 0.0])).collect();
        let batch = b.next_batch(Duration::from_millis(100)).expect("batch");
        assert_eq!(batch.len(), 4);
        b.dispatch(batch, 3, echo_exec);
        let batch2 = b.next_batch(Duration::from_millis(100)).expect("batch2");
        assert_eq!(batch2.len(), 2); // flushed by max_wait
        b.dispatch(batch2, 3, echo_exec);
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            // pixels were [i, 0, 0] -> argmax is col 0 (ties prefer first)
            assert_eq!(r.class, 0, "req {i}");
            // condvar wakeups can round to 0 µs, so only an upper bound
            // is meaningful here
            assert!(r.latency_us < 1_000_000, "absurd latency {}", r.latency_us);
        }
        assert_eq!(b.stats.requests, 6);
        assert_eq!(b.stats.batches, 2);
    }

    #[test]
    fn waits_then_flushes_partial_batch() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        let h = b.handle();
        let rx = h.submit(vec![9.0, 1.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("flush");
        assert_eq!(batch.len(), 1);
        b.dispatch(batch, 3, echo_exec);
        let r = rx.recv().unwrap();
        assert_eq!(r.class, 0);
        assert_eq!(r.probs.len(), 3);
    }

    #[test]
    fn idle_poll_returns_none() {
        let mut b = DynamicBatcher::new(4, Duration::from_millis(1));
        assert!(b.next_batch(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn submit_wakes_blocked_next_batch() {
        // a blocked next_batch must be woken by submit(), not by a poll
        // tick: with max_batch=1 the batch forms as soon as the request
        // lands, far before the 2 s idle deadline.
        let mut b = DynamicBatcher::new(1, Duration::from_millis(500));
        let h = b.handle();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            h.submit(vec![1.0, 0.0, 0.0])
        });
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(2)).expect("woken by submit");
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(1500),
            "next_batch was not woken promptly: {:?}",
            t0.elapsed()
        );
        b.dispatch(batch, 3, echo_exec);
        let rx = producer.join().unwrap();
        assert_eq!(rx.recv().unwrap().class, 0);
    }

    #[test]
    fn mean_fill_math() {
        let stats = BatchStats { requests: 6, batches: 2, batch_fill_sum: 6 };
        assert!((stats.mean_fill(4) - 0.75).abs() < 1e-9);
    }
}
