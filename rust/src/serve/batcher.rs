//! Dynamic batcher: groups concurrent inference requests into one
//! batched engine call — and enforces the serving resilience contract.
//!
//! The queue is a `Mutex<Vec<…>>` paired with a `Condvar` signaled by
//! [`BatcherHandle::submit`]: a batch-forming thread sleeps until a
//! request arrives (or a flush deadline passes) instead of a sleep-poll
//! loop, so an idle server burns no CPU and a new request is picked up
//! immediately.
//!
//! All methods take `&self` and counters are atomic, so one batcher can
//! be drained by **several worker threads at once** (the native engine
//! path runs N workers × one shared model): the queue mutex serializes
//! batch formation, and each worker runs its batch independently.
//!
//! ## The explicit-reply invariant
//!
//! Every request that enters [`BatcherHandle::submit`] receives
//! **exactly one** explicit [`Response`], whatever happens to it:
//!
//! * **admission control** — the queue is bounded (`max_pending`); a
//!   submit against a full queue is rejected in O(1) with
//!   [`ServeError::Overloaded`] (carrying a `retry_after_ms` hint)
//!   instead of queueing to infinity;
//! * **deadlines** — each [`Request`] carries an absolute deadline;
//!   [`DynamicBatcher::next_batch`] and [`DynamicBatcher::dispatch`]
//!   expire dead requests with [`ServeError::DeadlineExceeded`] before
//!   the model runs, so a client that already gave up never burns an
//!   inference slot;
//! * **fault containment** — [`DynamicBatcher::dispatch`] runs the
//!   executor under `catch_unwind`: a panicking engine fails its batch
//!   with an explicit [`ServeError::Engine`] reply and the calling
//!   worker thread survives;
//! * **close-out** — a closed queue ([`DynamicBatcher::close`], the
//!   unload/shutdown path) rejects later submits immediately, and
//!   [`DynamicBatcher::fail_pending`] answers whatever was queued.

use crate::tensor::Matrix;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Queue bound applied by [`DynamicBatcher::new`]; use
/// [`DynamicBatcher::bounded`] to pick one explicitly.
pub const DEFAULT_MAX_PENDING: usize = 1024;

/// What a request carries: the system's two first-class data shapes.
///
/// * `Dense` — one `n_in`-wide f32 row (the classify path).
/// * `Sparse` — a CSR bag request (`indices` + `offsets`, the
///   `EmbeddingBag` convention) for hashed embedding models. Batching
///   cost is the *index* count, not the request count, so
///   [`DynamicBatcher::next_batch`] charges sparse requests against the
///   total-indices budget ([`DynamicBatcher::with_index_budget`]).
pub enum Payload {
    Dense(Vec<f32>),
    Sparse { indices: Vec<u32>, offsets: Vec<u32> },
}

impl Payload {
    /// What this request costs against the batch's index budget. A
    /// dense row costs 1 (budgeting degenerates to row count); a bag
    /// request costs its index count (min 1 so empty-bag requests
    /// still occupy a slot).
    fn index_cost(&self) -> usize {
        match self {
            Payload::Dense(_) => 1,
            Payload::Sparse { indices, .. } => indices.len().max(1),
        }
    }
}

/// One inference request: input payload + reply sink + the absolute
/// point in time after which the client stops waiting.
pub struct Request {
    pub payload: Payload,
    pub reply: ReplySender,
    /// Requests whose deadline has passed are expired with an explicit
    /// [`ServeError::DeadlineExceeded`] at batch-formation/dispatch
    /// time instead of running the model.
    pub deadline: Instant,
}

/// Where a [`Response`] goes. Blocking callers (tests, benches, the
/// thread-per-request paths) receive on an mpsc channel; the event-loop
/// front end registers a completion hook that enqueues the reply on the
/// reactor's completion queue and wakes it. Either way the explicit-
/// reply invariant is the same: `send` consumes the sender, so each
/// request gets exactly one reply.
pub enum ReplySender {
    Channel(mpsc::Sender<Response>),
    /// Invoked exactly once — possibly inline on the submitting thread
    /// when admission control rejects the request, so hooks must be
    /// cheap and non-blocking.
    Hook(Box<dyn FnOnce(Response) + Send>),
}

impl ReplySender {
    /// Wrap a completion hook (see [`ReplySender::Hook`]).
    pub fn hook(f: impl FnOnce(Response) + Send + 'static) -> ReplySender {
        ReplySender::Hook(Box::new(f))
    }

    /// Deliver the reply. Returns the response back when the channel's
    /// receiver is gone (the client stopped waiting) — callers uniformly
    /// ignore that, matching mpsc semantics.
    pub fn send(self, resp: Response) -> Result<(), Response> {
        match self {
            ReplySender::Channel(tx) => tx.send(resp).map_err(|e| e.0),
            ReplySender::Hook(f) => {
                f(resp);
                Ok(())
            }
        }
    }
}

/// Why a request could not be served. Each variant maps to a stable
/// wire `code` (see [`ServeError::code`]) so clients can tell an
/// overloaded server (retry with backoff) from a dead model (don't).
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Admission control: the pending queue is full. Retry after the
    /// hinted delay.
    Overloaded { retry_after_ms: u64 },
    /// The request's deadline passed before the model ran.
    DeadlineExceeded,
    /// The model (or the whole server) is gone; the message says which.
    Unloaded(String),
    /// The executor failed or panicked; the message carries the cause.
    Engine(String),
    /// The input did not match the model (wrong pixel count).
    BadInput(String),
    /// The server-side wait for a reply expired (backstop distinct
    /// from `Overloaded`/`DeadlineExceeded`; produced by the server's
    /// receive path, never by the batcher itself).
    Timeout,
}

impl ServeError {
    /// Stable machine-readable discriminant, reported as `"code"` in
    /// error replies on the wire.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::DeadlineExceeded => "deadline",
            ServeError::Unloaded(_) => "unloaded",
            ServeError::Engine(_) => "engine",
            ServeError::BadInput(_) => "bad_input",
            ServeError::Timeout => "timeout",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded: queue full, retry in {retry_after_ms} ms")
            }
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before inference ran"),
            ServeError::Unloaded(msg) | ServeError::Engine(msg) | ServeError::BadInput(msg) => {
                write!(f, "{msg}")
            }
            ServeError::Timeout => write!(f, "timeout: no reply within the request deadline"),
        }
    }
}

/// Classification reply. `error` is set (and the other fields are
/// meaningless) when the request could not be served — see
/// [`ServeError`] for the failure taxonomy — so clients fail fast with
/// a typed cause instead of waiting out a receive timeout on a dropped
/// sender.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub probs: Vec<f32>,
    /// Time spent queued + in the model, microseconds.
    pub latency_us: u64,
    pub error: Option<ServeError>,
}

impl Response {
    fn failed(error: ServeError, latency_us: u64) -> Response {
        Response { class: 0, probs: Vec::new(), latency_us, error: Some(error) }
    }
}

/// Snapshot of the batcher's counters.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub requests: u64,
    pub batches: u64,
    pub batch_fill_sum: u64,
    /// Submits rejected by admission control (queue full).
    pub rejected: u64,
    /// Requests expired past their deadline before the model ran.
    pub expired: u64,
    /// Engine panics contained by [`DynamicBatcher::dispatch`].
    pub panics: u64,
}

impl BatchStats {
    pub fn mean_fill(&self, batch: usize) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_fill_sum as f64 / (self.batches as f64 * batch as f64)
        }
    }
}

/// Shared queue state: pending requests + arrival notification +
/// atomic counters (shared by all worker threads).
struct BatchQueue {
    queue: Mutex<Vec<(Request, Instant)>>,
    arrived: Condvar,
    /// Admission bound: `submit` rejects (O(1), explicit reply) once
    /// this many requests are pending.
    max_pending: usize,
    /// Backoff hint attached to `Overloaded` rejections — how long a
    /// full queue takes to turn over at least once, estimated from the
    /// batch geometry at construction time.
    retry_after_ms: u64,
    requests: AtomicU64,
    batches: AtomicU64,
    batch_fill_sum: AtomicU64,
    rejected: AtomicU64,
    expired: AtomicU64,
    panics: AtomicU64,
    /// Set by [`DynamicBatcher::close`] once no worker will drain this
    /// queue again; [`BatcherHandle::submit`] then fails fast instead
    /// of stranding the request until its receive timeout.
    closed: AtomicBool,
}

/// Collects requests and forms batches.
///
/// The executor closure runs the model on a `(rows × n_in)` matrix and
/// returns `(rows × n_out)` logits; the batcher owns queuing, padding,
/// softmax and scatter. Cloning is cheap (all state lives behind one
/// `Arc`), so worker threads hold their own clone.
#[derive(Clone)]
pub struct DynamicBatcher {
    shared: Arc<BatchQueue>,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// When true, [`DynamicBatcher::dispatch`] zero-pads the input to
    /// exactly `max_batch` rows — required by fixed-shape executors
    /// (the PJRT artifacts). The native engine takes any row count, so
    /// it skips the padding and the wasted rows.
    pad_batches: bool,
    /// Total-indices budget per batch for sparse payloads (dense rows
    /// cost 1 each, so dense batching is unchanged). A batch closes
    /// when the *next* request would push the summed
    /// [`Payload::index_cost`] past this — but always admits at least
    /// one request, so an oversized bag still runs alone.
    max_indices: usize,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> DynamicBatcher {
        DynamicBatcher::bounded(max_batch, max_wait, DEFAULT_MAX_PENDING)
    }

    /// A batcher with an explicit admission bound: at most
    /// `max_pending` requests queue; further submits are rejected
    /// immediately with [`ServeError::Overloaded`].
    pub fn bounded(max_batch: usize, max_wait: Duration, max_pending: usize) -> DynamicBatcher {
        let max_pending = max_pending.max(1);
        // How long a full queue needs to drain one turn: one flush
        // window per batch it holds. A hint, not a promise — clamped
        // so clients never back off absurdly long.
        let turns = (max_pending / max_batch.max(1)) as u64 + 1;
        let retry_after_ms = (turns * (max_wait.as_millis() as u64).max(1)).clamp(1, 1000);
        DynamicBatcher {
            shared: Arc::new(BatchQueue {
                queue: Mutex::new(Vec::new()),
                arrived: Condvar::new(),
                max_pending,
                retry_after_ms,
                requests: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                batch_fill_sum: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                expired: AtomicU64::new(0),
                panics: AtomicU64::new(0),
                closed: AtomicBool::new(false),
            }),
            max_batch,
            max_wait,
            pad_batches: false,
            max_indices: usize::MAX,
        }
    }

    /// Switch on fixed-shape padding (see `pad_batches`).
    pub fn padded(mut self) -> DynamicBatcher {
        self.pad_batches = true;
        self
    }

    /// Cap the summed [`Payload::index_cost`] per batch — sparse
    /// batching by total index count rather than request count.
    pub fn with_index_budget(mut self, max_indices: usize) -> DynamicBatcher {
        self.max_indices = max_indices.max(1);
        self
    }

    /// Handle used by producer threads to enqueue requests.
    pub fn handle(&self) -> BatcherHandle {
        BatcherHandle { shared: self.shared.clone() }
    }

    /// Counter snapshot (consistent enough for reporting).
    pub fn stats(&self) -> BatchStats {
        BatchStats {
            requests: self.shared.requests.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            batch_fill_sum: self.shared.batch_fill_sum.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            expired: self.shared.expired.load(Ordering::Relaxed),
            panics: self.shared.panics.load(Ordering::Relaxed),
        }
    }

    /// Current queue depth (for health reporting).
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// The admission bound this batcher enforces.
    pub fn max_pending(&self) -> usize {
        self.shared.max_pending
    }

    /// Answer expired requests under the queue lock and drop them from
    /// the queue. Runs at batch-formation time so a dead request never
    /// reaches the engine.
    fn expire_dead(&self, q: &mut Vec<(Request, Instant)>, now: Instant) {
        if !q.iter().any(|(r, _)| r.deadline <= now) {
            return;
        }
        let (dead, live): (Vec<_>, Vec<_>) = q.drain(..).partition(|(r, _)| r.deadline <= now);
        *q = live;
        self.shared.expired.fetch_add(dead.len() as u64, Ordering::Relaxed);
        for (req, t_in) in dead {
            let _ = req.reply.send(Response::failed(
                ServeError::DeadlineExceeded,
                t_in.elapsed().as_micros() as u64,
            ));
        }
    }

    /// Form the next batch: returns when `max_batch` requests are
    /// waiting or `max_wait` passed since the oldest arrival (None
    /// after `idle_poll` with no batch formed). Blocks on the condvar
    /// between arrivals — no busy-waiting. Safe to call from several
    /// worker threads; each pending request lands in exactly one batch.
    /// Requests past their deadline are expired (explicit
    /// [`ServeError::DeadlineExceeded`] reply) instead of batched.
    pub fn next_batch(&self, idle_poll: Duration) -> Option<Vec<(Request, Instant)>> {
        let deadline = Instant::now() + idle_poll;
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            self.expire_dead(&mut q, now);
            let oldest = q.first().map(|(_, t)| *t);
            let flush = oldest
                .map(|t| now.duration_since(t) >= self.max_wait)
                .unwrap_or(false);
            // How many leading requests fit this batch: bounded by
            // max_batch and by the total-indices budget (dense rows
            // cost 1, so the dense path reduces to `min(max_batch)`).
            let mut take = 0usize;
            let mut cost = 0usize;
            for (r, _) in q.iter() {
                if take >= self.max_batch {
                    break;
                }
                let c = r.payload.index_cost();
                if take > 0 && cost + c > self.max_indices {
                    break;
                }
                cost += c;
                take += 1;
            }
            // form a batch as soon as one is *full* (either bound hit
            // while more requests wait) or the oldest request's flush
            // deadline passed
            if (take > 0 && (take < q.len() || take == self.max_batch)) || (flush && take > 0) {
                let batch: Vec<_> = q.drain(..take).collect();
                self.shared.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
                self.shared.batches.fetch_add(1, Ordering::Relaxed);
                self.shared.batch_fill_sum.fetch_add(batch.len() as u64, Ordering::Relaxed);
                return Some(batch);
            }
            if now >= deadline {
                return None;
            }
            // Sleep until whichever comes first: the oldest request's
            // flush deadline or the idle deadline; submit() wakes us
            // early when a request lands.
            let wake_at = match oldest {
                Some(t) => (t + self.max_wait).min(deadline),
                None => deadline,
            };
            let wait = wake_at.saturating_duration_since(now);
            let (guard, _res) = self.shared.arrived.wait_timeout(q, wait).unwrap();
            q = guard;
        }
    }

    /// Take every pending request regardless of batch/flush rules —
    /// the server's shutdown path, so queued clients can be failed
    /// fast instead of waiting out their receive timeout.
    pub fn drain_pending(&self) -> Vec<(Request, Instant)> {
        let mut q = self.shared.queue.lock().unwrap();
        q.drain(..).collect()
    }

    /// Fail every pending request with `err` — the unload/shutdown
    /// tail: queued clients get the typed cause (e.g.
    /// [`ServeError::Unloaded`]) immediately. Returns how many were
    /// answered.
    pub fn fail_pending(&self, err: ServeError) -> usize {
        let pending = self.drain_pending();
        let n = pending.len();
        for (req, t_in) in pending {
            let _ = req
                .reply
                .send(Response::failed(err.clone(), t_in.elapsed().as_micros() as u64));
        }
        n
    }

    /// Mark the queue closed: no worker will drain it again. Every
    /// later [`BatcherHandle::submit`] fails fast with an explicit
    /// error reply. Call after stopping the workers and before the
    /// final [`DynamicBatcher::fail_pending`] pass — a submit that
    /// races the close lands in the queue *before* that drain (both
    /// sides serialize on the queue mutex), so no request is stranded.
    pub fn close(&self) {
        self.shared.closed.store(true, Ordering::Relaxed);
        // touch the mutex so the store is ordered before any drain the
        // caller performs next, even against a submit mid-flight
        drop(self.shared.queue.lock().unwrap());
    }

    /// Run one batch through `exec` and scatter responses. Every
    /// request receives a reply: a classification, or an explicit
    /// error `Response` when its deadline passed, its row length is
    /// wrong, or the executor fails *or panics* — reply senders are
    /// never silently dropped, and a panicking engine is contained
    /// here (the calling worker thread survives).
    pub fn dispatch<F>(&self, batch: Vec<(Request, Instant)>, n_in: usize, exec: F)
    where
        F: FnOnce(&Matrix) -> anyhow::Result<Matrix>,
    {
        // A deadline can pass between batch formation and dispatch
        // (e.g. the worker sat in a long engine call); drop those rows
        // now rather than compute logits nobody is waiting for.
        let now = Instant::now();
        let (batch, dead): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|(r, _)| r.deadline > now);
        if !dead.is_empty() {
            self.shared.expired.fetch_add(dead.len() as u64, Ordering::Relaxed);
            for (req, t_in) in dead {
                let _ = req.reply.send(Response::failed(
                    ServeError::DeadlineExceeded,
                    t_in.elapsed().as_micros() as u64,
                ));
            }
        }
        if batch.is_empty() {
            return;
        }
        let rows = if self.pad_batches { self.max_batch } else { batch.len() };
        let mut x = Matrix::zeros(rows, n_in);
        for (b, (req, _)) in batch.iter().enumerate() {
            // wrong-length or wrong-shape rows stay zero and get an
            // error reply after exec — never a silently zero-padded
            // classification
            if let Payload::Dense(pixels) = &req.payload {
                if pixels.len() == n_in {
                    x.row_mut(b).copy_from_slice(pixels);
                }
            }
        }
        // Fault containment: an engine panic must fail this batch, not
        // kill the worker thread that happened to run it.
        let result = catch_unwind(AssertUnwindSafe(|| exec(&x)));
        match result {
            Ok(Ok(logits)) => {
                let probs = logits.softmax_rows();
                let classes = logits.argmax_rows();
                for (b, (req, t_in)) in batch.into_iter().enumerate() {
                    let latency_us = t_in.elapsed().as_micros() as u64;
                    let resp = match &req.payload {
                        Payload::Sparse { .. } => Response::failed(
                            ServeError::BadInput(
                                "sparse request sent to a dense model".into(),
                            ),
                            latency_us,
                        ),
                        Payload::Dense(pixels) if pixels.len() != n_in => Response::failed(
                            ServeError::BadInput(format!(
                                "expected {n_in} pixels, got {}",
                                pixels.len()
                            )),
                            latency_us,
                        ),
                        Payload::Dense(_) => Response {
                            class: classes[b],
                            probs: probs.row(b).to_vec(),
                            latency_us,
                            error: None,
                        },
                    };
                    let _ = req.reply.send(resp);
                }
            }
            Ok(Err(e)) => {
                let err = ServeError::Engine(format!("inference failed: {e:#}"));
                for (req, t_in) in batch {
                    let _ = req
                        .reply
                        .send(Response::failed(err.clone(), t_in.elapsed().as_micros() as u64));
                }
            }
            Err(payload) => {
                self.shared.panics.fetch_add(1, Ordering::Relaxed);
                let err =
                    ServeError::Engine(format!("inference panicked: {}", panic_message(&payload)));
                for (req, t_in) in batch {
                    let _ = req
                        .reply
                        .send(Response::failed(err.clone(), t_in.elapsed().as_micros() as u64));
                }
            }
        }
    }

    /// Sparse twin of [`DynamicBatcher::dispatch`]: concatenate every
    /// request's bags into one CSR pair (each request's offsets shifted
    /// by the running index count), run `exec` once over the combined
    /// batch, and scatter each request its own rows back.
    ///
    /// The engine returns `(total_bags × dim)` values; request `i`'s
    /// reply carries its bag count as `class` and its bag vectors
    /// flattened row-major as `probs` (no softmax — embedding outputs
    /// are vectors, not logits). The explicit-reply and panic-
    /// containment contracts are identical to the dense path; a dense
    /// payload in a sparse batch gets a per-request
    /// [`ServeError::BadInput`] without poisoning its batchmates.
    pub fn dispatch_sparse<F>(&self, batch: Vec<(Request, Instant)>, exec: F)
    where
        F: FnOnce(&[u32], &[u32]) -> anyhow::Result<Matrix>,
    {
        let now = Instant::now();
        let (batch, dead): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|(r, _)| r.deadline > now);
        if !dead.is_empty() {
            self.shared.expired.fetch_add(dead.len() as u64, Ordering::Relaxed);
            for (req, t_in) in dead {
                let _ = req.reply.send(Response::failed(
                    ServeError::DeadlineExceeded,
                    t_in.elapsed().as_micros() as u64,
                ));
            }
        }
        if batch.is_empty() {
            return;
        }
        // Concatenate: per request either Some((first_bag, n_bags)) —
        // its row span in the combined output — or None (bad payload).
        let mut all_indices: Vec<u32> = Vec::new();
        let mut all_offsets: Vec<u32> = Vec::new();
        let mut spans: Vec<Option<(usize, usize)>> = Vec::with_capacity(batch.len());
        for (req, _) in &batch {
            match &req.payload {
                Payload::Sparse { indices, offsets } if !offsets.is_empty() => {
                    let base = all_indices.len() as u32;
                    spans.push(Some((all_offsets.len(), offsets.len())));
                    all_offsets.extend(offsets.iter().map(|&o| base + o));
                    all_indices.extend_from_slice(indices);
                }
                _ => spans.push(None),
            }
        }
        if all_offsets.is_empty() {
            // nothing valid to run: answer everyone without an engine call
            for (req, t_in) in batch {
                let _ = req.reply.send(Response::failed(
                    ServeError::BadInput("expected a sparse indices/offsets request".into()),
                    t_in.elapsed().as_micros() as u64,
                ));
            }
            return;
        }
        let result = catch_unwind(AssertUnwindSafe(|| exec(&all_indices, &all_offsets)));
        match result {
            Ok(Ok(values)) => {
                let dim = values.cols;
                for ((req, t_in), span) in batch.into_iter().zip(spans) {
                    let latency_us = t_in.elapsed().as_micros() as u64;
                    let resp = match span {
                        None => Response::failed(
                            ServeError::BadInput(
                                "expected a sparse indices/offsets request".into(),
                            ),
                            latency_us,
                        ),
                        Some((first, n_bags)) => {
                            let lo = first * dim;
                            let hi = lo + n_bags * dim;
                            Response {
                                class: n_bags,
                                probs: values.data[lo..hi].to_vec(),
                                latency_us,
                                error: None,
                            }
                        }
                    };
                    let _ = req.reply.send(resp);
                }
            }
            Ok(Err(e)) => {
                let err = ServeError::Engine(format!("inference failed: {e:#}"));
                for (req, t_in) in batch {
                    let _ = req
                        .reply
                        .send(Response::failed(err.clone(), t_in.elapsed().as_micros() as u64));
                }
            }
            Err(payload) => {
                self.shared.panics.fetch_add(1, Ordering::Relaxed);
                let err =
                    ServeError::Engine(format!("inference panicked: {}", panic_message(&payload)));
                for (req, t_in) in batch {
                    let _ = req
                        .reply
                        .send(Response::failed(err.clone(), t_in.elapsed().as_micros() as u64));
                }
            }
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Cloneable enqueue handle.
#[derive(Clone)]
pub struct BatcherHandle {
    shared: Arc<BatchQueue>,
}

impl BatcherHandle {
    /// [`BatcherHandle::submit_by`] with a one-minute deadline — for
    /// call sites (tests, benches) that don't propagate client
    /// deadlines.
    pub fn submit(&self, pixels: Vec<f32>) -> mpsc::Receiver<Response> {
        self.submit_by(pixels, Instant::now() + Duration::from_secs(60))
    }

    /// Enqueue a request and wake a batch former; returns the receiver
    /// for the reply. Admission is O(1) and never blocks the caller
    /// beyond the queue mutex:
    ///
    /// * closed queue (model unloaded) → immediate
    ///   [`ServeError::Unloaded`];
    /// * full queue (`max_pending` reached) → immediate
    ///   [`ServeError::Overloaded`] with a `retry_after_ms` hint;
    ///
    /// both checks happen under the queue mutex, so a request is
    /// either rejected here or visible to the closer's final drain,
    /// never stranded.
    pub fn submit_by(&self, pixels: Vec<f32>, deadline: Instant) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(pixels, deadline, ReplySender::Channel(tx));
        rx
    }

    /// [`BatcherHandle::submit_by`] with an explicit reply sink — the
    /// event-loop front end passes a [`ReplySender::Hook`] here so a
    /// worker's reply lands on the reactor's completion queue instead
    /// of an mpsc channel. Admission control is identical: a closed or
    /// full queue answers through `reply` immediately (inline, on the
    /// calling thread).
    pub fn submit_with(&self, pixels: Vec<f32>, deadline: Instant, reply: ReplySender) {
        self.submit_payload(Payload::Dense(pixels), deadline, reply);
    }

    /// Blocking sparse submit with a one-minute deadline (tests,
    /// benches, CLI eval).
    pub fn submit_sparse(&self, indices: Vec<u32>, offsets: Vec<u32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.submit_sparse_with(
            indices,
            offsets,
            Instant::now() + Duration::from_secs(60),
            ReplySender::Channel(tx),
        );
        rx
    }

    /// Sparse twin of [`BatcherHandle::submit_with`]: enqueue a CSR bag
    /// request. Admission control is shared with the dense path.
    pub fn submit_sparse_with(
        &self,
        indices: Vec<u32>,
        offsets: Vec<u32>,
        deadline: Instant,
        reply: ReplySender,
    ) {
        self.submit_payload(Payload::Sparse { indices, offsets }, deadline, reply);
    }

    fn submit_payload(&self, payload: Payload, deadline: Instant, reply: ReplySender) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.closed.load(Ordering::Relaxed) {
                drop(q);
                let _ = reply.send(Response::failed(
                    ServeError::Unloaded("model unloaded".into()),
                    0,
                ));
                return;
            }
            if q.len() >= self.shared.max_pending {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                drop(q);
                let _ = reply.send(Response::failed(
                    ServeError::Overloaded { retry_after_ms: self.shared.retry_after_ms },
                    0,
                ));
                return;
            }
            q.push((Request { payload, reply, deadline }, Instant::now()));
        }
        self.shared.arrived.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_exec(x: &Matrix) -> anyhow::Result<Matrix> {
        // "logits" = first 3 pixels
        Ok(Matrix::from_fn(x.rows, 3, |i, j| x.at(i, j)))
    }

    #[test]
    fn batches_fill_up_to_max() {
        let b = DynamicBatcher::new(4, Duration::from_millis(50));
        let h = b.handle();
        let rxs: Vec<_> = (0..6).map(|i| h.submit(vec![i as f32, 0.0, 0.0])).collect();
        let batch = b.next_batch(Duration::from_millis(100)).expect("batch");
        assert_eq!(batch.len(), 4);
        b.dispatch(batch, 3, echo_exec);
        let batch2 = b.next_batch(Duration::from_millis(100)).expect("batch2");
        assert_eq!(batch2.len(), 2); // flushed by max_wait
        b.dispatch(batch2, 3, echo_exec);
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "req {i}: {:?}", r.error);
            // pixels were [i, 0, 0] -> argmax is col 0 (ties prefer first)
            assert_eq!(r.class, 0, "req {i}");
            // condvar wakeups can round to 0 µs, so only an upper bound
            // is meaningful here
            assert!(r.latency_us < 1_000_000, "absurd latency {}", r.latency_us);
        }
        assert_eq!(b.stats().requests, 6);
        assert_eq!(b.stats().batches, 2);
    }

    #[test]
    fn waits_then_flushes_partial_batch() {
        let b = DynamicBatcher::new(8, Duration::from_millis(5));
        let h = b.handle();
        let rx = h.submit(vec![9.0, 1.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("flush");
        assert_eq!(batch.len(), 1);
        b.dispatch(batch, 3, echo_exec);
        let r = rx.recv().unwrap();
        assert_eq!(r.class, 0);
        assert_eq!(r.probs.len(), 3);
    }

    #[test]
    fn idle_poll_returns_none() {
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        assert!(b.next_batch(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn submit_wakes_blocked_next_batch() {
        // a blocked next_batch must be woken by submit(), not by a poll
        // tick: with max_batch=1 the batch forms as soon as the request
        // lands, far before the 2 s idle deadline.
        let b = DynamicBatcher::new(1, Duration::from_millis(500));
        let h = b.handle();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            h.submit(vec![1.0, 0.0, 0.0])
        });
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(2)).expect("woken by submit");
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(1500),
            "next_batch was not woken promptly: {:?}",
            t0.elapsed()
        );
        b.dispatch(batch, 3, echo_exec);
        let rx = producer.join().unwrap();
        assert_eq!(rx.recv().unwrap().class, 0);
    }

    #[test]
    fn executor_error_sends_explicit_error_response() {
        // a failing executor must fail the clients fast with the error
        // string, not drop the senders and leave them to recv_timeout
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        let rxs: Vec<_> = (0..2).map(|_| h.submit(vec![1.0, 2.0, 3.0])).collect();
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch(batch, 3, |_| Err(anyhow::anyhow!("backend exploded")));
        for rx in rxs {
            let r = rx.recv().expect("explicit error response, not a disconnect");
            let err = r.error.expect("error field set");
            assert_eq!(err.code(), "engine");
            assert!(err.to_string().contains("backend exploded"), "{err}");
        }
    }

    #[test]
    fn panicking_executor_fails_batch_explicitly_and_caller_survives() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        let rxs: Vec<_> = (0..2).map(|_| h.submit(vec![1.0, 2.0, 3.0])).collect();
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        // the panic is contained inside dispatch: this call returns
        b.dispatch(batch, 3, |_| -> anyhow::Result<Matrix> { panic!("engine blew up") });
        for rx in rxs {
            let r = rx.recv().expect("explicit error reply despite the panic");
            let err = r.error.expect("error field set");
            assert_eq!(err.code(), "engine");
            assert!(err.to_string().contains("engine blew up"), "{err}");
        }
        assert_eq!(b.stats().panics, 1);
        // the batcher is still fully usable after the contained panic
        let rx = h.submit(vec![0.0, 5.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch after panic");
        b.dispatch(batch, 3, echo_exec);
        assert_eq!(rx.recv().unwrap().class, 1);
    }

    #[test]
    fn wrong_length_row_gets_error_not_zero_padding() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        let rx_bad = h.submit(vec![7.0]); // too short for n_in = 3
        let rx_ok = h.submit(vec![0.0, 5.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch(batch, 3, echo_exec);
        let bad = rx_bad.recv().unwrap();
        let err = bad.error.expect("error field set");
        assert_eq!(err.code(), "bad_input");
        assert!(err.to_string().contains("expected 3 pixels"), "{err}");
        let ok = rx_ok.recv().unwrap();
        assert!(ok.error.is_none());
        assert_eq!(ok.class, 1); // argmax of [0, 5, 0]
    }

    #[test]
    fn full_queue_rejects_overloaded_in_o1() {
        // bound 2: the third submit must be rejected immediately with
        // an explicit overloaded reply + retry hint, no worker needed
        let b = DynamicBatcher::bounded(2, Duration::from_millis(5), 2);
        let h = b.handle();
        let _rx1 = h.submit(vec![1.0, 0.0, 0.0]);
        let _rx2 = h.submit(vec![2.0, 0.0, 0.0]);
        let t0 = Instant::now();
        let rx3 = h.submit(vec![3.0, 0.0, 0.0]);
        let r = rx3.recv().expect("immediate overloaded reply");
        assert!(t0.elapsed() < Duration::from_millis(100), "not O(1): {:?}", t0.elapsed());
        match r.error.expect("error field set") {
            ServeError::Overloaded { retry_after_ms } => assert!(retry_after_ms >= 1),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(b.stats().rejected, 1);
        assert_eq!(b.pending(), 2, "rejected submit must not enter the queue");
        // draining one batch frees capacity again
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch(batch, 3, echo_exec);
        let rx4 = h.submit(vec![4.0, 0.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch(batch, 3, echo_exec);
        assert!(rx4.recv().unwrap().error.is_none());
    }

    #[test]
    fn expired_request_fails_at_batch_formation_not_in_the_model() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        let rx = h.submit_by(vec![1.0, 0.0, 0.0], Instant::now() + Duration::from_millis(10));
        std::thread::sleep(Duration::from_millis(25));
        // the only queued request is dead: no batch forms, the client
        // gets an explicit deadline reply instead of an inference
        assert!(b.next_batch(Duration::from_millis(30)).is_none());
        let r = rx.recv().expect("explicit deadline reply");
        assert_eq!(r.error.expect("error field set"), ServeError::DeadlineExceeded);
        assert_eq!(b.stats().expired, 1);
        assert_eq!(b.stats().requests, 0, "expired requests never count as batched");
    }

    #[test]
    fn dispatch_skips_requests_that_died_after_batch_formation() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        let rx = h.submit_by(vec![1.0, 0.0, 0.0], Instant::now() + Duration::from_millis(20));
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        std::thread::sleep(Duration::from_millis(35));
        let ran = std::sync::atomic::AtomicBool::new(false);
        b.dispatch(batch, 3, |x| {
            ran.store(true, Ordering::Relaxed);
            echo_exec(x)
        });
        assert!(!ran.load(Ordering::Relaxed), "model must not run for a dead batch");
        assert_eq!(rx.recv().unwrap().error, Some(ServeError::DeadlineExceeded));
        assert_eq!(b.stats().expired, 1);
    }

    #[test]
    fn two_workers_drain_one_queue_without_losing_requests() {
        // N workers × one queue: every request gets exactly one reply
        let b = DynamicBatcher::new(2, Duration::from_millis(1));
        let h = b.handle();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let b = b.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        if let Some(batch) = b.next_batch(Duration::from_millis(5)) {
                            b.dispatch(batch, 3, echo_exec);
                        }
                    }
                })
            })
            .collect();
        let rxs: Vec<_> = (0..40).map(|i| h.submit(vec![i as f32, 0.0, 0.0])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
            assert!(r.error.is_none(), "req {i}");
        }
        stop.store(true, Ordering::Relaxed);
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(b.stats().requests, 40);
    }

    #[test]
    fn padded_mode_keeps_fixed_rows() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5)).padded();
        let h = b.handle();
        let rx = h.submit(vec![1.0, 2.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        assert_eq!(batch.len(), 1);
        b.dispatch(batch, 3, |x| {
            assert_eq!(x.rows, 4, "fixed-shape executor sees max_batch rows");
            echo_exec(x)
        });
        assert_eq!(rx.recv().unwrap().class, 1);
    }

    #[test]
    fn submit_after_close_fails_fast() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        // a request queued before the close is still drainable
        let rx_before = h.submit(vec![1.0, 0.0, 0.0]);
        b.close();
        let t0 = Instant::now();
        let rx_after = h.submit(vec![2.0, 0.0, 0.0]);
        let r = rx_after.recv().expect("immediate error reply");
        assert!(t0.elapsed() < Duration::from_millis(100), "not fast: {:?}", t0.elapsed());
        let err = r.error.expect("error field set");
        assert_eq!(err.code(), "unloaded");
        assert!(err.to_string().contains("unloaded"), "{err}");
        // the close-out path answers what was already queued, typed
        let n = b.fail_pending(ServeError::Unloaded("model 'x' unloaded".into()));
        assert_eq!(n, 1);
        let r = rx_before.recv().unwrap();
        assert_eq!(r.error.as_ref().map(ServeError::code), Some("unloaded"));
    }

    #[test]
    fn mean_fill_math() {
        let stats = BatchStats { requests: 6, batches: 2, batch_fill_sum: 6, ..Default::default() };
        assert!((stats.mean_fill(4) - 0.75).abs() < 1e-9);
    }

    /// Sparse echo: value of bag b, col c = sum of the bag's indices
    /// (so scatter correctness is visible per request).
    fn sparse_echo(indices: &[u32], offsets: &[u32]) -> anyhow::Result<Matrix> {
        let dim = 2usize;
        let mut m = Matrix::zeros(offsets.len(), dim);
        for b in 0..offsets.len() {
            let s = offsets[b] as usize;
            let e = offsets.get(b + 1).map(|&o| o as usize).unwrap_or(indices.len());
            let sum: u32 = indices[s..e].iter().sum();
            for c in 0..dim {
                m.row_mut(b)[c] = sum as f32 + c as f32;
            }
        }
        Ok(m)
    }

    #[test]
    fn sparse_dispatch_concatenates_and_scatters_per_request() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        // req A: 2 bags {1,2},{3}; req B: 1 bag {10,10}
        let rx_a = h.submit_sparse(vec![1, 2, 3], vec![0, 2]);
        let rx_b = h.submit_sparse(vec![10, 10], vec![0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        assert_eq!(batch.len(), 2);
        b.dispatch_sparse(batch, sparse_echo);
        let a = rx_a.recv().unwrap();
        assert!(a.error.is_none(), "{:?}", a.error);
        assert_eq!(a.class, 2); // bag count
        assert_eq!(a.probs, vec![3.0, 4.0, 3.0, 4.0]); // bags {1,2} and {3}
        let bb = rx_b.recv().unwrap();
        assert_eq!(bb.class, 1);
        assert_eq!(bb.probs, vec![20.0, 21.0]);
    }

    #[test]
    fn index_budget_closes_batches_by_total_indices() {
        // budget 5: req of 4 indices + req of 3 cannot share a batch
        let b = DynamicBatcher::new(16, Duration::from_millis(100)).with_index_budget(5);
        let h = b.handle();
        let _r1 = h.submit_sparse(vec![1, 2, 3, 4], vec![0]);
        let _r2 = h.submit_sparse(vec![5, 6, 7], vec![0]);
        // the budget overflow must close the first batch *immediately*,
        // well before the 100 ms flush window
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_millis(500)).expect("first batch");
        assert_eq!(batch.len(), 1, "budget must split the requests");
        assert!(t0.elapsed() < Duration::from_millis(90), "split batch must not wait for flush");
        b.dispatch_sparse(batch, sparse_echo);
        let batch2 = b.next_batch(Duration::from_millis(500)).expect("second batch");
        assert_eq!(batch2.len(), 1);
        b.dispatch_sparse(batch2, sparse_echo);
        // an oversized single request still runs alone
        let _r3 = h.submit_sparse((0..40).collect(), vec![0]);
        let batch3 = b.next_batch(Duration::from_millis(500)).expect("oversized");
        assert_eq!(batch3.len(), 1);
        b.dispatch_sparse(batch3, sparse_echo);
    }

    #[test]
    fn mixed_payload_kinds_fail_individually_not_batchwide() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        // dense request into a sparse dispatch: per-request bad_input,
        // the sparse batchmate still gets served
        let rx_dense = h.submit(vec![1.0, 2.0]);
        let rx_sparse = h.submit_sparse(vec![7], vec![0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch_sparse(batch, sparse_echo);
        let d = rx_dense.recv().unwrap();
        assert_eq!(d.error.as_ref().map(ServeError::code), Some("bad_input"));
        let s = rx_sparse.recv().unwrap();
        assert!(s.error.is_none());
        assert_eq!(s.probs, vec![7.0, 8.0]);
        // and the converse: sparse request into a dense dispatch
        let rx_sparse2 = h.submit_sparse(vec![1], vec![0]);
        let rx_dense2 = h.submit(vec![0.0, 5.0, 0.0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch(batch, 3, echo_exec);
        let s2 = rx_sparse2.recv().unwrap();
        assert_eq!(s2.error.as_ref().map(ServeError::code), Some("bad_input"));
        let d2 = rx_dense2.recv().unwrap();
        assert!(d2.error.is_none());
        assert_eq!(d2.class, 1);
    }

    #[test]
    fn sparse_empty_bags_round_trip() {
        // a request of all-empty bags costs 1 budget unit and yields
        // zero vectors (engine-dependent — sparse_echo sums to 0)
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        let h = b.handle();
        let rx = h.submit_sparse(vec![], vec![0, 0, 0]);
        let batch = b.next_batch(Duration::from_millis(200)).expect("batch");
        b.dispatch_sparse(batch, sparse_echo);
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert_eq!(r.class, 3);
        assert_eq!(r.probs, vec![0.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
    }
}
