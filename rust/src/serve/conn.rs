//! Event-loop connection layer: per-connection state machines driven by
//! a readiness reactor ([`super::poll`]), replacing the old
//! thread-per-connection front end.
//!
//! One thread owns every connection. The flow per request:
//!
//! 1. a readable socket is drained into the connection's input buffer;
//! 2. messages are parsed — the first byte picks the protocol
//!    ([`frame::MAGIC`] → binary frame, anything else → JSON line);
//! 3. classify requests are validated and submitted to the owning
//!    model's **existing** bounded [`DynamicBatcher`] admission path
//!    ([`BatcherHandle::submit_with`]) with a [`ReplySender::Hook`] that
//!    enqueues the worker's reply on the reactor's completion queue and
//!    wakes the loop — so admission control, deadlines and panic
//!    containment from the resilience layer carry over unchanged;
//! 4. completions are matched back to their connection by
//!    `(token, generation, sequence)` — a reply for a connection that
//!    died (or a slot that was reused) is dropped, never misdelivered;
//! 5. replies are serialized **in request order** per connection
//!    (pipelined clients see FIFO semantics, like the old sequential
//!    loop) and flushed with vectored writes ([`WriteQueue`]): a binary
//!    reply's header and payload are queued as separate buffers and
//!    leave in one `writev(2)` instead of being copied together first;
//!    a full socket registers write interest and resumes on
//!    writability.
//!
//! Both dense (`pixels`) and sparse (`indices`/`offsets` embedding-bag)
//! classify requests flow through the same pending/completion machinery;
//! the request shape is validated here against the model's kind before
//! admission, so a dense request to a sparse model (and vice versa)
//! fails as `bad_input` on either wire protocol.
//!
//! Cheap admin commands (`stats`/`health`/`models`/`shutdown`) run
//! inline on the loop; mutating ones (`load`/`unload`/`reload`) run on
//! a short-lived thread so engine builds and worker joins never stall
//! live traffic, completing through the same queue.
//!
//! A lost reply is bounded by a per-request backstop timer
//! (deadline + 250 ms grace, a timer heap instead of the old blocking
//! `recv_timeout`), answering with the same `"timeout"` code.
//!
//! [`DynamicBatcher`]: super::batcher::DynamicBatcher
//! [`BatcherHandle::submit_with`]: super::batcher::BatcherHandle::submit_with
//! [`ReplySender::Hook`]: super::batcher::ReplySender::Hook

use super::batcher::{ReplySender, Response, ServeError};
use super::frame;
use super::poll::{Event, Interest, Poller, PollerKind, WakeHandle, Waker};
use super::server::{
    cmd_load, cmd_reload, cmd_unload, error_reply, health_json, models_json, print_model_summary,
    retire, stats_json, ModelHandle, ServeCtx,
};
use crate::nn::embed::validate_bags;
use crate::util::json::{num, obj, Json};
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const TOKEN_LISTENER: usize = usize::MAX;
const TOKEN_WAKER: usize = usize::MAX - 1;
/// Idle poll tick: bounds how stale a stop-flag / `max_requests` check
/// can get (the old accept loop polled at a similar cadence).
const TICK: Duration = Duration::from_millis(250);
/// Grace past a request's deadline before the lost-reply backstop
/// fires — matches the old `recv_timeout(timeout + 250ms)`.
const REPLY_GRACE: Duration = Duration::from_millis(250);
/// Cap on bytes buffered while waiting for one message to complete.
const MAX_MSG: usize = 16 << 20;
/// Per-connection cap on in-flight requests; parsing (and, via
/// level-triggered readiness, reading) pauses until completions drain.
const MAX_INFLIGHT: usize = 1024;
/// Bound on the shutdown drain (completions normally arrive in
/// milliseconds once every model is retired).
const SHUTDOWN_DRAIN: Duration = Duration::from_secs(5);

/// Backstop timer entries: (due, token, generation, sequence).
type Timers = BinaryHeap<Reverse<(Instant, usize, u64, u64)>>;

/// Wire protocol of one pending request.
enum Proto {
    Json,
    Binary { req_id: u32 },
}

/// What a completed request serializes to.
enum Outcome {
    /// A classify result (success or typed [`ServeError`]).
    Resp(Response),
    /// A ready JSON object (admin results, parse errors).
    Reply(Json),
    /// Binary-protocol inline errors whose codes have no [`ServeError`]
    /// variant (`unknown_model`, `bad_frame`).
    BinErr { code: u8, message: String },
}

/// One in-flight request on a connection, in FIFO (request) order.
struct Pending {
    seq: u64,
    proto: Proto,
    /// Set for batcher-routed requests and counted inline errors; drives
    /// the per-model served/errors accounting at completion time.
    handle: Option<Arc<ModelHandle>>,
    model_name: String,
    /// Sparse embedding-bag request: a JSON success serializes as
    /// `"bags"`/`"values"` instead of `"class"`/`"probs"` (the binary
    /// reply frame is shared — `class` carries the bag count).
    sparse: bool,
    /// `None` until the batcher/admin completion (or backstop) lands.
    outcome: Option<Outcome>,
}

/// A completion crossing from a worker/admin thread to the reactor.
enum DonePayload {
    Resp(Response),
    Reply(Json),
}

struct Done {
    token: usize,
    gen: u64,
    seq: u64,
    payload: DonePayload,
}

/// Reactor-wide context threaded through the connection handlers.
struct Shared<'a> {
    ctx: &'a Arc<ServeCtx>,
    done_tx: &'a mpsc::Sender<Done>,
    wake: &'a WakeHandle,
    timers: &'a mut Timers,
}

/// Outgoing reply bytes as a queue of owned buffers flushed with
/// vectored writes — a reply's header and payload stay separate
/// (pushed back-to-back) and leave in one `writev(2)` syscall, instead
/// of being copied into a single flat buffer first. `pos` tracks the
/// partially-written prefix of the front buffer, so a short write
/// resumes exactly where the kernel stopped.
struct WriteQueue {
    bufs: VecDeque<Vec<u8>>,
    pos: usize,
}

impl WriteQueue {
    fn new() -> WriteQueue {
        WriteQueue { bufs: VecDeque::new(), pos: 0 }
    }

    fn push(&mut self, buf: Vec<u8>) {
        if !buf.is_empty() {
            self.bufs.push_back(buf);
        }
    }

    fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    fn clear(&mut self) {
        self.bufs.clear();
        self.pos = 0;
    }

    /// Consume `n` written bytes from the front of the queue.
    fn advance(&mut self, mut n: usize) {
        while n > 0 {
            let left = self.bufs[0].len() - self.pos;
            if n < left {
                self.pos += n;
                return;
            }
            n -= left;
            self.bufs.pop_front();
            self.pos = 0;
        }
    }

    /// One vectored write of everything queued; returns the byte count
    /// the sink accepted (0 only for a closed sink, per `Write`). I/O
    /// errors (including `WouldBlock`) pass through untouched.
    fn write_once(&mut self, sink: &mut impl Write) -> std::io::Result<usize> {
        let slices: Vec<IoSlice<'_>> = std::iter::once(IoSlice::new(&self.bufs[0][self.pos..]))
            .chain(self.bufs.iter().skip(1).map(|b| IoSlice::new(b)))
            .collect();
        let n = sink.write_vectored(&slices)?;
        self.advance(n);
        Ok(n)
    }
}

struct Conn {
    stream: TcpStream,
    token: usize,
    gen: u64,
    inbuf: Vec<u8>,
    outq: WriteQueue,
    pending: VecDeque<Pending>,
    next_seq: u64,
    /// Peer EOF, transport error, or an unrecoverable frame error: no
    /// more reads; queued replies still flush, then the slot is freed.
    closing: bool,
    registered_write: bool,
    /// Set once a closing connection is removed from the poller — a
    /// closed socket is permanently "readable" under level-triggered
    /// readiness, so leaving it registered would spin the loop while
    /// its in-flight requests drain.
    deregistered: bool,
}

impl Conn {
    fn new(stream: TcpStream, token: usize, gen: u64) -> Conn {
        Conn {
            stream,
            token,
            gen,
            inbuf: Vec::new(),
            outq: WriteQueue::new(),
            pending: VecDeque::new(),
            next_seq: 0,
            closing: false,
            registered_write: false,
            deregistered: false,
        }
    }

    fn alloc_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Queue an already-decided reply (inline validation errors, admin
    /// results computed on the loop), applying counter accounting.
    fn push_inline(
        &mut self,
        ctx: &ServeCtx,
        proto: Proto,
        handle: Option<Arc<ModelHandle>>,
        model_name: String,
        outcome: Outcome,
    ) {
        account(ctx, handle.as_deref(), &outcome);
        let seq = self.alloc_seq();
        self.pending.push_back(Pending {
            seq,
            proto,
            handle,
            model_name,
            sparse: false,
            outcome: Some(outcome),
        });
    }

    /// Drain the socket and parse/submit what arrived. Honors the
    /// in-flight cap: with `MAX_INFLIGHT` outstanding requests the read
    /// loop pauses, and level-triggered readiness resumes it once
    /// completions drain the queue.
    fn handle_readable(&mut self, sh: &mut Shared<'_>) {
        if self.closing {
            return;
        }
        let mut chunk = [0u8; 16384];
        loop {
            if self.pending.len() >= MAX_INFLIGHT || self.inbuf.len() > MAX_MSG {
                break;
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.closing = true;
                    break;
                }
                Ok(n) => {
                    self.inbuf.extend_from_slice(&chunk[..n]);
                    self.parse_available(sh);
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closing = true;
                    break;
                }
            }
        }
        self.parse_available(sh);
    }

    /// Parse complete messages off the input buffer, protocol-detected
    /// per message from its first byte.
    fn parse_available(&mut self, sh: &mut Shared<'_>) {
        loop {
            if self.closing || self.inbuf.is_empty() || self.pending.len() >= MAX_INFLIGHT {
                return;
            }
            if frame::is_binary(self.inbuf[0]) {
                match frame::decode_request(&self.inbuf) {
                    Ok(Some((req, used))) => {
                        self.inbuf.drain(..used);
                        self.dispatch_binary(sh, req);
                    }
                    Ok(None) => {
                        if self.inbuf.len() > MAX_MSG {
                            self.fail_frame(sh, "bad frame: exceeds the message size cap");
                        }
                        return;
                    }
                    Err(e) => {
                        self.fail_frame(sh, &e.to_string());
                        return;
                    }
                }
            } else {
                let Some(pos) = self.inbuf.iter().position(|&b| b == b'\n') else {
                    if self.inbuf.len() > MAX_MSG {
                        self.push_inline(
                            sh.ctx,
                            Proto::Json,
                            None,
                            String::new(),
                            Outcome::Reply(obj(vec![(
                                "error",
                                Json::Str("line exceeds the message size cap".into()),
                            )])),
                        );
                        self.closing = true;
                    }
                    return;
                };
                let line: Vec<u8> = self.inbuf.drain(..=pos).collect();
                let line = trim_ascii(&line[..line.len() - 1]);
                if line.is_empty() {
                    continue;
                }
                match Json::parse_bytes(line) {
                    Ok(req) => self.dispatch_json(sh, req),
                    Err(e) => self.push_inline(
                        sh.ctx,
                        Proto::Json,
                        None,
                        String::new(),
                        Outcome::Reply(obj(vec![(
                            "error",
                            Json::Str(format!("bad json: {e}")),
                        )])),
                    ),
                }
            }
        }
    }

    /// A malformed binary frame: the stream cannot be resynced, so
    /// answer with an `ERR_BAD_FRAME` frame and close after flushing.
    fn fail_frame(&mut self, sh: &mut Shared<'_>, msg: &str) {
        self.push_inline(
            sh.ctx,
            Proto::Binary { req_id: 0 },
            None,
            String::new(),
            Outcome::BinErr { code: frame::ERR_BAD_FRAME, message: msg.to_string() },
        );
        self.closing = true;
    }

    /// One parsed JSON request — admin dispatch or classify.
    fn dispatch_json(&mut self, sh: &mut Shared<'_>, req: Json) {
        if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
            let reply = match cmd {
                "shutdown" => {
                    sh.ctx.stop.store(true, Ordering::Relaxed);
                    obj(vec![("ok", Json::Bool(true))])
                }
                "stats" => stats_json(sh.ctx),
                "health" => health_json(sh.ctx),
                "models" => models_json(sh.ctx),
                "load" | "unload" | "reload" => {
                    self.spawn_admin(sh, cmd.to_string(), req);
                    return;
                }
                other => obj(vec![("error", Json::Str(format!("unknown cmd {other}")))]),
            };
            self.push_inline(sh.ctx, Proto::Json, None, String::new(), Outcome::Reply(reply));
            return;
        }
        let sparse = req.get("indices").is_some() || req.get("offsets").is_some();
        if !sparse && req.get("pixels").and_then(Json::as_arr).is_none() {
            self.push_inline(
                sh.ctx,
                Proto::Json,
                None,
                String::new(),
                Outcome::Reply(obj(vec![(
                    "error",
                    Json::Str("need pixels, indices/offsets, or cmd".into()),
                )])),
            );
            return;
        }
        let default_name = sh.ctx.registry.default_name();
        let model_name =
            req.get("model").and_then(Json::as_str).unwrap_or(&default_name).to_string();
        let Some(handle) = sh.ctx.registry.get(&model_name) else {
            self.push_inline(
                sh.ctx,
                Proto::Json,
                None,
                String::new(),
                Outcome::Reply(obj(vec![
                    ("error", Json::Str(format!("unknown model '{model_name}'"))),
                    ("code", Json::Str("unknown_model".into())),
                ])),
            );
            return;
        };
        // Per-request deadline: "timeout_ms" overrides the server
        // default; invalid values fail loudly as bad_input.
        let timeout = match json_timeout(&req, sh.ctx.default_timeout) {
            Ok(t) => t,
            Err(err) => {
                self.push_inline(
                    sh.ctx,
                    Proto::Json,
                    Some(handle),
                    model_name,
                    Outcome::Resp(failed(err)),
                );
                return;
            }
        };
        if sparse {
            // A sparse bag lookup: both arrays must be present and hold
            // in-range integer ids — silently dropping a malformed id
            // (as the dense path does for non-number pixels) would
            // shift every bag boundary after it.
            let ids = req.get("indices").and_then(Json::as_arr).and_then(parse_u32s);
            let offs = req.get("offsets").and_then(Json::as_arr).and_then(parse_u32s);
            let (Some(indices), Some(offsets)) = (ids, offs) else {
                let err = ServeError::BadInput(
                    "a sparse request needs \"indices\" and \"offsets\" arrays of u32".into(),
                );
                self.push_inline(
                    sh.ctx,
                    Proto::Json,
                    Some(handle),
                    model_name,
                    Outcome::Resp(failed(err)),
                );
                return;
            };
            self.classify_sparse(sh, Proto::Json, handle, model_name, indices, offsets, timeout);
            return;
        }
        let pixels: Vec<f32> = req
            .get("pixels")
            .and_then(Json::as_arr)
            .expect("checked above")
            .iter()
            .filter_map(|v| v.as_f64())
            .map(|v| v as f32)
            .collect();
        self.classify(sh, Proto::Json, handle, model_name, pixels, timeout);
    }

    /// One decoded binary classify frame.
    fn dispatch_binary(&mut self, sh: &mut Shared<'_>, req: frame::FrameRequest) {
        let proto = Proto::Binary { req_id: req.req_id };
        let model_name = if req.model.is_empty() {
            sh.ctx.registry.default_name()
        } else {
            req.model
        };
        let Some(handle) = sh.ctx.registry.get(&model_name) else {
            self.push_inline(
                sh.ctx,
                proto,
                None,
                String::new(),
                Outcome::BinErr {
                    code: frame::ERR_UNKNOWN_MODEL,
                    message: format!("unknown model '{model_name}'"),
                },
            );
            return;
        };
        let timeout = if req.timeout_ms == 0 {
            sh.ctx.default_timeout
        } else {
            Duration::from_millis(req.timeout_ms as u64)
        };
        match req.payload {
            frame::FramePayload::Dense(pixels) => {
                self.classify(sh, proto, handle, model_name, pixels, timeout)
            }
            frame::FramePayload::Sparse { indices, offsets } => {
                self.classify_sparse(sh, proto, handle, model_name, indices, offsets, timeout)
            }
        }
    }

    /// Protocol-independent classify tail: validation mirrors the old
    /// per-thread `handle_request` exactly (same error taxonomy, same
    /// counter accounting), then the request enters the model's bounded
    /// admission path with a reactor-completion hook.
    fn classify(
        &mut self,
        sh: &mut Shared<'_>,
        proto: Proto,
        handle: Arc<ModelHandle>,
        model_name: String,
        pixels: Vec<f32>,
        timeout: Duration,
    ) {
        count_proto(&handle, &proto);
        // Validate here, not in the batcher: a truncated input must fail
        // loudly instead of being zero-padded into a wrong classification.
        if handle.sparse {
            let err = ServeError::BadInput(format!(
                "model '{}' expects sparse indices/offsets, not dense pixels",
                handle.name
            ));
            self.push_inline(sh.ctx, proto, Some(handle), model_name, Outcome::Resp(failed(err)));
            return;
        }
        if pixels.len() != handle.n_in {
            let err = ServeError::BadInput(format!(
                "model '{}' expects {} pixels, got {}",
                handle.name,
                handle.n_in,
                pixels.len()
            ));
            self.push_inline(sh.ctx, proto, Some(handle), model_name, Outcome::Resp(failed(err)));
            return;
        }
        if handle.stop.load(Ordering::Relaxed) {
            // a handle caught mid-unload: typed reply, not an error count
            // (matches the old early-return before the reply wait)
            let err = ServeError::Unloaded(format!("model '{}' unloaded", handle.name));
            self.push_inline(sh.ctx, proto, None, model_name, Outcome::Resp(failed(err)));
            return;
        }
        let deadline = Instant::now() + timeout;
        let seq = self.submit_pending(sh, proto, handle.clone(), model_name, deadline, false);
        let sink = self.reactor_sink(sh, seq);
        handle.batcher.handle().submit_with(pixels, deadline, sink);
    }

    /// Sparse twin of [`Conn::classify`]: an embedding-bag lookup.
    /// Bag structure and index range are validated here with the same
    /// [`validate_bags`] the engine uses, so JSON and binary requests
    /// fail identically (`bad_input`) before touching the batcher.
    #[allow(clippy::too_many_arguments)]
    fn classify_sparse(
        &mut self,
        sh: &mut Shared<'_>,
        proto: Proto,
        handle: Arc<ModelHandle>,
        model_name: String,
        indices: Vec<u32>,
        offsets: Vec<u32>,
        timeout: Duration,
    ) {
        count_proto(&handle, &proto);
        if !handle.sparse {
            let err = ServeError::BadInput(format!(
                "model '{}' expects {} pixels, not sparse indices/offsets",
                handle.name, handle.n_in
            ));
            self.push_inline(sh.ctx, proto, Some(handle), model_name, Outcome::Resp(failed(err)));
            return;
        }
        if let Err(why) = validate_bags(&indices, &offsets, handle.n_in) {
            let err = ServeError::BadInput(format!("bad bag request: {why}"));
            self.push_inline(sh.ctx, proto, Some(handle), model_name, Outcome::Resp(failed(err)));
            return;
        }
        if handle.stop.load(Ordering::Relaxed) {
            let err = ServeError::Unloaded(format!("model '{}' unloaded", handle.name));
            self.push_inline(sh.ctx, proto, None, model_name, Outcome::Resp(failed(err)));
            return;
        }
        let deadline = Instant::now() + timeout;
        let seq = self.submit_pending(sh, proto, handle.clone(), model_name, deadline, true);
        let sink = self.reactor_sink(sh, seq);
        handle.batcher.handle().submit_sparse_with(indices, offsets, deadline, sink);
    }

    /// Shared admission tail for both request shapes: allocate the
    /// sequence number, arm the lost-reply backstop timer, and queue
    /// the pending slot. Returns the sequence for the reply sink.
    fn submit_pending(
        &mut self,
        sh: &mut Shared<'_>,
        proto: Proto,
        handle: Arc<ModelHandle>,
        model_name: String,
        deadline: Instant,
        sparse: bool,
    ) -> u64 {
        let seq = self.alloc_seq();
        // Lost-reply backstop (the old recv_timeout's grace window):
        // if nothing lands by deadline + grace, the timer pass answers
        // with the typed "timeout" code.
        sh.timers.push(Reverse((deadline + REPLY_GRACE, self.token, self.gen, seq)));
        self.pending.push_back(Pending {
            seq,
            proto,
            handle: Some(handle),
            model_name,
            sparse,
            outcome: None,
        });
        seq
    }

    /// A [`ReplySender`] that lands the worker's reply on the reactor's
    /// completion queue and wakes the loop.
    fn reactor_sink(&self, sh: &Shared<'_>, seq: u64) -> ReplySender {
        ReplySender::hook({
            let tx = sh.done_tx.clone();
            let wake = sh.wake.clone();
            let (token, gen) = (self.token, self.gen);
            move |resp| {
                let _ = tx.send(Done { token, gen, seq, payload: DonePayload::Resp(resp) });
                wake.wake();
            }
        })
    }

    /// Run a mutating admin command (`load`/`unload`/`reload`) on a
    /// short-lived thread: engine builds and worker joins must not stall
    /// the loop. The result arrives through the completion queue like
    /// any other reply, keeping per-connection FIFO order.
    fn spawn_admin(&mut self, sh: &mut Shared<'_>, cmd: String, req: Json) {
        let seq = self.alloc_seq();
        self.pending.push_back(Pending {
            seq,
            proto: Proto::Json,
            handle: None,
            model_name: String::new(),
            sparse: false,
            outcome: None,
        });
        let ctx = sh.ctx.clone();
        let tx = sh.done_tx.clone();
        let wake = sh.wake.clone();
        let (token, gen) = (self.token, self.gen);
        std::thread::spawn(move || {
            let reply = catch_unwind(AssertUnwindSafe(|| match cmd.as_str() {
                "load" => cmd_load(&req, &ctx),
                "unload" => cmd_unload(&req, &ctx),
                _ => cmd_reload(&ctx),
            }))
            .unwrap_or_else(|_| {
                obj(vec![("error", Json::Str(format!("cmd {cmd} panicked (contained)")))])
            });
            let _ = tx.send(Done { token, gen, seq, payload: DonePayload::Reply(reply) });
            wake.wake();
        });
    }

    /// Serialize the completed FIFO prefix and push bytes to the socket.
    /// Returns false once the connection is finished (closing, fully
    /// drained and flushed) and should be destroyed.
    fn flush(&mut self, poller: &mut Poller) -> bool {
        while let Some(front) = self.pending.front() {
            if front.outcome.is_none() {
                break;
            }
            let p = self.pending.pop_front().unwrap();
            serialize_reply(p, &mut self.outq);
        }
        while !self.outq.is_empty() {
            match self.outq.write_once(&mut self.stream) {
                Ok(0) => {
                    self.closing = true;
                    self.outq.clear();
                    break;
                }
                Ok(_) => {}
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    // peer is gone; drop the bytes but keep the entry
                    // accounting that already happened
                    self.closing = true;
                    self.outq.clear();
                    break;
                }
            }
        }
        if self.closing {
            // Completions arrive via the waker and each loop pass
            // re-flushes, so remaining replies still drain without
            // readiness events from this socket.
            if !self.deregistered {
                let _ = poller.deregister(self.stream.as_raw_fd());
                self.deregistered = true;
                self.registered_write = false;
            }
        } else {
            let want_write = !self.outq.is_empty();
            if want_write != self.registered_write {
                let interest = if want_write { Interest::BOTH } else { Interest::READ };
                if poller.modify(self.stream.as_raw_fd(), self.token, interest).is_ok() {
                    self.registered_write = want_write;
                }
            }
        }
        // keep a draining connection alive until every in-flight request
        // completed (counters!) and its replies are flushed or dropped
        !(self.closing && self.pending.is_empty() && self.outq.is_empty())
    }
}

/// `Response::failed` equivalent (that constructor is private to the
/// batcher): a typed error reply with no payload.
fn failed(error: ServeError) -> Response {
    Response { class: 0, probs: Vec::new(), latency_us: 0, error: Some(error) }
}

/// Per-model wire-protocol counters (`{"cmd":"stats"}` breakdown):
/// every classify attempt routed to a resolved model counts under the
/// protocol it arrived on, including ones that fail validation.
fn count_proto(handle: &ModelHandle, proto: &Proto) {
    match proto {
        Proto::Json => handle.reqs_json.fetch_add(1, Ordering::Relaxed),
        Proto::Binary { .. } => handle.reqs_binary.fetch_add(1, Ordering::Relaxed),
    };
}

/// Resolve a JSON request's `"timeout_ms"` against the server default;
/// invalid values fail loudly as `bad_input`.
fn json_timeout(req: &Json, default: Duration) -> Result<Duration, ServeError> {
    match req.get("timeout_ms") {
        None => Ok(default),
        Some(v) => match v.as_f64() {
            Some(ms) if ms.is_finite() && ms >= 1.0 => Ok(Duration::from_millis(ms as u64)),
            _ => Err(ServeError::BadInput("timeout_ms must be a number >= 1".into())),
        },
    }
}

/// Parse a JSON array as u32 ids; `None` on any entry that is not a
/// non-negative integer in range.
fn parse_u32s(vals: &[Json]) -> Option<Vec<u32>> {
    vals.iter()
        .map(|v| match v.as_f64() {
            Some(x) if x.is_finite() && (0.0..=u32::MAX as f64).contains(&x) && x.fract() == 0.0 => {
                Some(x as u32)
            }
            _ => None,
        })
        .collect()
}

/// Strip ASCII whitespace from both ends (stable-toolchain-friendly
/// stand-in for `[u8]::trim_ascii`).
fn trim_ascii(mut b: &[u8]) -> &[u8] {
    while let Some((first, rest)) = b.split_first() {
        if first.is_ascii_whitespace() {
            b = rest;
        } else {
            break;
        }
    }
    while let Some((last, rest)) = b.split_last() {
        if last.is_ascii_whitespace() {
            b = rest;
        } else {
            break;
        }
    }
    b
}

/// Apply the served/errors accounting for a completed request — the
/// same rules as the old per-thread reply wait: successes bump the
/// per-model and global served counters (the latter drives
/// `max_requests`); failures bump `errors` except overload rejections
/// and deadline expiries, which have their own batcher counters.
fn account(ctx: &ServeCtx, handle: Option<&ModelHandle>, outcome: &Outcome) {
    let (Some(h), Outcome::Resp(resp)) = (handle, outcome) else {
        return;
    };
    match &resp.error {
        None => {
            h.served.fetch_add(1, Ordering::Relaxed);
            let n = ctx.served.fetch_add(1, Ordering::Relaxed) + 1;
            if ctx.max_requests > 0 && n >= ctx.max_requests {
                ctx.stop.store(true, Ordering::Relaxed);
            }
        }
        Some(ServeError::Overloaded { .. }) | Some(ServeError::DeadlineExceeded) => {}
        Some(_) => {
            h.errors.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Serialize one completed request to its protocol's wire form. Each
/// reply lands on the connection's [`WriteQueue`]; a binary success
/// queues its header and payload as two buffers so they flush in a
/// single vectored write.
fn serialize_reply(p: Pending, out: &mut WriteQueue) {
    let outcome = p.outcome.expect("serialized only when complete");
    match p.proto {
        Proto::Json => {
            let json = match outcome {
                Outcome::Reply(j) => j,
                Outcome::Resp(resp) => match resp.error {
                    Some(err) => error_reply(&err, Some(&p.model_name)),
                    // Sparse bag replies rename the fields: `class`
                    // carries the bag count and the payload is b×dim
                    // bag vectors, not class probabilities.
                    None if p.sparse => obj(vec![
                        ("bags", num(resp.class as f64)),
                        ("values", Json::Arr(resp.probs.iter().map(|&x| num(x as f64)).collect())),
                        ("latency_us", num(resp.latency_us as f64)),
                        ("model", Json::Str(p.model_name)),
                    ]),
                    None => obj(vec![
                        ("class", num(resp.class as f64)),
                        ("probs", Json::Arr(resp.probs.iter().map(|&x| num(x as f64)).collect())),
                        ("latency_us", num(resp.latency_us as f64)),
                        ("model", Json::Str(p.model_name)),
                    ]),
                },
                Outcome::BinErr { message, .. } => {
                    obj(vec![("error", Json::Str(message))])
                }
            };
            let mut line = json.to_string().into_bytes();
            line.push(b'\n');
            out.push(line);
        }
        Proto::Binary { req_id } => match outcome {
            Outcome::Resp(resp) => {
                let latency = resp.latency_us.min(u32::MAX as u64) as u32;
                match &resp.error {
                    None => {
                        let mut header = Vec::new();
                        frame::encode_reply_ok_header(
                            &mut header,
                            req_id,
                            resp.class as u32,
                            latency,
                            resp.probs.len() as u32,
                        );
                        let mut payload = Vec::with_capacity(4 * resp.probs.len());
                        for v in &resp.probs {
                            payload.extend_from_slice(&v.to_le_bytes());
                        }
                        out.push(header);
                        out.push(payload);
                    }
                    Some(err) => {
                        let retry = match err {
                            ServeError::Overloaded { retry_after_ms } => {
                                (*retry_after_ms).min(u32::MAX as u64) as u32
                            }
                            _ => 0,
                        };
                        let mut buf = Vec::new();
                        frame::encode_reply_err(
                            &mut buf,
                            req_id,
                            frame::code_to_num(err.code()),
                            retry,
                            latency,
                            &err.to_string(),
                        );
                        out.push(buf);
                    }
                }
            }
            Outcome::BinErr { code, message } => {
                let mut buf = Vec::new();
                frame::encode_reply_err(&mut buf, req_id, code, 0, 0, &message);
                out.push(buf);
            }
            Outcome::Reply(j) => {
                // admin over the binary protocol isn't defined; surface
                // the JSON result as a frame error payload defensively
                let mut buf = Vec::new();
                frame::encode_reply_err(&mut buf, req_id, frame::ERR_BAD_FRAME, 0, 0, &j.to_string());
                out.push(buf);
            }
        },
    }
}

/// Route one completion to its connection. Generation and outcome
/// checks drop late or misrouted completions (a backstop already fired,
/// the connection died, the slot was reused) instead of misdelivering.
fn apply_done(ctx: &ServeCtx, conns: &mut [Option<Conn>], d: Done) {
    let Some(conn) = conns.get_mut(d.token).and_then(Option::as_mut) else {
        return;
    };
    if conn.gen != d.gen {
        return;
    }
    let Some(p) = conn.pending.iter_mut().find(|p| p.seq == d.seq) else {
        return;
    };
    if p.outcome.is_some() {
        return; // the timeout backstop answered first; drop the late reply
    }
    let outcome = match d.payload {
        DonePayload::Resp(resp) => Outcome::Resp(resp),
        DonePayload::Reply(j) => Outcome::Reply(j),
    };
    account(ctx, p.handle.as_deref(), &outcome);
    p.outcome = Some(outcome);
}

/// Fire due backstop timers: any request still unanswered past its
/// deadline + grace gets the typed `"timeout"` reply (and an error
/// count), exactly like the old blocking receive's `Err` arm.
fn fire_timers(ctx: &ServeCtx, conns: &mut [Option<Conn>], timers: &mut Timers, now: Instant) {
    while let Some(&Reverse((due, token, gen, seq))) = timers.peek() {
        if due > now {
            break;
        }
        timers.pop();
        let Some(conn) = conns.get_mut(token).and_then(Option::as_mut) else {
            continue;
        };
        if conn.gen != gen {
            continue;
        }
        let Some(p) = conn.pending.iter_mut().find(|p| p.seq == seq) else {
            continue;
        };
        if p.outcome.is_some() {
            continue;
        }
        let outcome = Outcome::Resp(failed(ServeError::Timeout));
        account(ctx, p.handle.as_deref(), &outcome);
        p.outcome = Some(outcome);
    }
}

/// The reactor: owns the listener, every connection, the completion
/// queue and the backstop timers. Returns once `ctx.stop` is observed
/// (shutdown command, `max_requests`, or an external trigger), after
/// retiring every model and answering/flushing everything in flight.
pub(crate) fn run_event_loop(
    listener: TcpListener,
    ctx: Arc<ServeCtx>,
    kind: PollerKind,
) -> Result<()> {
    let mut poller = Poller::new(kind)?;
    let waker = Waker::new()?;
    let wake = waker.handle();
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    poller.register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)?;
    poller.register(waker.fd(), TOKEN_WAKER, Interest::READ)?;
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut next_gen: u64 = 1;
    let mut timers: Timers = BinaryHeap::new();
    let mut events: Vec<Event> = Vec::new();
    println!(
        "serving [{}] on {} ({} event loop)",
        ctx.registry.names().join(", "),
        listener.local_addr()?,
        poller.backend_name()
    );

    let mut result: Result<()> = Ok(());
    while !ctx.stop.load(Ordering::Relaxed) {
        let now = Instant::now();
        let timeout = timers
            .peek()
            .map(|&Reverse((t, ..))| t.saturating_duration_since(now).min(TICK))
            .unwrap_or(TICK);
        if let Err(e) = poller.wait(&mut events, Some(timeout)) {
            result = Err(e.into());
            break;
        }
        for ev in events.iter().copied() {
            match ev.token {
                TOKEN_WAKER => waker.drain(),
                TOKEN_LISTENER => accept_ready(
                    &listener,
                    &mut poller,
                    &mut conns,
                    &mut free,
                    &mut next_gen,
                ),
                token => {
                    if ev.readable {
                        if let Some(conn) = conns.get_mut(token).and_then(Option::as_mut) {
                            let mut sh = Shared {
                                ctx: &ctx,
                                done_tx: &done_tx,
                                wake: &wake,
                                timers: &mut timers,
                            };
                            conn.handle_readable(&mut sh);
                        }
                    }
                    // writability is handled by the flush pass below
                }
            }
        }
        while let Ok(d) = done_rx.try_recv() {
            apply_done(&ctx, &mut conns, d);
        }
        fire_timers(&ctx, &mut conns, &mut timers, Instant::now());
        flush_all(&mut poller, &mut conns, &mut free);
        if ctx.max_requests > 0 && ctx.served.load(Ordering::Relaxed) >= ctx.max_requests {
            ctx.stop.store(true, Ordering::Relaxed);
        }
    }

    // ---- shutdown: answer everything, flush everything, then return ----
    ctx.stop.store(true, Ordering::Relaxed);
    let _ = poller.deregister(listener.as_raw_fd());
    // Retire every model: stops + joins workers, and the close() +
    // fail_pending() pair answers whatever was queued — each reply
    // arrives through the completion queue like any other.
    for h in ctx.registry.snapshot() {
        retire(&h);
    }
    let drain_deadline = Instant::now() + SHUTDOWN_DRAIN;
    loop {
        while let Ok(d) = done_rx.try_recv() {
            apply_done(&ctx, &mut conns, d);
        }
        let unresolved = conns
            .iter()
            .flatten()
            .any(|c| c.pending.iter().any(|p| p.outcome.is_none()));
        if !unresolved {
            break;
        }
        if Instant::now() >= drain_deadline {
            // nothing should reach this: every batcher path answers.
            // Fail the stragglers explicitly rather than hang.
            for conn in conns.iter_mut().flatten() {
                for p in conn.pending.iter_mut().filter(|p| p.outcome.is_none()) {
                    let outcome = Outcome::Resp(failed(ServeError::Timeout));
                    account(&ctx, p.handle.as_deref(), &outcome);
                    p.outcome = Some(outcome);
                }
            }
            break;
        }
        if let Ok(d) = done_rx.recv_timeout(Duration::from_millis(20)) {
            apply_done(&ctx, &mut conns, d);
        }
    }
    // Bounded final flush: serialize + write every queued reply (the
    // shutdown "ok" among them) before the sockets drop.
    let flush_deadline = Instant::now() + Duration::from_secs(2);
    loop {
        flush_all(&mut poller, &mut conns, &mut free);
        let dirty = conns
            .iter()
            .flatten()
            .any(|c| !c.outq.is_empty() || !c.pending.is_empty());
        if !dirty || Instant::now() >= flush_deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    for conn in conns.iter_mut().filter(|c| c.is_some()) {
        let c = conn.take().unwrap();
        let _ = poller.deregister(c.stream.as_raw_fd());
    }
    print_model_summary(&ctx);
    result
}

/// Accept until the listener would block. Transient failures (EMFILE
/// under a connection flood, a connection reset before accept) skip the
/// round instead of killing the server.
fn accept_ready(
    listener: &TcpListener,
    poller: &mut Poller,
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    next_gen: &mut u64,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let token = free.pop().unwrap_or_else(|| {
                    conns.push(None);
                    conns.len() - 1
                });
                let gen = *next_gen;
                *next_gen += 1;
                if poller.register(stream.as_raw_fd(), token, Interest::READ).is_err() {
                    free.push(token);
                    continue;
                }
                conns[token] = Some(Conn::new(stream, token, gen));
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

/// Flush every connection; destroy the ones that finished draining.
fn flush_all(poller: &mut Poller, conns: &mut [Option<Conn>], free: &mut Vec<usize>) {
    for slot in conns.iter_mut() {
        let keep = match slot.as_mut() {
            Some(conn) => conn.flush(poller),
            None => continue,
        };
        if !keep {
            let conn = slot.take().unwrap();
            let _ = poller.deregister(conn.stream.as_raw_fd());
            free.push(conn.token);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::WriteQueue;
    use std::io::{self, Write};

    /// A sink that accepts at most `cap` bytes per call — models a
    /// socket whose send buffer keeps filling up, forcing the queue to
    /// resume partial writes mid-buffer and across buffer boundaries.
    struct Trickle {
        cap: usize,
        data: Vec<u8>,
        calls: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.calls += 1;
            let n = buf.len().min(self.cap);
            self.data.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn write_vectored(&mut self, bufs: &[io::IoSlice<'_>]) -> io::Result<usize> {
            self.calls += 1;
            let mut left = self.cap;
            let mut n = 0;
            for b in bufs {
                let take = b.len().min(left);
                self.data.extend_from_slice(&b[..take]);
                n += take;
                left -= take;
                if left == 0 {
                    break;
                }
            }
            Ok(n)
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_queue_resumes_partial_writes_in_order() {
        let mut q = WriteQueue::new();
        q.push(vec![1, 2, 3, 4, 5]);
        q.push(Vec::new()); // empty buffers are skipped, not queued
        q.push(vec![6, 7, 8]);
        q.push(vec![9]);
        // cap 2 stops mid-buffer (inside the 5-byte buffer) and on
        // buffer boundaries; every resume must pick up exactly where
        // the previous short write ended.
        let mut sink = Trickle { cap: 2, data: Vec::new(), calls: 0 };
        let mut rounds = 0;
        while !q.is_empty() {
            q.write_once(&mut sink).unwrap();
            rounds += 1;
            assert!(rounds < 32, "queue failed to drain");
        }
        assert_eq!(sink.data, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert!(sink.calls >= 5, "9 bytes at <=2/call takes >=5 calls");
    }

    #[test]
    fn write_queue_header_and_payload_leave_in_one_vectored_write() {
        let mut q = WriteQueue::new();
        q.push(vec![0xAA; 20]); // reply header
        q.push(vec![0xBB; 40]); // reply payload
        let mut sink = Trickle { cap: 1024, data: Vec::new(), calls: 0 };
        q.write_once(&mut sink).unwrap();
        assert!(q.is_empty());
        assert_eq!(sink.calls, 1, "both buffers must go in one writev");
        assert_eq!(sink.data.len(), 60);
        assert_eq!(&sink.data[..20], &[0xAA; 20][..]);
        assert_eq!(&sink.data[20..], &[0xBB; 40][..]);
    }

    #[test]
    fn write_queue_partial_write_straddles_the_header_payload_boundary() {
        let mut q = WriteQueue::new();
        q.push(vec![1; 20]);
        q.push(vec![2; 40]);
        // first write takes the header plus 10 payload bytes; the next
        // resumes 10 bytes into the second buffer
        let mut sink = Trickle { cap: 30, data: Vec::new(), calls: 0 };
        q.write_once(&mut sink).unwrap();
        assert!(!q.is_empty());
        q.write_once(&mut sink).unwrap();
        assert!(q.is_empty());
        let mut want = vec![1u8; 20];
        want.extend(vec![2u8; 40]);
        assert_eq!(sink.data, want);
    }
}
