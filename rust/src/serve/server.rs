//! TCP front end: model registry, admin commands, and the blocking
//! [`Client`]. The connection layer itself is the event loop in
//! `serve/conn.rs`: one reactor thread ([`super::poll`]) drives every
//! connection, feeding classify requests into each model's bounded
//! [`DynamicBatcher`] admission path.
//!
//! Two wire protocols share the port, auto-detected per message from
//! the first byte: newline-delimited JSON (below), and the
//! length-prefixed binary frame format in [`super::frame`] (first byte
//! [`frame::MAGIC`](super::frame::MAGIC), which can never start JSON).
//!
//! JSON protocol (one object per line):
//!   request:  {"pixels": [f32; n_in]}              → classify (default model)
//!             {"model": "name", "pixels": [...]}   → classify a named model
//!               optional "timeout_ms"              → per-request deadline
//!                                                    (default --timeout-ms)
//!             {"indices": [u32], "offsets": [u32]} → sparse embedding-bag
//!                                                    lookup (hashed_embedding
//!                                                    models); replies with
//!                                                    {"bags": b, "values":
//!                                                    [f32; b*dim], ...}
//!             {"cmd": "stats"}                     → server + per-model counters
//!             {"cmd": "health"}                    → liveness: live workers,
//!                                                    queue depth, resilience
//!                                                    counters per model
//!             {"cmd": "models"}                    → per-model metadata (spec,
//!                                                    storage, bundle version)
//!             {"cmd": "load", "path": "m.hnb"}     → hot-load a model bundle
//!                                                    (optional "name", "workers")
//!             {"cmd": "unload", "model": "name"}   → remove a served model
//!             {"cmd": "reload"}                    → rebuild every model from
//!                                                    its source file(s)
//!             {"cmd": "shutdown"}                  → stop accepting
//!   response: {"class": u, "probs": [...], "latency_us": u, "model": "name"}
//!             {"error": "...", "code": "..."}      → typed failure; codes:
//!                 "overloaded" (queue full; carries "retry_after_ms"),
//!                 "deadline" (expired before inference), "timeout" (reply
//!                 never arrived), "engine" (failure/panic, contained),
//!                 "bad_input", "unloaded", "unknown_model"
//!
//! One process serves **multiple named models** through a mutable
//! engine registry: each model gets its own [`DynamicBatcher`] plus
//! worker threads — N threads sharing one `NativeEngine`, or a single
//! thread owning a PJRT `RuntimeEngine`. The registry is `RwLock`'d so
//! `{"cmd":"load"}` can register a bundle trained *after* startup
//! without restarting: the new handle is swapped in, new requests route
//! to it, and the displaced handle drains on its own `Arc` (its workers
//! finish, queued requests get explicit replies) while other models
//! keep serving uninterrupted.
//!
//! [`Server::bind`] / [`Server::run`] split binding from serving so
//! callers can bind port 0 and read [`Server::local_addr`] before the
//! accept loop starts; [`serve`] is the one-call wrapper.

use super::batcher::{DynamicBatcher, ServeError};
use super::conn::run_event_loop;
use super::engine::{
    error_loop, worker_loop, Backend, InferenceEngine, ModelConfig, NativeEngine, RuntimeEngine,
};
use super::poll::PollerKind;
use crate::model::{BundleMap, ModelSpec};
use crate::runtime::{ArtifactSpec, Manifest, Runtime};
use crate::util::json::{num, obj, Json};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub artifacts_dir: PathBuf,
    /// Models to serve; the first is the default for requests that
    /// carry no `"model"` field.
    pub models: Vec<ModelConfig>,
    pub addr: String,
    /// Execution backend; `Auto` prefers the PJRT runtime and falls
    /// back to native when artifact loading fails. Bundle-sourced
    /// models are always native (a bundle carries no HLO graphs).
    pub backend: Backend,
    /// Worker threads per natively-served model (the runtime backend
    /// is always pinned to one worker — PJRT handles are not `Send`).
    pub workers: usize,
    pub max_wait: Duration,
    /// Stop after serving this many classify requests (0 = run forever).
    /// Used by tests and the examples.
    pub max_requests: u64,
    /// Admission bound per model: at most this many requests queue in a
    /// model's batcher; further submits are rejected immediately with an
    /// explicit `overloaded` reply (`--max-pending`).
    pub max_pending: usize,
    /// Default per-request deadline, used when a classify request
    /// carries no `"timeout_ms"` field (`--timeout-ms`). Replaces the
    /// old hardcoded 10 s receive timeout.
    pub default_timeout: Duration,
    /// Readiness backend for the connection event loop (`--poller`):
    /// `Auto` picks epoll on Linux, portable `poll(2)` elsewhere.
    pub poller: PollerKind,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            artifacts_dir: "artifacts".into(),
            models: Vec::new(),
            addr: "127.0.0.1:7878".into(),
            backend: Backend::Auto,
            workers: 2,
            max_wait: Duration::from_millis(2),
            max_requests: 0,
            max_pending: 256,
            default_timeout: Duration::from_secs(10),
            poller: PollerKind::Auto,
        }
    }
}

impl ServeOptions {
    /// One manifest artifact, default everything else.
    pub fn single(artifact: impl Into<String>) -> ServeOptions {
        ServeOptions { models: vec![ModelConfig::new(artifact)], ..Default::default() }
    }

    /// One bundle file, default everything else.
    pub fn single_bundle(path: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions { models: vec![ModelConfig::bundle(path)], ..Default::default() }
    }
}

/// Where a served model's engine came from — retained on the handle so
/// `{"cmd":"reload"}` can rebuild it from disk.
#[derive(Debug, Clone)]
enum ModelSource {
    /// A self-describing bundle file (native backend).
    Bundle(PathBuf),
    /// A manifest artifact + optional parameter file; `runtime` marks
    /// the PJRT backend.
    Artifact { artifact: String, checkpoint: Option<PathBuf>, runtime: bool },
    /// Injected via [`Server::bind_with_engines`]; cannot be reloaded.
    Injected,
}

impl ModelSource {
    fn describe(&self) -> String {
        match self {
            ModelSource::Bundle(p) => format!("bundle:{}", p.display()),
            ModelSource::Artifact { artifact, .. } => format!("artifact:{artifact}"),
            ModelSource::Injected => "injected".into(),
        }
    }
}

/// One served model: its batcher (shared with the worker threads),
/// request counters, worker lifecycle, and provenance. The event loop
/// holds an `Arc` per in-flight request, so a handle displaced from
/// the registry stays fully functional until its last request drains.
pub(crate) struct ModelHandle {
    pub(crate) name: String,
    backend: &'static str,
    workers: usize,
    pub(crate) n_in: usize,
    n_out: usize,
    max_batch: usize,
    pub(crate) batcher: DynamicBatcher,
    pub(crate) served: AtomicU64,
    pub(crate) errors: AtomicU64,
    /// Classify requests received per wire protocol (JSON lines vs
    /// binary frames) — the `{"cmd":"stats"}` per-model breakdown.
    /// Counted at dispatch, so validation failures are included.
    pub(crate) reqs_json: AtomicU64,
    pub(crate) reqs_binary: AtomicU64,
    /// The engine takes sparse `indices`/`offsets` bag requests instead
    /// of dense pixel rows (hashed embedding-bag models); `n_in` is its
    /// category-id range, not a pixel count.
    pub(crate) sparse: bool,
    /// Worker threads currently running (each decrements on exit);
    /// `{"cmd":"health"}` compares it against `workers` to surface a
    /// permanently-dead worker. The containment in `worker_loop` means
    /// this should only drop below `workers` once `stop` is set.
    live: Arc<AtomicUsize>,
    /// Per-model stop flag — this model's worker threads watch it; set
    /// by unload / hot-swap / server shutdown.
    pub(crate) stop: Arc<AtomicBool>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
    source: ModelSource,
    /// Model identity, when known (absent for injected engines).
    spec: Option<ModelSpec>,
    /// Bundle format version, when the model came from a bundle file.
    bundle_version: Option<u32>,
}

/// Mutable model registry shared by the event loop, admin threads and
/// the batcher completion hooks.
pub(crate) struct Registry {
    models: RwLock<BTreeMap<String, Arc<ModelHandle>>>,
    default_model: RwLock<String>,
}

impl Registry {
    pub(crate) fn get(&self, name: &str) -> Option<Arc<ModelHandle>> {
        self.models.read().unwrap().get(name).cloned()
    }

    pub(crate) fn snapshot(&self) -> Vec<Arc<ModelHandle>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    pub(crate) fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    /// Insert under the handle's name; returns the displaced handle.
    fn insert(&self, handle: Arc<ModelHandle>) -> Option<Arc<ModelHandle>> {
        self.models.write().unwrap().insert(handle.name.clone(), handle)
    }

    fn remove(&self, name: &str) -> Option<Arc<ModelHandle>> {
        self.models.write().unwrap().remove(name)
    }

    pub(crate) fn default_name(&self) -> String {
        self.default_model.read().unwrap().clone()
    }

    fn set_default(&self, name: &str) {
        *self.default_model.write().unwrap() = name.to_string();
    }
}

/// Everything the event loop and admin threads need, shared behind one
/// `Arc`.
pub(crate) struct ServeCtx {
    pub(crate) registry: Registry,
    pub(crate) stop: AtomicBool,
    pub(crate) served: AtomicU64,
    pub(crate) max_requests: u64,
    artifacts_dir: PathBuf,
    backend: Backend,
    default_workers: usize,
    max_wait: Duration,
    max_pending: usize,
    pub(crate) default_timeout: Duration,
}

/// Stop a handle's workers, join them, and fail whatever was queued —
/// the tail end of unload, hot-swap and shutdown. Never called with a
/// registry lock held.
pub(crate) fn retire(handle: &ModelHandle) {
    handle.stop.store(true, Ordering::Relaxed);
    let joins: Vec<_> = handle.joins.lock().unwrap().drain(..).collect();
    for j in joins {
        let _ = j.join();
    }
    // Close the queue so every later submit fails fast, then fail the
    // requests that were already queued with the typed cause. The
    // closed check and this drain serialize on the queue mutex, so a
    // submit racing the unload is either rejected immediately or
    // caught here — never stranded until its receive timeout.
    handle.batcher.close();
    handle
        .batcher
        .fail_pending(ServeError::Unloaded(format!("model '{}' unloaded", handle.name)));
}

impl ServeCtx {
    /// Build a handle from a bind-time [`ModelConfig`].
    fn open_from_config(&self, mc: &ModelConfig) -> Result<Arc<ModelHandle>> {
        match &mc.bundle {
            Some(path) => self.open_bundle(path, None, self.default_workers),
            None => self.open_artifact(&mc.artifact, mc.checkpoint.as_deref()),
        }
    }

    /// Native engine from a bundle file, loaded mmap+checksum instead
    /// of read-parse-copy: [`BundleMap::open`] runs the same validation
    /// as `ModelBundle::load`, then f32 tensors serve in place from the
    /// mapping (quantized tensors dequantize once here).
    fn open_bundle(
        &self,
        path: &Path,
        name_override: Option<&str>,
        workers: usize,
    ) -> Result<Arc<ModelHandle>> {
        let map = Arc::new(
            BundleMap::open(path)
                .map_err(|e| anyhow!("loading bundle {}: {e}", path.display()))?,
        );
        let name = name_override.unwrap_or(&map.spec().name).to_string();
        let spec = map.spec().clone();
        let version = map.version();
        let eng: Arc<dyn InferenceEngine + Send + Sync> =
            Arc::new(NativeEngine::from_bundle_map(&map)?);
        Ok(spawn_engine_workers(
            name,
            eng,
            workers,
            self.max_wait,
            self.max_pending,
            ModelSource::Bundle(path.to_path_buf()),
            Some(spec),
            Some(version),
        ))
    }

    /// Engine for a manifest artifact, honoring the backend selection.
    fn open_artifact(
        &self,
        artifact: &str,
        checkpoint: Option<&Path>,
    ) -> Result<Arc<ModelHandle>> {
        let manifest = Manifest::load(&self.artifacts_dir.join("manifest.json"))?;
        let spec = manifest
            .get(artifact)
            .ok_or_else(|| anyhow!("unknown artifact '{artifact}'"))?
            .clone();
        let use_runtime = match self.backend {
            Backend::Native => false,
            Backend::Runtime => match probe_runtime(&self.artifacts_dir, &spec) {
                Some(e) => return Err(anyhow!("--backend runtime unavailable: {e}")),
                None => true,
            },
            Backend::Auto => match probe_runtime(&self.artifacts_dir, &spec) {
                Some(e) => {
                    eprintln!(
                        "backend auto: runtime unavailable ({e}); serving '{artifact}' natively"
                    );
                    false
                }
                None => true,
            },
        };
        let source = ModelSource::Artifact {
            artifact: artifact.to_string(),
            checkpoint: checkpoint.map(Path::to_path_buf),
            runtime: use_runtime,
        };
        if use_runtime {
            Ok(self.spawn_runtime_model(&spec, checkpoint, source))
        } else {
            let bundle = spec.resolve_bundle(checkpoint, 0x5EED)?;
            let model_spec = bundle.spec.clone();
            let eng: Arc<dyn InferenceEngine + Send + Sync> =
                Arc::new(NativeEngine::from_bundle(&bundle)?);
            Ok(spawn_engine_workers(
                artifact.to_string(),
                eng,
                self.default_workers,
                self.max_wait,
                self.max_pending,
                source,
                Some(model_spec),
                None,
            ))
        }
    }

    /// PJRT handles are not `Send`: the engine is built inside its
    /// (single) worker thread, which then owns it for life.
    fn spawn_runtime_model(
        &self,
        spec: &ArtifactSpec,
        checkpoint: Option<&Path>,
        source: ModelSource,
    ) -> Arc<ModelHandle> {
        let batcher =
            DynamicBatcher::bounded(spec.batch.max(1), self.max_wait, self.max_pending).padded();
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(1)); // counted before the thread starts
        let handle = Arc::new(ModelHandle {
            name: spec.name.clone(),
            backend: "runtime",
            workers: 1,
            n_in: spec.dims[0],
            n_out: *spec.dims.last().unwrap_or(&0),
            max_batch: spec.batch.max(1),
            batcher: batcher.clone(),
            served: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            reqs_json: AtomicU64::new(0),
            reqs_binary: AtomicU64::new(0),
            sparse: false,
            live: live.clone(),
            stop: stop.clone(),
            joins: Mutex::new(Vec::new()),
            source,
            spec: Some(spec.to_model_spec()),
            bundle_version: None,
        });
        let dir = self.artifacts_dir.clone();
        let artifact = spec.name.clone();
        let ckpt = checkpoint.map(Path::to_path_buf);
        let n_in = spec.dims[0];
        let join = std::thread::spawn(move || {
            match RuntimeEngine::open(&dir, &artifact, ckpt.as_deref()) {
                Ok(eng) => worker_loop(&eng, &batcher, &stop),
                Err(e) => {
                    let msg = format!("runtime backend for '{artifact}' failed: {e:#}");
                    eprintln!("{msg}");
                    error_loop(&msg, n_in, &batcher, &stop);
                }
            }
            live.fetch_sub(1, Ordering::Relaxed);
        });
        handle.joins.lock().unwrap().push(join);
        handle
    }

    /// Rebuild a model from its recorded source (`{"cmd":"reload"}`);
    /// `None` means the source is not reloadable (injected engine).
    fn rebuild(&self, handle: &ModelHandle) -> Result<Option<Arc<ModelHandle>>> {
        match &handle.source {
            ModelSource::Injected => Ok(None),
            ModelSource::Bundle(path) => self
                .open_bundle(path, Some(&handle.name), handle.workers)
                .map(Some),
            ModelSource::Artifact { artifact, checkpoint, .. } => {
                self.open_artifact(artifact, checkpoint.as_deref()).map(Some)
            }
        }
    }
}

/// A bound server: workers are already running; [`Server::run`] enters
/// the accept loop. Returned by [`Server::bind`] so callers (tests,
/// benches) can bind port 0 and read the chosen address.
pub struct Server {
    listener: TcpListener,
    local: SocketAddr,
    ctx: Arc<ServeCtx>,
    poller: PollerKind,
}

impl Server {
    /// Bind the listener, build one engine per configured model, and
    /// spawn the worker threads. Fails eagerly on a bad address, an
    /// unknown artifact, an unreadable bundle, a checkpoint/spec
    /// mismatch, or (with `--backend runtime`) an unavailable PJRT
    /// runtime.
    pub fn bind(opt: ServeOptions) -> Result<Server> {
        Server::bind_with_engines(opt, Vec::new())
    }

    /// [`Server::bind`] plus pre-built engines (tests and benches
    /// inject custom [`InferenceEngine`]s — e.g. a failing one to
    /// exercise the error path). Custom engines are registered under
    /// their paired name and served like native models.
    pub fn bind_with_engines(
        opt: ServeOptions,
        custom: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&opt.addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let poller = opt.poller;

        let ctx = Arc::new(ServeCtx {
            registry: Registry {
                models: RwLock::new(BTreeMap::new()),
                default_model: RwLock::new(String::new()),
            },
            stop: AtomicBool::new(false),
            served: AtomicU64::new(0),
            max_requests: opt.max_requests,
            artifacts_dir: opt.artifacts_dir.clone(),
            backend: opt.backend,
            default_workers: opt.workers,
            max_wait: opt.max_wait,
            max_pending: opt.max_pending,
            default_timeout: opt.default_timeout,
        });

        let mut first_custom: Option<String> = None;
        let mut first_configured: Option<String> = None;
        // FnOnce: consumes `custom`, mutates the `first_*` trackers.
        let build = || -> Result<()> {
            for (name, eng) in custom {
                first_custom.get_or_insert_with(|| name.clone());
                let handle = spawn_engine_workers(
                    name,
                    eng,
                    ctx.default_workers,
                    ctx.max_wait,
                    ctx.max_pending,
                    ModelSource::Injected,
                    None,
                    None,
                );
                let name = handle.name.clone();
                if let Some(displaced) = ctx.registry.insert(handle) {
                    // stop the displaced handle's workers too — the
                    // error path below only retires what's in the map
                    retire(&displaced);
                    return Err(anyhow!("duplicate model name '{name}'"));
                }
            }
            for mc in &opt.models {
                let handle = ctx.open_from_config(mc)?;
                let name = handle.name.clone();
                // a duplicate would orphan the first entry's workers
                // and batcher while stats silently showed only one
                if let Some(displaced) = ctx.registry.insert(handle) {
                    retire(&displaced);
                    return Err(anyhow!("duplicate model name '{name}'"));
                }
                first_configured.get_or_insert(name);
            }
            Ok(())
        };
        match build() {
            Ok(()) => {}
            Err(e) => {
                // don't leak worker threads spawned for earlier models
                for h in ctx.registry.snapshot() {
                    retire(&h);
                }
                return Err(e);
            }
        }
        let default = first_configured
            .or(first_custom)
            .ok_or_else(|| anyhow!("no models configured"))?;
        ctx.registry.set_default(&default);
        Ok(Server { listener, local, ctx, poller })
    }

    /// The bound address — pass port 0 to `ServeOptions::addr` and read
    /// the picked port here.
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Enter the connection event loop (`serve/conn.rs`); returns once
    /// shut down (via `{"cmd":"shutdown"}` or `max_requests`), after
    /// retiring every model and answering everything in flight.
    pub fn run(self) -> Result<()> {
        run_event_loop(self.listener, self.ctx, self.poller)
    }
}

/// The end-of-run per-model summary (printed by the event loop once
/// everything has drained).
pub(crate) fn print_model_summary(ctx: &ServeCtx) {
    for h in ctx.registry.snapshot() {
        let s = h.batcher.stats();
        println!(
            "{} [{} x{}]: {} served / {} errors in {} batches (mean fill {:.0}%)",
            h.name,
            h.backend,
            h.workers,
            h.served.load(Ordering::Relaxed),
            h.errors.load(Ordering::Relaxed),
            s.batches,
            100.0 * s.mean_fill(h.max_batch)
        );
    }
}

/// Run the server; returns once shut down. Prints the bound address —
/// pass port 0 to pick one (or use [`Server::bind`] to read it back).
pub fn serve(opt: ServeOptions) -> Result<()> {
    Server::bind(opt)?.run()
}

/// Register a model handle and start `n_workers` threads sharing one
/// engine and one batcher — the native multi-worker path (also used
/// for injected custom engines).
fn spawn_engine_workers(
    name: String,
    eng: Arc<dyn InferenceEngine + Send + Sync>,
    n_workers: usize,
    max_wait: Duration,
    max_pending: usize,
    source: ModelSource,
    spec: Option<ModelSpec>,
    bundle_version: Option<u32>,
) -> Arc<ModelHandle> {
    let n_workers = n_workers.max(1);
    let mut batcher = DynamicBatcher::bounded(eng.max_batch(), max_wait, max_pending);
    if eng.fixed_batch() {
        batcher = batcher.padded();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let live = Arc::new(AtomicUsize::new(0));
    let handle = Arc::new(ModelHandle {
        name,
        backend: eng.name(),
        workers: n_workers,
        n_in: eng.n_in(),
        n_out: eng.n_out(),
        max_batch: eng.max_batch(),
        batcher: batcher.clone(),
        served: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        reqs_json: AtomicU64::new(0),
        reqs_binary: AtomicU64::new(0),
        sparse: eng.sparse_input(),
        live: live.clone(),
        stop: stop.clone(),
        joins: Mutex::new(Vec::new()),
        source,
        spec,
        bundle_version,
    });
    let mut joins = handle.joins.lock().unwrap();
    for _ in 0..n_workers {
        let eng = eng.clone();
        let b = batcher.clone();
        let stop = stop.clone();
        // count the worker live *before* its thread starts so a health
        // probe racing the spawn never sees a half-started model as dead
        live.fetch_add(1, Ordering::Relaxed);
        let live = live.clone();
        joins.push(std::thread::spawn(move || {
            worker_loop(&*eng, &b, &stop);
            live.fetch_sub(1, Ordering::Relaxed);
        }));
    }
    drop(joins);
    handle
}

/// PJRT availability probe for `Backend::Runtime` / `Backend::Auto`:
/// returns `Some(reason)` when the runtime cannot serve `spec`.
fn probe_runtime(dir: &Path, spec: &ArtifactSpec) -> Option<String> {
    if let Err(e) = Runtime::open(dir) {
        return Some(format!("{e:#}"));
    }
    let hlo = dir.join(&spec.graphs.1);
    if !hlo.exists() {
        return Some(format!("missing predict graph {}", hlo.display()));
    }
    None
}

/// A typed error as a wire reply: human-readable `error`, stable
/// machine-readable `code`, and — for overload rejections — the
/// `retry_after_ms` backoff hint the client's retry loop reads.
pub(crate) fn error_reply(err: &ServeError, model: Option<&str>) -> Json {
    let mut pairs = vec![
        ("error", Json::Str(err.to_string())),
        ("code", Json::Str(err.code().to_string())),
    ];
    if let ServeError::Overloaded { retry_after_ms } = err {
        pairs.push(("retry_after_ms", num(*retry_after_ms as f64)));
    }
    if let Some(m) = model {
        pairs.push(("model", Json::Str(m.to_string())));
    }
    obj(pairs)
}

/// `{"cmd":"load","path":…}`: hot-load a bundle into the running
/// registry. An existing model of the same name is swapped out — its
/// in-flight requests drain on the displaced handle, new requests hit
/// the fresh engine — and every other model is untouched.
pub(crate) fn cmd_load(req: &Json, ctx: &ServeCtx) -> Json {
    let Some(path) = req.get("path").and_then(Json::as_str) else {
        return obj(vec![("error", Json::Str("load needs a bundle \"path\"".into()))]);
    };
    let name_override = req.get("name").and_then(Json::as_str);
    let workers = req
        .get("workers")
        .and_then(Json::as_usize)
        .unwrap_or(ctx.default_workers);
    match ctx.open_bundle(Path::new(path), name_override, workers) {
        Ok(handle) => {
            let name = handle.name.clone();
            let stored = handle.spec.as_ref().map(|s| s.stored_params()).unwrap_or(0);
            let displaced = ctx.registry.insert(handle);
            if ctx.registry.default_name().is_empty() {
                ctx.registry.set_default(&name);
            }
            let swapped = displaced.is_some();
            if let Some(old) = displaced {
                retire(&old);
            }
            obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name)),
                ("swapped", Json::Bool(swapped)),
                ("stored_params", num(stored as f64)),
            ])
        }
        Err(e) => obj(vec![("error", Json::Str(format!("{e:#}")))]),
    }
}

/// `{"cmd":"unload","model":…}`: remove a model. Its queued requests
/// get explicit errors; other models keep serving.
pub(crate) fn cmd_unload(req: &Json, ctx: &ServeCtx) -> Json {
    let Some(name) = req.get("model").and_then(Json::as_str) else {
        return obj(vec![("error", Json::Str("unload needs a \"model\" name".into()))]);
    };
    match ctx.registry.remove(name) {
        None => obj(vec![("error", Json::Str(format!("unknown model '{name}'")))]),
        Some(handle) => {
            if ctx.registry.default_name() == name {
                let next = ctx.registry.names().first().cloned().unwrap_or_default();
                ctx.registry.set_default(&next);
            }
            retire(&handle);
            obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(name.to_string())),
                ("default", Json::Str(ctx.registry.default_name())),
            ])
        }
    }
}

/// `{"cmd":"reload"}`: rebuild every model from its source file(s),
/// swapping each in atomically. Injected engines (no file source) are
/// skipped; per-model failures are reported without disturbing the
/// running handle.
pub(crate) fn cmd_reload(ctx: &ServeCtx) -> Json {
    let mut reloaded = Vec::new();
    let mut skipped = Vec::new();
    let mut errors = Vec::new();
    for handle in ctx.registry.snapshot() {
        match ctx.rebuild(&handle) {
            Ok(Some(fresh)) => {
                let displaced = ctx.registry.insert(fresh);
                if let Some(old) = displaced {
                    retire(&old);
                }
                reloaded.push(handle.name.clone());
            }
            Ok(None) => skipped.push(handle.name.clone()),
            Err(e) => errors.push(format!("{}: {e:#}", handle.name)),
        }
    }
    let to_arr = |v: Vec<String>| Json::Arr(v.into_iter().map(Json::Str).collect());
    obj(vec![
        ("ok", Json::Bool(errors.is_empty())),
        ("reloaded", to_arr(reloaded)),
        ("skipped", to_arr(skipped)),
        ("errors", to_arr(errors)),
    ])
}

/// `{"cmd":"stats"}` reply: aggregate counters plus per-model backend,
/// worker count, served/error/rejected/expired counters and batch
/// fill. Each top-level aggregate equals the sum over the per-model
/// entries of the currently-registered models (asserted by the stats
/// test); `served` is the global counter that also drives
/// `max_requests`.
pub(crate) fn stats_json(ctx: &ServeCtx) -> Json {
    let mut errors = 0u64;
    let mut rejected = 0u64;
    let mut expired = 0u64;
    let per: Vec<(String, Json)> = ctx
        .registry
        .snapshot()
        .into_iter()
        .map(|h| {
            let s = h.batcher.stats();
            errors += h.errors.load(Ordering::Relaxed);
            rejected += s.rejected;
            expired += s.expired;
            (
                h.name.clone(),
                obj(vec![
                    ("backend", Json::Str(h.backend.to_string())),
                    ("workers", num(h.workers as f64)),
                    ("served", num(h.served.load(Ordering::Relaxed) as f64)),
                    ("errors", num(h.errors.load(Ordering::Relaxed) as f64)),
                    ("json_requests", num(h.reqs_json.load(Ordering::Relaxed) as f64)),
                    ("binary_requests", num(h.reqs_binary.load(Ordering::Relaxed) as f64)),
                    ("rejected", num(s.rejected as f64)),
                    ("expired", num(s.expired as f64)),
                    ("panics_contained", num(s.panics as f64)),
                    ("batches", num(s.batches as f64)),
                    ("mean_fill", num(s.mean_fill(h.max_batch))),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("served", num(ctx.served.load(Ordering::Relaxed) as f64)),
        ("errors", num(errors as f64)),
        ("rejected", num(rejected as f64)),
        ("expired", num(expired as f64)),
        (
            "models",
            Json::Obj(per.into_iter().collect()),
        ),
    ])
}

/// `{"cmd":"health"}` reply: liveness-oriented view — per model, the
/// configured vs live worker count, current queue depth against its
/// bound, and the resilience counters. Top-level `ok` is true iff
/// every registered model still has at least one live worker.
pub(crate) fn health_json(ctx: &ServeCtx) -> Json {
    let mut all_live = true;
    let per: Vec<(String, Json)> = ctx
        .registry
        .snapshot()
        .into_iter()
        .map(|h| {
            let s = h.batcher.stats();
            let live = h.live.load(Ordering::Relaxed);
            all_live &= live > 0;
            (
                h.name.clone(),
                obj(vec![
                    ("workers", num(h.workers as f64)),
                    ("live_workers", num(live as f64)),
                    ("queue_depth", num(h.batcher.pending() as f64)),
                    ("max_pending", num(h.batcher.max_pending() as f64)),
                    ("served", num(h.served.load(Ordering::Relaxed) as f64)),
                    ("errors", num(h.errors.load(Ordering::Relaxed) as f64)),
                    ("rejected", num(s.rejected as f64)),
                    ("expired", num(s.expired as f64)),
                    ("panics_contained", num(s.panics as f64)),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("ok", Json::Bool(all_live)),
        ("models", Json::Obj(per.into_iter().collect())),
    ])
}

/// `{"cmd":"models"}` reply: the registry's metadata — spec identity,
/// storage accounting, compression, bundle version and source per
/// model, plus the current default.
pub(crate) fn models_json(ctx: &ServeCtx) -> Json {
    let per: Vec<(String, Json)> = ctx
        .registry
        .snapshot()
        .into_iter()
        .map(|h| {
            let mut pairs = vec![
                ("backend", Json::Str(h.backend.to_string())),
                ("workers", num(h.workers as f64)),
                ("n_in", num(h.n_in as f64)),
                ("n_out", num(h.n_out as f64)),
                ("max_batch", num(h.max_batch as f64)),
                ("source", Json::Str(h.source.describe())),
            ];
            if let Some(spec) = &h.spec {
                pairs.push(("method", Json::Str(spec.method.as_str().to_string())));
                pairs.push(("stored_params", num(spec.stored_params() as f64)));
                pairs.push(("virtual_params", num(spec.virtual_params() as f64)));
                pairs.push(("compression", num(spec.compression())));
            }
            if let Some(v) = h.bundle_version {
                pairs.push(("bundle_version", num(v as f64)));
            }
            (h.name.clone(), obj(pairs))
        })
        .collect();
    obj(vec![
        ("default", Json::Str(ctx.registry.default_name())),
        ("models", Json::Obj(per.into_iter().collect())),
    ])
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Jitter source for [`Client::classify_retry`] backoff, seeded
    /// from the connection's ephemeral port so concurrent clients
    /// don't retry in lockstep (which would re-create the very
    /// overload spike they are backing off from).
    rng: Pcg32,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let seed = stream.local_addr().map(|a| a.port() as u64).unwrap_or(1);
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
            rng: Pcg32::new(seed, 0xB0FF),
        })
    }

    /// Bound how long [`Client::read_reply`] blocks on the socket
    /// (None = forever). Soak tests set this so a lost reply surfaces
    /// as a transport error instead of a hung test.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Classify against the server's default model.
    pub fn classify(&mut self, pixels: &[f32]) -> Result<(usize, Vec<f32>, u64)> {
        self.classify_model(None, pixels)
    }

    /// Classify against a named model (None = server default).
    pub fn classify_model(
        &mut self,
        model: Option<&str>,
        pixels: &[f32],
    ) -> Result<(usize, Vec<f32>, u64)> {
        let v = self.classify_raw(model, pixels, None)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok((
            v.req_f64("class").map_err(|e| anyhow!(e))? as usize,
            v.req_arr("probs")
                .map_err(|e| anyhow!(e))?
                .iter()
                .filter_map(|p| p.as_f64())
                .map(|p| p as f32)
                .collect(),
            v.req_f64("latency_us").map_err(|e| anyhow!(e))? as u64,
        ))
    }

    /// One classify round trip returning the raw reply object —
    /// `Err` only on transport/parse failure, so callers (the soak
    /// test's exactly-one-explicit-reply tally) can distinguish
    /// a served `"class"` from each typed `"code"`.
    pub fn classify_raw(
        &mut self,
        model: Option<&str>,
        pixels: &[f32],
        timeout_ms: Option<u64>,
    ) -> Result<Json> {
        let arr = Json::Arr(pixels.iter().map(|&p| num(p as f64)).collect());
        let mut pairs = vec![("pixels", arr)];
        if let Some(m) = model {
            pairs.push(("model", Json::Str(m.to_string())));
        }
        if let Some(ms) = timeout_ms {
            pairs.push(("timeout_ms", num(ms as f64)));
        }
        writeln!(self.writer, "{}", obj(pairs).to_string())?;
        self.read_reply()
    }

    /// One sparse (embedding-bag) classify round trip: sends
    /// `{"indices": [...], "offsets": [...]}` and returns the raw
    /// reply — `"bags"`/`"values"` on success, a typed `"code"` on
    /// failure. `Err` only on transport/parse problems.
    pub fn classify_sparse_raw(
        &mut self,
        model: Option<&str>,
        indices: &[u32],
        offsets: &[u32],
        timeout_ms: Option<u64>,
    ) -> Result<Json> {
        let mut pairs = vec![
            ("indices", Json::Arr(indices.iter().map(|&i| num(i as f64)).collect())),
            ("offsets", Json::Arr(offsets.iter().map(|&o| num(o as f64)).collect())),
        ];
        if let Some(m) = model {
            pairs.push(("model", Json::Str(m.to_string())));
        }
        if let Some(ms) = timeout_ms {
            pairs.push(("timeout_ms", num(ms as f64)));
        }
        writeln!(self.writer, "{}", obj(pairs).to_string())?;
        self.read_reply()
    }

    /// [`Client::classify_raw`] with jittered exponential backoff on
    /// `"overloaded"` rejections: waits a uniform-random slice of the
    /// current window (full jitter), doubling the window each attempt
    /// starting from the server's `retry_after_ms` hint, capped at 1 s.
    /// Any other reply — success or typed error — returns immediately;
    /// retrying a deadline or engine failure would just double charge
    /// the model. Returns the last reply after `max_attempts`.
    pub fn classify_retry(
        &mut self,
        model: Option<&str>,
        pixels: &[f32],
        timeout_ms: Option<u64>,
        max_attempts: u32,
    ) -> Result<Json> {
        let mut attempt = 0u32;
        loop {
            let v = self.classify_raw(model, pixels, timeout_ms)?;
            attempt += 1;
            let overloaded =
                v.get("code").and_then(Json::as_str).map(|c| c == "overloaded").unwrap_or(false);
            if !overloaded || attempt >= max_attempts {
                return Ok(v);
            }
            let hint = v
                .get("retry_after_ms")
                .and_then(Json::as_f64)
                .map(|ms| ms.max(1.0) as u64)
                .unwrap_or(10);
            let window = hint.saturating_mul(1u64 << (attempt - 1).min(10)).clamp(1, 1000);
            let jittered = 1 + (self.rng.next_f64() * window as f64) as u64;
            std::thread::sleep(Duration::from_millis(jittered));
        }
    }

    /// Fetch the `{"cmd":"health"}` liveness report.
    pub fn health(&mut self) -> Result<Json> {
        writeln!(
            self.writer,
            "{}",
            obj(vec![("cmd", Json::Str("health".into()))]).to_string()
        )?;
        self.read_reply()
    }

    /// Send one admin command object and return the parsed reply
    /// (turned into `Err` when the server reports `"error"`).
    pub fn admin(&mut self, cmd: Json) -> Result<Json> {
        writeln!(self.writer, "{}", cmd.to_string())?;
        let v = self.read_reply()?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(v)
    }

    /// Hot-load a bundle file into the running server.
    pub fn load_model(&mut self, path: &str) -> Result<Json> {
        self.admin(obj(vec![
            ("cmd", Json::Str("load".into())),
            ("path", Json::Str(path.to_string())),
        ]))
    }

    /// Remove a served model.
    pub fn unload_model(&mut self, name: &str) -> Result<Json> {
        self.admin(obj(vec![
            ("cmd", Json::Str("unload".into())),
            ("model", Json::Str(name.to_string())),
        ]))
    }

    /// Rebuild every served model from its source file(s).
    pub fn reload(&mut self) -> Result<Json> {
        self.admin(obj(vec![("cmd", Json::Str("reload".into()))]))
    }

    /// Fetch the registry metadata (`{"cmd":"models"}`).
    pub fn models(&mut self) -> Result<Json> {
        self.admin(obj(vec![("cmd", Json::Str("models".into()))]))
    }

    /// Fetch the server's `stats` object.
    pub fn stats(&mut self) -> Result<Json> {
        writeln!(
            self.writer,
            "{}",
            obj(vec![("cmd", Json::Str("stats".into()))]).to_string()
        )?;
        self.read_reply()
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(
            self.writer,
            "{}",
            obj(vec![("cmd", Json::Str("shutdown".into()))]).to_string()
        )?;
        let mut line = String::new();
        // Propagate a failed acknowledgement read: the old version
        // swallowed it, so a server that died mid-shutdown (or a
        // half-closed socket) looked like a clean stop to callers.
        self.reader.read_line(&mut line)?;
        Ok(())
    }

    fn read_reply(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(anyhow!("server closed the connection"));
        }
        Json::parse(&line).map_err(|e| anyhow!("reply: {e}"))
    }
}
