//! TCP front end: newline-delimited JSON over std::net.
//!
//! Protocol (one JSON object per line):
//!   request:  {"pixels": [f32; n_in]}            → classify
//!             {"cmd": "stats"}                   → server counters
//!             {"cmd": "shutdown"}                → stop accepting
//!   response: {"class": u, "probs": [...], "latency_us": u}
//!             {"error": "..."}
//!
//! One model thread owns the PJRT executable and drains the dynamic
//! batcher; connection threads parse requests and block on replies.

use super::batcher::{BatcherHandle, DynamicBatcher};
use crate::runtime::{Graph, ModelState, Runtime};
use crate::util::json::{num, obj, Json};
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub artifacts_dir: PathBuf,
    pub artifact: String,
    pub checkpoint: Option<PathBuf>,
    pub addr: String,
    pub max_wait: Duration,
    /// Stop after serving this many classify requests (0 = run forever).
    /// Used by tests and the examples.
    pub max_requests: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            artifacts_dir: "artifacts".into(),
            artifact: String::new(),
            checkpoint: None,
            addr: "127.0.0.1:7878".into(),
            max_wait: Duration::from_millis(2),
            max_requests: 0,
        }
    }
}

/// Run the server; returns once shut down (via `{"cmd":"shutdown"}` or
/// `max_requests`). Prints the bound address — pass port 0 to pick one.
pub fn serve(opt: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(&opt.addr)?;
    let local = listener.local_addr()?;
    println!("serving {} on {local}", opt.artifact);
    listener.set_nonblocking(true)?;

    let stop = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicU64::new(0));

    // ---- model thread -------------------------------------------------
    // PJRT handles are not Send, so the model thread owns its own
    // Runtime; the manifest is read here only for shapes.
    let manifest = crate::runtime::Manifest::load(&opt.artifacts_dir.join("manifest.json"))?;
    let spec = manifest
        .get(&opt.artifact)
        .ok_or_else(|| anyhow!("unknown artifact '{}'", opt.artifact))?
        .clone();
    let n_in = spec.dims[0];
    let mut batcher = DynamicBatcher::new(spec.batch, opt.max_wait);
    let handle = batcher.handle();
    let stop_model = stop.clone();
    let opt_model = opt.clone();
    let spec_model = spec.clone();
    let model = std::thread::spawn(move || -> Result<super::batcher::BatchStats> {
        let rt = Runtime::open(&opt_model.artifacts_dir)?;
        let exe = rt.load(&opt_model.artifact, Graph::Predict)?;
        let state = match &opt_model.checkpoint {
            Some(p) => ModelState::load(p)?,
            None => ModelState::init(&spec_model, 0x5EED),
        };
        if state.params.len() != spec_model.params.len() {
            return Err(anyhow!("checkpoint does not match artifact"));
        }
        while !stop_model.load(Ordering::Relaxed) {
            if let Some(batch) = batcher.next_batch(Duration::from_millis(20)) {
                batcher.dispatch(batch, n_in, |x| exe.predict(&state, x));
            }
        }
        Ok(batcher.stats)
    });

    // ---- accept loop --------------------------------------------------
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let h = handle.clone();
                let stop_c = stop.clone();
                let served_c = served.clone();
                let max_req = opt.max_requests;
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, h, &stop_c, &served_c, max_req);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
                if opt.max_requests > 0 && served.load(Ordering::Relaxed) >= opt.max_requests {
                    stop.store(true, Ordering::Relaxed);
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    let stats = model.join().expect("model thread")?;
    println!(
        "served {} requests in {} batches (mean fill {:.0}%)",
        stats.requests,
        stats.batches,
        100.0 * stats.mean_fill(spec.batch)
    );
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    batcher: BatcherHandle,
    stop: &AtomicBool,
    served: &AtomicU64,
    max_requests: u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
                    match cmd {
                        "shutdown" => {
                            stop.store(true, Ordering::Relaxed);
                            obj(vec![("ok", Json::Bool(true))])
                        }
                        "stats" => obj(vec![(
                            "served",
                            num(served.load(Ordering::Relaxed) as f64),
                        )]),
                        other => obj(vec![("error", Json::Str(format!("unknown cmd {other}")))]),
                    }
                } else if let Some(pixels) = req.get("pixels").and_then(Json::as_arr) {
                    let pixels: Vec<f32> =
                        pixels.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect();
                    let rx = batcher.submit(pixels);
                    match rx.recv_timeout(Duration::from_secs(10)) {
                        Ok(resp) => {
                            let n = served.fetch_add(1, Ordering::Relaxed) + 1;
                            if max_requests > 0 && n >= max_requests {
                                stop.store(true, Ordering::Relaxed);
                            }
                            obj(vec![
                                ("class", num(resp.class as f64)),
                                (
                                    "probs",
                                    Json::Arr(
                                        resp.probs.iter().map(|&p| num(p as f64)).collect(),
                                    ),
                                ),
                                ("latency_us", num(resp.latency_us as f64)),
                            ])
                        }
                        Err(_) => obj(vec![("error", Json::Str("model timeout".into()))]),
                    }
                } else {
                    obj(vec![("error", Json::Str("need pixels or cmd".into()))])
                }
            }
            Err(e) => obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
        };
        writeln!(writer, "{}", reply.to_string())?;
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn classify(&mut self, pixels: &[f32]) -> Result<(usize, Vec<f32>, u64)> {
        let arr = Json::Arr(pixels.iter().map(|&p| num(p as f64)).collect());
        writeln!(self.writer, "{}", obj(vec![("pixels", arr)]).to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = Json::parse(&line).map_err(|e| anyhow!("reply: {e}"))?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok((
            v.req_f64("class").map_err(|e| anyhow!(e))? as usize,
            v.req_arr("probs")
                .map_err(|e| anyhow!(e))?
                .iter()
                .filter_map(|p| p.as_f64())
                .map(|p| p as f32)
                .collect(),
            v.req_f64("latency_us").map_err(|e| anyhow!(e))? as u64,
        ))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", obj(vec![("cmd", Json::Str("shutdown".into()))]).to_string())?;
        let mut line = String::new();
        let _ = self.reader.read_line(&mut line);
        Ok(())
    }
}
