//! TCP front end: newline-delimited JSON over std::net.
//!
//! Protocol (one JSON object per line):
//!   request:  {"pixels": [f32; n_in]}              → classify (default model)
//!             {"model": "name", "pixels": [...]}   → classify a named model
//!             {"cmd": "stats"}                     → server + per-model counters
//!             {"cmd": "shutdown"}                  → stop accepting
//!   response: {"class": u, "probs": [...], "latency_us": u, "model": "name"}
//!             {"error": "..."}                     → bad request, wrong pixel
//!                                                    count, or engine failure
//!
//! One process serves **multiple named models** through an engine
//! registry (see [`super::engine`]): each model gets its own
//! [`DynamicBatcher`] plus worker threads — N threads sharing one
//! `NativeEngine`, or a single thread owning a PJRT `RuntimeEngine`.
//! Connection threads parse requests, validate the pixel count against
//! the routed model, and block on replies.
//!
//! [`Server::bind`] / [`Server::run`] split binding from serving so
//! callers can bind port 0 and read [`Server::local_addr`] before the
//! accept loop starts; [`serve`] is the one-call wrapper.

use super::batcher::DynamicBatcher;
use super::engine::{
    error_loop, load_state, worker_loop, Backend, InferenceEngine, ModelConfig, NativeEngine,
    RuntimeEngine,
};
use crate::runtime::{Manifest, Runtime};
use crate::util::json::{num, obj, Json};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub artifacts_dir: PathBuf,
    /// Models to serve; the first is the default for requests that
    /// carry no `"model"` field.
    pub models: Vec<ModelConfig>,
    pub addr: String,
    /// Execution backend; `Auto` prefers the PJRT runtime and falls
    /// back to native when artifact loading fails.
    pub backend: Backend,
    /// Worker threads per natively-served model (the runtime backend
    /// is always pinned to one worker — PJRT handles are not `Send`).
    pub workers: usize,
    pub max_wait: Duration,
    /// Stop after serving this many classify requests (0 = run forever).
    /// Used by tests and the examples.
    pub max_requests: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            artifacts_dir: "artifacts".into(),
            models: Vec::new(),
            addr: "127.0.0.1:7878".into(),
            backend: Backend::Auto,
            workers: 2,
            max_wait: Duration::from_millis(2),
            max_requests: 0,
        }
    }
}

impl ServeOptions {
    /// One model, default everything else.
    pub fn single(artifact: impl Into<String>) -> ServeOptions {
        ServeOptions { models: vec![ModelConfig::new(artifact)], ..Default::default() }
    }
}

/// One served model: its batcher (shared with the worker threads) and
/// request counters, looked up by name on every classify request.
struct ModelHandle {
    name: String,
    backend: &'static str,
    workers: usize,
    n_in: usize,
    max_batch: usize,
    batcher: DynamicBatcher,
    served: AtomicU64,
    errors: AtomicU64,
}

/// Immutable model registry shared by all connection threads.
struct Registry {
    models: BTreeMap<String, Arc<ModelHandle>>,
    default_model: String,
}

/// A bound server: workers are already running; [`Server::run`] enters
/// the accept loop. Returned by [`Server::bind`] so callers (tests,
/// benches) can bind port 0 and read the chosen address.
pub struct Server {
    listener: TcpListener,
    local: SocketAddr,
    registry: Arc<Registry>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    max_requests: u64,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind the listener, build one engine per configured model, and
    /// spawn the worker threads. Fails eagerly on a bad address, an
    /// unknown artifact, a checkpoint/spec mismatch, or (with
    /// `--backend runtime`) an unavailable PJRT runtime.
    pub fn bind(opt: ServeOptions) -> Result<Server> {
        Server::bind_with_engines(opt, Vec::new())
    }

    /// [`Server::bind`] plus pre-built engines (tests and benches
    /// inject custom [`InferenceEngine`]s — e.g. a failing one to
    /// exercise the error path). Custom engines are registered under
    /// their paired name and served like native models.
    pub fn bind_with_engines(
        opt: ServeOptions,
        custom: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&opt.addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut models: BTreeMap<String, Arc<ModelHandle>> = BTreeMap::new();
        match Server::build_registry(&opt, custom, &stop, &mut workers, &mut models) {
            Ok(default_model) => Ok(Server {
                listener,
                local,
                registry: Arc::new(Registry { models, default_model }),
                stop,
                served: Arc::new(AtomicU64::new(0)),
                max_requests: opt.max_requests,
                workers,
            }),
            Err(e) => {
                // don't leak worker threads spawned for earlier models
                stop.store(true, Ordering::Relaxed);
                for w in workers {
                    let _ = w.join();
                }
                Err(e)
            }
        }
    }

    /// Build every model's engine + batcher + workers; returns the
    /// default model name.
    fn build_registry(
        opt: &ServeOptions,
        custom: Vec<(String, Arc<dyn InferenceEngine + Send + Sync>)>,
        stop: &Arc<AtomicBool>,
        workers: &mut Vec<std::thread::JoinHandle<()>>,
        models: &mut BTreeMap<String, Arc<ModelHandle>>,
    ) -> Result<String> {
        let mut default_model = opt.models.first().map(|m| m.artifact.clone());

        for (name, eng) in custom {
            default_model.get_or_insert_with(|| name.clone());
            let handle =
                spawn_engine_workers(name.clone(), eng, opt.workers, opt.max_wait, stop, workers);
            if models.insert(name.clone(), handle).is_some() {
                return Err(anyhow!("duplicate model name '{name}'"));
            }
        }

        if !opt.models.is_empty() {
            let manifest = Manifest::load(&opt.artifacts_dir.join("manifest.json"))?;
            // Probe the PJRT runtime once for all models that may want
            // it: can the client open, and do the predict graphs exist?
            // (Compile errors surface later, in the worker, as explicit
            // error replies.)
            let runtime_err = if matches!(opt.backend, Backend::Runtime | Backend::Auto) {
                probe_runtime(opt, &manifest)
            } else {
                None
            };

            for mc in &opt.models {
                let spec = manifest
                    .get(&mc.artifact)
                    .ok_or_else(|| anyhow!("unknown artifact '{}'", mc.artifact))?
                    .clone();
                let use_runtime = match (opt.backend, &runtime_err) {
                    (Backend::Native, _) => false,
                    (Backend::Runtime, Some(e)) => {
                        return Err(anyhow!("--backend runtime unavailable: {e}"))
                    }
                    (Backend::Runtime, None) => true,
                    (Backend::Auto, Some(e)) => {
                        eprintln!(
                            "backend auto: runtime unavailable ({e}); serving '{}' natively",
                            mc.artifact
                        );
                        false
                    }
                    (Backend::Auto, None) => true,
                };
                let handle = if use_runtime {
                    // PJRT handles are not Send: the engine is built
                    // inside its (single) worker thread.
                    let batcher = DynamicBatcher::new(spec.batch.max(1), opt.max_wait).padded();
                    let handle = Arc::new(ModelHandle {
                        name: mc.artifact.clone(),
                        backend: "runtime",
                        workers: 1,
                        n_in: spec.dims[0],
                        max_batch: spec.batch.max(1),
                        batcher: batcher.clone(),
                        served: AtomicU64::new(0),
                        errors: AtomicU64::new(0),
                    });
                    let stop_w = stop.clone();
                    let dir = opt.artifacts_dir.clone();
                    let artifact = mc.artifact.clone();
                    let ckpt = mc.checkpoint.clone();
                    let n_in = spec.dims[0];
                    workers.push(std::thread::spawn(move || {
                        match RuntimeEngine::open(&dir, &artifact, ckpt.as_deref()) {
                            Ok(eng) => worker_loop(&eng, &batcher, &stop_w),
                            Err(e) => {
                                let msg =
                                    format!("runtime backend for '{artifact}' failed: {e:#}");
                                eprintln!("{msg}");
                                error_loop(&msg, n_in, &batcher, &stop_w);
                            }
                        }
                    }));
                    handle
                } else {
                    let state = load_state(&spec, mc.checkpoint.as_deref())?;
                    let eng: Arc<dyn InferenceEngine + Send + Sync> =
                        Arc::new(NativeEngine::from_spec(&spec, &state)?);
                    spawn_engine_workers(
                        mc.artifact.clone(),
                        eng,
                        opt.workers,
                        opt.max_wait,
                        stop,
                        workers,
                    )
                };
                // a duplicate would orphan the first entry's workers
                // and batcher while stats silently showed only one
                if models.insert(mc.artifact.clone(), handle).is_some() {
                    return Err(anyhow!("duplicate model name '{}'", mc.artifact));
                }
            }
        }

        default_model.ok_or_else(|| anyhow!("no models configured"))
    }

    /// The bound address — pass port 0 to `ServeOptions::addr` and read
    /// the picked port here.
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Accept loop; returns once shut down (via `{"cmd":"shutdown"}` or
    /// `max_requests`). Finished connection threads are reaped every
    /// iteration so a long-running server holds one handle per *live*
    /// connection, not per connection ever accepted.
    pub fn run(mut self) -> Result<()> {
        let names: Vec<&str> = self.registry.models.keys().map(String::as_str).collect();
        println!("serving [{}] on {}", names.join(", "), self.local);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut result = Ok(());
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let reg = self.registry.clone();
                    let stop_c = self.stop.clone();
                    let served_c = self.served.clone();
                    let max_req = self.max_requests;
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, &reg, &stop_c, &served_c, max_req);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                    if self.max_requests > 0
                        && self.served.load(Ordering::Relaxed) >= self.max_requests
                    {
                        self.stop.store(true, Ordering::Relaxed);
                    }
                }
                // fall through to the shutdown sequence below so worker
                // and connection threads are never leaked
                Err(e) => {
                    result = Err(e.into());
                    break;
                }
            }
            let mut i = 0;
            while i < conns.len() {
                if conns[i].is_finished() {
                    let _ = conns.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
        }
        // Shutdown: stop the workers first (they exit within one idle
        // poll), then fail queued requests fast until every connection
        // thread has exited — a request can still slip into a queue
        // after a drain pass, so drain and reap in a loop.
        self.stop.store(true, Ordering::Relaxed);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        while !conns.is_empty() {
            for h in self.registry.models.values() {
                let pending = h.batcher.drain_pending();
                if !pending.is_empty() {
                    h.batcher.dispatch(pending, h.n_in, |_| Err(anyhow!("server shutting down")));
                }
            }
            let mut i = 0;
            while i < conns.len() {
                if conns[i].is_finished() {
                    let _ = conns.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            if !conns.is_empty() {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        for (name, h) in &self.registry.models {
            let s = h.batcher.stats();
            println!(
                "{name} [{} x{}]: {} served / {} errors in {} batches (mean fill {:.0}%)",
                h.backend,
                h.workers,
                h.served.load(Ordering::Relaxed),
                h.errors.load(Ordering::Relaxed),
                s.batches,
                100.0 * s.mean_fill(h.max_batch)
            );
        }
        result
    }
}

/// Run the server; returns once shut down. Prints the bound address —
/// pass port 0 to pick one (or use [`Server::bind`] to read it back).
pub fn serve(opt: ServeOptions) -> Result<()> {
    Server::bind(opt)?.run()
}

/// Register a model handle and start `n_workers` threads sharing one
/// engine and one batcher — the native multi-worker path (also used
/// for injected custom engines).
fn spawn_engine_workers(
    name: String,
    eng: Arc<dyn InferenceEngine + Send + Sync>,
    n_workers: usize,
    max_wait: Duration,
    stop: &Arc<AtomicBool>,
    workers: &mut Vec<std::thread::JoinHandle<()>>,
) -> Arc<ModelHandle> {
    let n_workers = n_workers.max(1);
    let mut batcher = DynamicBatcher::new(eng.max_batch(), max_wait);
    if eng.fixed_batch() {
        batcher = batcher.padded();
    }
    let handle = Arc::new(ModelHandle {
        name,
        backend: eng.name(),
        workers: n_workers,
        n_in: eng.n_in(),
        max_batch: eng.max_batch(),
        batcher: batcher.clone(),
        served: AtomicU64::new(0),
        errors: AtomicU64::new(0),
    });
    for _ in 0..n_workers {
        let eng = eng.clone();
        let b = batcher.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || worker_loop(&*eng, &b, &stop)));
    }
    handle
}

/// PJRT availability probe for `Backend::Runtime` / `Backend::Auto`:
/// returns `Some(reason)` when the runtime cannot serve `opt.models`.
fn probe_runtime(opt: &ServeOptions, manifest: &Manifest) -> Option<String> {
    if let Err(e) = Runtime::open(&opt.artifacts_dir) {
        return Some(format!("{e:#}"));
    }
    for mc in &opt.models {
        let spec = manifest.get(&mc.artifact)?; // unknown artifact: reported later
        let hlo = opt.artifacts_dir.join(&spec.graphs.1);
        if !hlo.exists() {
            return Some(format!("missing predict graph {}", hlo.display()));
        }
    }
    None
}

fn handle_conn(
    stream: TcpStream,
    reg: &Registry,
    stop: &AtomicBool,
    served: &AtomicU64,
    max_requests: u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Bounded reads so an idle connection re-checks the stop flag a few
    // times a second — otherwise a silent client would block this
    // thread in read() forever and stall the server's shutdown.
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client disconnected
            Ok(_) => {
                if !line.trim().is_empty() {
                    let reply = match Json::parse(&line) {
                        Ok(req) => handle_request(&req, reg, stop, served, max_requests),
                        Err(e) => obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
                    };
                    writeln!(writer, "{}", reply.to_string())?;
                }
                line.clear();
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            // read timeout: partially-read bytes stay appended to `line`
            // (read_line's documented behavior), so a slow writer still
            // gets its whole line on a later pass
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// One parsed request → one JSON reply.
fn handle_request(
    req: &Json,
    reg: &Registry,
    stop: &AtomicBool,
    served: &AtomicU64,
    max_requests: u64,
) -> Json {
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "shutdown" => {
                stop.store(true, Ordering::Relaxed);
                obj(vec![("ok", Json::Bool(true))])
            }
            "stats" => stats_json(reg, served),
            other => obj(vec![("error", Json::Str(format!("unknown cmd {other}")))]),
        };
    }
    let Some(pixels) = req.get("pixels").and_then(Json::as_arr) else {
        return obj(vec![("error", Json::Str("need pixels or cmd".into()))]);
    };
    let model_name = req
        .get("model")
        .and_then(Json::as_str)
        .unwrap_or(&reg.default_model);
    let Some(handle) = reg.models.get(model_name) else {
        return obj(vec![(
            "error",
            Json::Str(format!("unknown model '{model_name}'")),
        )]);
    };
    let pixels: Vec<f32> = pixels.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect();
    // Validate here, not in the batcher: a truncated input must fail
    // loudly instead of being zero-padded into a wrong classification.
    if pixels.len() != handle.n_in {
        handle.errors.fetch_add(1, Ordering::Relaxed);
        return obj(vec![
            (
                "error",
                Json::Str(format!(
                    "model '{}' expects {} pixels, got {}",
                    handle.name,
                    handle.n_in,
                    pixels.len()
                )),
            ),
            ("model", Json::Str(handle.name.clone())),
        ]);
    }
    let rx = handle.batcher.handle().submit(pixels);
    match rx.recv_timeout(Duration::from_secs(10)) {
        Ok(resp) => {
            if let Some(err) = resp.error {
                handle.errors.fetch_add(1, Ordering::Relaxed);
                obj(vec![
                    ("error", Json::Str(err)),
                    ("model", Json::Str(handle.name.clone())),
                ])
            } else {
                handle.served.fetch_add(1, Ordering::Relaxed);
                // the global counter (and the max_requests stop trigger)
                // tracks successful classifications only, matching the
                // per-model counters
                let n = served.fetch_add(1, Ordering::Relaxed) + 1;
                if max_requests > 0 && n >= max_requests {
                    stop.store(true, Ordering::Relaxed);
                }
                obj(vec![
                    ("class", num(resp.class as f64)),
                    (
                        "probs",
                        Json::Arr(resp.probs.iter().map(|&p| num(p as f64)).collect()),
                    ),
                    ("latency_us", num(resp.latency_us as f64)),
                    ("model", Json::Str(handle.name.clone())),
                ])
            }
        }
        Err(_) => {
            handle.errors.fetch_add(1, Ordering::Relaxed);
            obj(vec![("error", Json::Str("model timeout".into()))])
        }
    }
}

/// `{"cmd":"stats"}` reply: total successful classifications plus
/// per-model backend, worker count, served/error counters and batch
/// fill (top-level `served` == sum of per-model `served`).
fn stats_json(reg: &Registry, served: &AtomicU64) -> Json {
    let per: Vec<(&str, Json)> = reg
        .models
        .iter()
        .map(|(name, h)| {
            let s = h.batcher.stats();
            (
                name.as_str(),
                obj(vec![
                    ("backend", Json::Str(h.backend.to_string())),
                    ("workers", num(h.workers as f64)),
                    ("served", num(h.served.load(Ordering::Relaxed) as f64)),
                    ("errors", num(h.errors.load(Ordering::Relaxed) as f64)),
                    ("batches", num(s.batches as f64)),
                    ("mean_fill", num(s.mean_fill(h.max_batch))),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("served", num(served.load(Ordering::Relaxed) as f64)),
        ("models", obj(per)),
    ])
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    /// Classify against the server's default model.
    pub fn classify(&mut self, pixels: &[f32]) -> Result<(usize, Vec<f32>, u64)> {
        self.classify_model(None, pixels)
    }

    /// Classify against a named model (None = server default).
    pub fn classify_model(
        &mut self,
        model: Option<&str>,
        pixels: &[f32],
    ) -> Result<(usize, Vec<f32>, u64)> {
        let arr = Json::Arr(pixels.iter().map(|&p| num(p as f64)).collect());
        let mut pairs = vec![("pixels", arr)];
        if let Some(m) = model {
            pairs.push(("model", Json::Str(m.to_string())));
        }
        writeln!(self.writer, "{}", obj(pairs).to_string())?;
        let v = self.read_reply()?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok((
            v.req_f64("class").map_err(|e| anyhow!(e))? as usize,
            v.req_arr("probs")
                .map_err(|e| anyhow!(e))?
                .iter()
                .filter_map(|p| p.as_f64())
                .map(|p| p as f32)
                .collect(),
            v.req_f64("latency_us").map_err(|e| anyhow!(e))? as u64,
        ))
    }

    /// Fetch the server's `stats` object.
    pub fn stats(&mut self) -> Result<Json> {
        writeln!(
            self.writer,
            "{}",
            obj(vec![("cmd", Json::Str("stats".into()))]).to_string()
        )?;
        self.read_reply()
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(
            self.writer,
            "{}",
            obj(vec![("cmd", Json::Str("shutdown".into()))]).to_string()
        )?;
        let mut line = String::new();
        let _ = self.reader.read_line(&mut line);
        Ok(())
    }

    fn read_reply(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(anyhow!("server closed the connection"));
        }
        Json::parse(&line).map_err(|e| anyhow!("reply: {e}"))
    }
}
