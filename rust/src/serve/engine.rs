//! Backend abstraction for serving: the [`InferenceEngine`] trait and
//! its two implementations.
//!
//! The paper's deployment story (a HashedNet is small enough to serve
//! anywhere) should not depend on *how* the forward pass executes, so
//! the server talks to engines, not runtimes:
//!
//! * [`NativeEngine`] — wraps an [`Arc<Network>`] built from the same
//!   `ArtifactSpec` + `ModelState` an artifact uses (see
//!   `coordinator::native`). It is `Send + Sync` — hashed layers read a
//!   shared immutable `HashPlan` — so the server runs **N worker
//!   threads draining one batcher against one model**, no locks, no
//!   parameter clones.
//! * [`RuntimeEngine`] — the PJRT artifact path. PJRT handles are not
//!   `Send`, so a runtime engine is constructed *inside* its single
//!   worker thread and never crosses threads; its executor requires
//!   fixed-shape batches ([`InferenceEngine::fixed_batch`]).
//!
//! Backend selection is a [`Backend`] value threaded through
//! `ServeOptions`: `native`, `runtime`, or `auto` (prefer the artifact
//! runtime, fall back to native when artifact loading fails — e.g. the
//! offline `xla` stub is linked or the HLO files are absent).

use crate::model::{BundleMap, ModelBundle};
use crate::nn::{EmbedBag, Network};
use crate::runtime::{Graph, ModelState, Runtime};
use crate::tensor::Matrix;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Which execution backend serves a model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// In-process `nn::Network` (HashPlan kernels), multi-worker.
    Native,
    /// PJRT artifact executable, single worker.
    Runtime,
    /// Prefer `Runtime`, fall back to `Native` if artifact loading fails.
    Auto,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "native" => Some(Backend::Native),
            "runtime" => Some(Backend::Runtime),
            "auto" => Some(Backend::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Runtime => "runtime",
            Backend::Auto => "auto",
        }
    }
}

/// A model that can classify batches: the contract between the serving
/// front end and any execution backend.
pub trait InferenceEngine {
    /// Forward pass: `(rows × n_in)` → `(rows × n_out)` logits.
    fn predict(&self, x: &Matrix) -> Result<Matrix>;
    /// Input width the engine expects.
    fn n_in(&self) -> usize;
    /// Logit width the engine produces.
    fn n_out(&self) -> usize;
    /// Largest (or, for fixed-shape engines, the exact) batch size.
    fn max_batch(&self) -> usize;
    /// Backend name for stats/logs (e.g. "native", "runtime").
    fn name(&self) -> &'static str;
    /// True when `predict` requires exactly `max_batch` rows (the
    /// batcher then zero-pads partial batches).
    fn fixed_batch(&self) -> bool {
        false
    }
    /// True when this engine consumes CSR bag requests
    /// (`indices` + `offsets`) instead of dense rows; the worker then
    /// drains the batcher through
    /// [`super::batcher::DynamicBatcher::dispatch_sparse`] and
    /// [`InferenceEngine::predict_sparse`]. For sparse engines,
    /// [`InferenceEngine::n_in`] is the valid index range
    /// (`num_categories`) and [`InferenceEngine::n_out`] the embedding
    /// width.
    fn sparse_input(&self) -> bool {
        false
    }
    /// Bag lookup: `(indices, offsets)` → `(n_bags × n_out)` values.
    /// Only meaningful when [`InferenceEngine::sparse_input`] is true.
    fn predict_sparse(&self, _indices: &[u32], _offsets: &[u32]) -> Result<Matrix> {
        Err(anyhow!("engine '{}' does not serve sparse requests", self.name()))
    }
}

/// What a [`NativeEngine`] wraps: the repo's two first-class model
/// shapes. Both are immutable once constructed and `Send + Sync`, so
/// either serves any number of worker threads.
enum NativeModel {
    /// Feed-forward classifier (dense rows in, logits out).
    Net(Arc<Network>),
    /// Hashed embedding table (CSR bags in, bag vectors out).
    Embed(Arc<EmbedBag>),
}

/// The native in-process engine: one shared [`Network`] or
/// [`EmbedBag`].
///
/// `Network::predict`/`EmbedBag::forward` take `&self` and share only
/// immutable state, so one `NativeEngine` serves any number of worker
/// threads concurrently.
pub struct NativeEngine {
    model: NativeModel,
    n_in: usize,
    n_out: usize,
    max_batch: usize,
}

impl NativeEngine {
    /// Build from a self-describing [`ModelBundle`] — the one
    /// construction path the server uses, whether the bundle came from
    /// a file (`{"cmd":"load"}`, `--bundle`) or from converting a
    /// manifest artifact + checkpoint. The bundle's spec picks the
    /// model shape: a `hashed_embedding` spec builds a sparse
    /// [`EmbedBag`] engine, everything else a dense [`Network`] engine.
    /// Shape validation happened when the bundle was built/loaded, so
    /// this cannot panic on bad params.
    pub fn from_bundle(bundle: &ModelBundle) -> Result<NativeEngine> {
        if bundle.spec.embedding_shape().is_some() {
            let bag = EmbedBag::from_bundle(bundle)
                .with_context(|| format!("building embedding engine for '{}'", bundle.spec.name))?;
            return Ok(NativeEngine::from_embed_bag(bag, bundle.spec.batch.max(1)));
        }
        let net = Network::from_bundle(bundle)
            .with_context(|| format!("building native engine for '{}'", bundle.spec.name))?;
        // pre-build the hashed layers' inverse plans here, at (hot-)load
        // time, so the first batch-1 request doesn't pay the build inline
        net.warm();
        Ok(NativeEngine {
            n_in: net.n_in(),
            n_out: net.n_out(),
            max_batch: bundle.spec.batch.max(1),
            model: NativeModel::Net(Arc::new(net)),
        })
    }

    /// [`NativeEngine::from_bundle`] over an mmap'd bundle: f32 tensors
    /// are served straight out of the page cache (no heap copy),
    /// quantized tensors dequantize once at load. This is the
    /// `{"cmd":"load"}` hot-swap path.
    pub fn from_bundle_map(map: &Arc<BundleMap>) -> Result<NativeEngine> {
        let (name, batch) = (map.spec().name.clone(), map.spec().batch.max(1));
        if map.spec().embedding_shape().is_some() {
            let bag = EmbedBag::from_bundle_map(map)
                .with_context(|| format!("building embedding engine for '{name}'"))?;
            return Ok(NativeEngine::from_embed_bag(bag, batch));
        }
        let net = Network::from_bundle_map(map)
            .with_context(|| format!("building native engine for '{name}'"))?;
        net.warm(); // see from_bundle
        Ok(NativeEngine {
            n_in: net.n_in(),
            n_out: net.n_out(),
            max_batch: batch,
            model: NativeModel::Net(Arc::new(net)),
        })
    }

    /// Wrap an existing network (tests).
    pub fn from_network(net: Network, max_batch: usize) -> NativeEngine {
        net.warm(); // see from_bundle
        NativeEngine {
            n_in: net.n_in(),
            n_out: net.n_out(),
            max_batch: max_batch.max(1),
            model: NativeModel::Net(Arc::new(net)),
        }
    }

    /// Wrap an existing embedding table. `n_in` reports the valid
    /// index range (`num_categories`) so the front end can range-check
    /// indices before admission; `n_out` reports the embedding width.
    pub fn from_embed_bag(bag: EmbedBag, max_batch: usize) -> NativeEngine {
        NativeEngine {
            n_in: bag.num_categories,
            n_out: bag.dim,
            max_batch: max_batch.max(1),
            model: NativeModel::Embed(Arc::new(bag)),
        }
    }

    /// The shared network (e.g. for asserting server replies in
    /// tests); None for embedding engines.
    pub fn network(&self) -> Option<&Arc<Network>> {
        match &self.model {
            NativeModel::Net(net) => Some(net),
            NativeModel::Embed(_) => None,
        }
    }

    /// The shared embedding table; None for feed-forward engines.
    pub fn embed_bag(&self) -> Option<&Arc<EmbedBag>> {
        match &self.model {
            NativeModel::Embed(bag) => Some(bag),
            NativeModel::Net(_) => None,
        }
    }
}

impl InferenceEngine for NativeEngine {
    fn predict(&self, x: &Matrix) -> Result<Matrix> {
        let NativeModel::Net(net) = &self.model else {
            return Err(anyhow!("embedding model expects sparse indices/offsets requests"));
        };
        if x.cols != self.n_in {
            return Err(anyhow!("expected {} input cols, got {}", self.n_in, x.cols));
        }
        Ok(net.predict(x))
    }

    fn n_in(&self) -> usize {
        self.n_in
    }

    fn n_out(&self) -> usize {
        self.n_out
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn sparse_input(&self) -> bool {
        matches!(self.model, NativeModel::Embed(_))
    }

    fn predict_sparse(&self, indices: &[u32], offsets: &[u32]) -> Result<Matrix> {
        let NativeModel::Embed(bag) = &self.model else {
            return Err(anyhow!("dense model expects pixel-row requests"));
        };
        // the front end validates per request before admission; this
        // re-check guards direct/CLI callers with a typed error rather
        // than an out-of-bounds panic inside the kernel
        bag.validate_bags(indices, offsets).map_err(|why| anyhow!("bad bag request: {why}"))?;
        Ok(bag.forward(indices, offsets))
    }
}

/// The PJRT artifact engine. Owns its `Runtime` (not `Send` — construct
/// and use it on one worker thread only).
pub struct RuntimeEngine {
    _rt: Runtime,
    exe: crate::runtime::Executable,
    state: ModelState,
}

impl RuntimeEngine {
    /// Open the artifact runtime and load one predict graph. The
    /// parameters resolve through the bundle path (`checkpoint` may be
    /// a legacy `.ckpt` or a `.hnb` bundle; absent → seed init) —
    /// identical to what [`NativeEngine::from_bundle`] would serve.
    pub fn open(
        artifacts_dir: &Path,
        artifact: &str,
        checkpoint: Option<&Path>,
    ) -> Result<RuntimeEngine> {
        let rt = Runtime::open(artifacts_dir)?;
        let exe = rt.load(artifact, Graph::Predict)?;
        let bundle = exe.spec.resolve_bundle(checkpoint, 0x5EED)?;
        let state = ModelState::from_bundle(&bundle);
        Ok(RuntimeEngine { _rt: rt, exe, state })
    }
}

impl InferenceEngine for RuntimeEngine {
    fn predict(&self, x: &Matrix) -> Result<Matrix> {
        self.exe.predict(&self.state, x)
    }

    fn n_in(&self) -> usize {
        self.exe.n_in()
    }

    fn n_out(&self) -> usize {
        self.exe.n_out()
    }

    fn max_batch(&self) -> usize {
        self.exe.batch()
    }

    fn name(&self) -> &'static str {
        "runtime"
    }

    fn fixed_batch(&self) -> bool {
        true
    }
}

/// Drain `batcher` through `engine` until `stop` is set — the body of
/// every serving worker thread, shared by all backends.
///
/// Fault containment is two layers deep: `dispatch` itself catches a
/// panicking `predict` and fails that batch with an explicit reply,
/// and this loop additionally catches anything that escapes an
/// iteration (e.g. an engine whose *metadata* methods panic), so a
/// worker thread never dies while `stop` is unset — it logs, backs off
/// a beat, and keeps draining.
pub fn worker_loop(
    engine: &dyn InferenceEngine,
    batcher: &super::batcher::DynamicBatcher,
    stop: &AtomicBool,
) {
    let n_in = engine.n_in();
    let sparse = engine.sparse_input();
    while !stop.load(Ordering::Relaxed) {
        let iteration = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Some(batch) = batcher.next_batch(Duration::from_millis(20)) {
                if sparse {
                    batcher.dispatch_sparse(batch, |i, o| engine.predict_sparse(i, o));
                } else {
                    batcher.dispatch(batch, n_in, |x| engine.predict(x));
                }
            }
        }));
        if iteration.is_err() {
            eprintln!("serve: worker iteration panicked for engine '{}'; worker continues", engine.name());
            // avoid a hot spin if the panic source is persistent
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// Drain `batcher` replying `error` to everything — used when a worker's
/// engine failed to construct, so queued clients fail fast instead of
/// timing out.
pub fn error_loop(
    error: &str,
    n_in: usize,
    batcher: &super::batcher::DynamicBatcher,
    stop: &AtomicBool,
) {
    while !stop.load(Ordering::Relaxed) {
        if let Some(batch) = batcher.next_batch(Duration::from_millis(20)) {
            batcher.dispatch(batch, n_in, |_| Err(anyhow!("{error}")));
        }
    }
}

/// How one model should be served. Two sources:
///
/// * a **bundle file** ([`ModelConfig::bundle`]) — fully
///   self-describing, served natively, no manifest required;
/// * a **manifest artifact** ([`ModelConfig::new`]) with an optional
///   checkpoint/bundle parameter file — the compat path, also the only
///   way onto the PJRT runtime backend (which needs the HLO graphs).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Manifest artifact name (empty for bundle-sourced models — the
    /// registry name then comes from the bundle's spec).
    pub artifact: String,
    /// Parameter file for a manifest artifact (legacy `.ckpt` or
    /// `.hnb`); absent → deterministic seed init.
    pub checkpoint: Option<PathBuf>,
    /// Bundle file to serve directly.
    pub bundle: Option<PathBuf>,
}

impl ModelConfig {
    /// Serve a manifest artifact (seed-initialized unless
    /// [`ModelConfig::with_checkpoint`] adds parameters).
    pub fn new(artifact: impl Into<String>) -> ModelConfig {
        ModelConfig { artifact: artifact.into(), checkpoint: None, bundle: None }
    }

    pub fn with_checkpoint(mut self, ckpt: impl Into<PathBuf>) -> ModelConfig {
        self.checkpoint = Some(ckpt.into());
        self
    }

    /// Serve a self-describing bundle file.
    pub fn bundle(path: impl Into<PathBuf>) -> ModelConfig {
        ModelConfig { artifact: String::new(), checkpoint: None, bundle: Some(path.into()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerKind;
    use crate::util::rng::Pcg32;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn native_engine_is_send_sync() {
        // the whole multi-worker design rests on this bound
        assert_send_sync::<NativeEngine>();
    }

    fn tiny_net() -> Network {
        let mut net = Network::from_dims(
            &[6, 5, 3],
            vec![LayerKind::Hashed { k: 12 }, LayerKind::Dense],
            crate::hash::DEFAULT_SEED_BASE,
        );
        net.init(&mut Pcg32::new(9, 9));
        net
    }

    #[test]
    fn native_engine_matches_direct_predict() {
        let net = tiny_net();
        let x = Matrix::from_fn(4, 6, |i, j| (i * 6 + j) as f32 * 0.1);
        let want = net.predict(&x);
        let eng = NativeEngine::from_network(net, 8);
        assert_eq!(eng.n_in(), 6);
        assert_eq!(eng.n_out(), 3);
        assert_eq!(eng.max_batch(), 8);
        assert_eq!(eng.name(), "native");
        assert!(!eng.fixed_batch());
        let got = eng.predict(&x).unwrap();
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn native_engine_from_bundle_matches_network() {
        let spec = crate::model::ModelSpec::new(
            "tiny",
            crate::model::Method::Hashnet,
            vec![6, 5, 3],
            vec![12, 18],
            crate::hash::DEFAULT_SEED_BASE,
            8,
        )
        .unwrap();
        let mut hnet = Network::from_spec(&spec).unwrap();
        hnet.init(&mut Pcg32::new(4, 4));
        let x = Matrix::from_fn(3, 6, |i, j| (i + 2 * j) as f32 * 0.2);
        let want = hnet.predict(&x);
        let bundle = hnet.to_bundle(&spec).unwrap();
        let eng = NativeEngine::from_bundle(&bundle).unwrap();
        assert_eq!(eng.max_batch(), 8);
        assert_eq!(eng.predict(&x).unwrap().data, want.data);
    }

    #[test]
    fn embedding_engine_serves_sparse_and_rejects_dense() {
        let mut bag = EmbedBag::new(1_000, 8, 64, crate::model::BagMode::Sum, 7);
        bag.init(&mut Pcg32::new(2, 2));
        let want = bag.forward(&[1, 2, 999], &[0, 2]);
        let eng = NativeEngine::from_embed_bag(bag, 16);
        assert!(eng.sparse_input());
        assert_eq!(eng.n_in(), 1_000); // index range, for front-end checks
        assert_eq!(eng.n_out(), 8);
        let got = eng.predict_sparse(&[1, 2, 999], &[0, 2]).unwrap();
        assert_eq!(got.data, want.data);
        // dense rows are a typed error, not a panic
        assert!(eng.predict(&Matrix::zeros(1, 1_000)).is_err());
        // out-of-range index is a typed error from the engine re-check
        assert!(eng.predict_sparse(&[1_000], &[0]).is_err());
        // and the dense engine rejects sparse
        let dense = NativeEngine::from_network(tiny_net(), 8);
        assert!(!dense.sparse_input());
        assert!(dense.predict_sparse(&[0], &[0]).is_err());
    }

    #[test]
    fn worker_loop_serves_sparse_batches() {
        let mut bag = EmbedBag::new(100, 4, 32, crate::model::BagMode::Sum, 7);
        bag.init(&mut Pcg32::new(3, 3));
        let want = bag.forward(&[5, 6], &[0]);
        let eng = NativeEngine::from_embed_bag(bag, 16);
        let batcher =
            super::super::batcher::DynamicBatcher::new(16, Duration::from_millis(1));
        let handle = batcher.handle();
        let stop = Arc::new(AtomicBool::new(false));
        let worker = {
            let b = batcher.clone();
            let stop = stop.clone();
            std::thread::spawn(move || worker_loop(&eng, &b, &stop))
        };
        let rx = handle.submit_sparse(vec![5, 6], vec![0]);
        let r = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.class, 1); // bag count
        assert_eq!(r.probs, want.data);
        stop.store(true, Ordering::Relaxed);
        worker.join().unwrap();
    }

    #[test]
    fn native_engine_rejects_wrong_width() {
        let eng = NativeEngine::from_network(tiny_net(), 8);
        let x = Matrix::zeros(2, 5); // n_in is 6
        assert!(eng.predict(&x).is_err());
    }

    #[test]
    fn backend_parse_roundtrip() {
        for b in [Backend::Native, Backend::Runtime, Backend::Auto] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("gpu"), None);
    }

    /// Panics on every `predict` — the engine a chaos monkey would ship.
    struct PanicEngine;

    impl InferenceEngine for PanicEngine {
        fn predict(&self, _x: &Matrix) -> Result<Matrix> {
            panic!("injected engine panic");
        }
        fn n_in(&self) -> usize {
            6
        }
        fn n_out(&self) -> usize {
            3
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn name(&self) -> &'static str {
            "panic"
        }
    }

    #[test]
    fn worker_survives_panicking_engine() {
        // the resilience contract: a panicking predict fails its batch
        // with an explicit error reply and the same worker keeps
        // serving — it must answer a *second* request after the panic.
        let batcher = super::super::batcher::DynamicBatcher::new(4, Duration::from_millis(1));
        let handle = batcher.handle();
        let stop = Arc::new(AtomicBool::new(false));
        let worker = {
            let b = batcher.clone();
            let stop = stop.clone();
            std::thread::spawn(move || worker_loop(&PanicEngine, &b, &stop))
        };
        for attempt in 0..2 {
            let rx = handle.submit(vec![0.1; 6]);
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("explicit reply, not a hang");
            let err = r.error.expect("error field set");
            assert_eq!(err.code(), "engine", "attempt {attempt}");
            assert!(err.to_string().contains("injected engine panic"), "attempt {attempt}: {err}");
        }
        assert_eq!(batcher.stats().panics, 2);
        stop.store(true, Ordering::Relaxed);
        worker.join().unwrap(); // worker thread itself never panicked out
    }

    #[test]
    fn worker_loop_serves_until_stopped() {
        let eng = NativeEngine::from_network(tiny_net(), 8);
        let batcher = super::super::batcher::DynamicBatcher::new(4, Duration::from_millis(1));
        let handle = batcher.handle();
        let stop = Arc::new(AtomicBool::new(false));
        let worker = {
            let b = batcher.clone();
            let stop = stop.clone();
            std::thread::spawn(move || worker_loop(&eng, &b, &stop))
        };
        let rx = handle.submit(vec![0.1; 6]);
        let r = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.probs.len(), 3);
        stop.store(true, Ordering::Relaxed);
        worker.join().unwrap();
    }
}
