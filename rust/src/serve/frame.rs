//! Length-prefixed binary wire protocol for the serve front end.
//!
//! JSON (one object per line) remains the compatibility protocol; this
//! module adds a binary framing that removes the two hot-path costs the
//! JSON path pays per request — float *text* parsing on the way in and
//! float formatting on the way out. Pixels and probabilities travel as
//! raw little-endian `f32`, so encode/decode is a bounds check plus a
//! `memcpy`, and a decoded request performs exactly two heap
//! allocations (the pixel vec + the model-name string) versus the
//! per-token `Json` tree the text path builds (asserted structurally in
//! `rust/tests/serve_wire.rs`, measured in `benches/serve_scale.rs`).
//!
//! The first byte of every frame is [`MAGIC`] = `0x95` — a UTF-8
//! continuation byte that can never begin a JSON object — so the server
//! auto-detects the protocol per message on one port ([`is_binary`]).
//!
//! ## Frame layout (all integers little-endian)
//!
//! Request (classify), 16-byte header:
//!
//! | off | len | field                                        |
//! |-----|-----|----------------------------------------------|
//! | 0   | 1   | magic `0x95`                                 |
//! | 1   | 1   | opcode: `0x01` classify                      |
//! | 2   | 1   | model-name length `m` (0 = default model)    |
//! | 3   | 1   | reserved (0)                                 |
//! | 4   | 4   | `req_id` (u32, echoed verbatim in the reply) |
//! | 8   | 4   | `timeout_ms` (u32, 0 = server default)       |
//! | 12  | 4   | pixel count `n` (u32)                        |
//! | 16  | m   | model name (UTF-8)                           |
//! | 16+m| 4n  | pixels (`f32` LE)                            |
//!
//! Sparse request (embedding-bag lookup), 20-byte header:
//!
//! | off    | len | field                                        |
//! |--------|-----|----------------------------------------------|
//! | 0      | 1   | magic `0x95`                                 |
//! | 1      | 1   | opcode: `0x02` classify-sparse               |
//! | 2      | 1   | model-name length `m` (0 = default model)    |
//! | 3      | 1   | reserved (0)                                 |
//! | 4      | 4   | `req_id` (u32, echoed verbatim in the reply) |
//! | 8      | 4   | `timeout_ms` (u32, 0 = server default)       |
//! | 12     | 4   | bag count `b` (u32, CSR offsets)             |
//! | 16     | 4   | index count `n` (u32)                        |
//! | 20     | m   | model name (UTF-8)                           |
//! | 20+m   | 4b  | offsets (`u32` LE, first must be 0)          |
//! | 20+m+4b| 4n  | indices (`u32` LE)                           |
//!
//! The sparse reply reuses the ok frame below with `class` carrying the
//! bag count and the payload carrying `b × dim` bag values row-major —
//! byte-identical framing, so one reply decoder serves both shapes.
//!
//! Reply, 20-byte header:
//!
//! | off | len | field                                                  |
//! |-----|-----|--------------------------------------------------------|
//! | 0   | 1   | magic `0x95`                                           |
//! | 1   | 1   | opcode: `0x81` ok, `0x82` error                        |
//! | 2   | 1   | error code (see below; 0 on ok)                        |
//! | 3   | 1   | reserved (0)                                           |
//! | 4   | 4   | `req_id` (u32)                                         |
//! | 8   | 4   | `latency_us` (u32, saturated)                          |
//! | 12  | 4   | ok: class index · error: `retry_after_ms` hint         |
//! | 16  | 4   | payload count `n` (u32): probs on ok, msg bytes on err |
//! | 20  | …   | ok: `n × f32` LE probs · error: `n` bytes UTF-8 message|
//!
//! Error codes mirror the JSON `"code"` strings one-to-one
//! ([`code_to_num`] / [`num_to_code`]), so both protocols expose the
//! identical failure taxonomy: 1 `overloaded`, 2 `deadline`,
//! 3 `timeout`, 4 `engine`, 5 `bad_input`, 6 `unloaded`,
//! 7 `unknown_model`, 8 `bad_frame` (malformed/unsupported frame —
//! binary-only, the analogue of the JSON `"bad json"` reply).

use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// First byte of every binary frame. `0x95` is a UTF-8 continuation
/// byte: no JSON line (or any UTF-8 text) can start with it.
pub const MAGIC: u8 = 0x95;

/// Request opcode: classify (dense f32 row).
pub const OP_CLASSIFY: u8 = 0x01;
/// Request opcode: sparse embedding-bag lookup (u32 CSR payload).
pub const OP_CLASSIFY_SPARSE: u8 = 0x02;
/// Reply opcode: successful classification.
pub const OP_REPLY_OK: u8 = 0x81;
/// Reply opcode: typed error.
pub const OP_REPLY_ERR: u8 = 0x82;

/// Numeric error codes (wire) ↔ the JSON `"code"` strings.
pub const ERR_OVERLOADED: u8 = 1;
pub const ERR_DEADLINE: u8 = 2;
pub const ERR_TIMEOUT: u8 = 3;
pub const ERR_ENGINE: u8 = 4;
pub const ERR_BAD_INPUT: u8 = 5;
pub const ERR_UNLOADED: u8 = 6;
pub const ERR_UNKNOWN_MODEL: u8 = 7;
pub const ERR_BAD_FRAME: u8 = 8;

const REQ_HEADER: usize = 16;
const SPARSE_REQ_HEADER: usize = 20;
const REPLY_HEADER: usize = 20;

/// Hard caps against hostile headers: a length field beyond these fails
/// the frame instead of asking the allocator for gigabytes.
pub const MAX_PIXELS: usize = 1 << 20;
/// Index cap per sparse request frame.
pub const MAX_INDICES: usize = 1 << 20;
/// Bag cap per sparse request frame.
pub const MAX_BAGS: usize = 1 << 20;
/// Probs/message payload cap on replies (defensive client-side bound).
pub const MAX_REPLY_ITEMS: usize = 1 << 20;

/// Does a message starting with `first_byte` use the binary protocol?
pub fn is_binary(first_byte: u8) -> bool {
    first_byte == MAGIC
}

/// JSON `"code"` string → wire byte.
pub fn code_to_num(code: &str) -> u8 {
    match code {
        "overloaded" => ERR_OVERLOADED,
        "deadline" => ERR_DEADLINE,
        "timeout" => ERR_TIMEOUT,
        "engine" => ERR_ENGINE,
        "bad_input" => ERR_BAD_INPUT,
        "unloaded" => ERR_UNLOADED,
        "unknown_model" => ERR_UNKNOWN_MODEL,
        "bad_frame" => ERR_BAD_FRAME,
        _ => 0,
    }
}

/// Wire byte → JSON `"code"` string (`"unknown"` for unassigned bytes).
pub fn num_to_code(num: u8) -> &'static str {
    match num {
        ERR_OVERLOADED => "overloaded",
        ERR_DEADLINE => "deadline",
        ERR_TIMEOUT => "timeout",
        ERR_ENGINE => "engine",
        ERR_BAD_INPUT => "bad_input",
        ERR_UNLOADED => "unloaded",
        ERR_UNKNOWN_MODEL => "unknown_model",
        ERR_BAD_FRAME => "bad_frame",
        _ => "unknown",
    }
}

/// What a request frame carries — the wire twin of
/// [`super::batcher::Payload`].
#[derive(Debug, Clone, PartialEq)]
pub enum FramePayload {
    /// [`OP_CLASSIFY`]: one dense f32 row.
    Dense(Vec<f32>),
    /// [`OP_CLASSIFY_SPARSE`]: a CSR bag request.
    Sparse { indices: Vec<u32>, offsets: Vec<u32> },
}

/// A decoded classify request.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameRequest {
    pub req_id: u32,
    /// Empty = route to the server's default model.
    pub model: String,
    /// 0 = use the server's default deadline.
    pub timeout_ms: u32,
    pub payload: FramePayload,
}

/// A decoded reply frame.
#[derive(Debug, Clone, PartialEq)]
pub enum FrameReply {
    Ok { req_id: u32, class: u32, latency_us: u32, probs: Vec<f32> },
    Err { req_id: u32, code: u8, retry_after_ms: u32, message: String },
}

/// Malformed frame: the connection cannot resync after this, so the
/// server answers with an `ERR_BAD_FRAME` frame and closes.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameError(pub String);

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bad frame: {}", self.0)
    }
}

impl std::error::Error for FrameError {}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Append one classify request frame to `buf` (which is not cleared, so
/// callers can pack several frames per write).
pub fn encode_request(
    buf: &mut Vec<u8>,
    req_id: u32,
    model: &str,
    timeout_ms: u32,
    pixels: &[f32],
) {
    assert!(model.len() <= u8::MAX as usize, "model name too long for the wire");
    buf.reserve(REQ_HEADER + model.len() + 4 * pixels.len());
    buf.push(MAGIC);
    buf.push(OP_CLASSIFY);
    buf.push(model.len() as u8);
    buf.push(0);
    buf.extend_from_slice(&req_id.to_le_bytes());
    buf.extend_from_slice(&timeout_ms.to_le_bytes());
    buf.extend_from_slice(&(pixels.len() as u32).to_le_bytes());
    buf.extend_from_slice(model.as_bytes());
    for p in pixels {
        buf.extend_from_slice(&p.to_le_bytes());
    }
}

/// Append one sparse classify request frame (embedding-bag lookup) to
/// `buf`. `offsets` is the CSR bag-start array (first entry 0), the
/// same convention [`crate::nn::EmbedBag::forward`] consumes.
pub fn encode_sparse_request(
    buf: &mut Vec<u8>,
    req_id: u32,
    model: &str,
    timeout_ms: u32,
    indices: &[u32],
    offsets: &[u32],
) {
    assert!(model.len() <= u8::MAX as usize, "model name too long for the wire");
    buf.reserve(SPARSE_REQ_HEADER + model.len() + 4 * (indices.len() + offsets.len()));
    buf.push(MAGIC);
    buf.push(OP_CLASSIFY_SPARSE);
    buf.push(model.len() as u8);
    buf.push(0);
    buf.extend_from_slice(&req_id.to_le_bytes());
    buf.extend_from_slice(&timeout_ms.to_le_bytes());
    buf.extend_from_slice(&(offsets.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(indices.len() as u32).to_le_bytes());
    buf.extend_from_slice(model.as_bytes());
    for o in offsets {
        buf.extend_from_slice(&o.to_le_bytes());
    }
    for i in indices {
        buf.extend_from_slice(&i.to_le_bytes());
    }
}

/// Try to decode one request frame from the front of `buf`.
///
/// * `Ok(None)` — the frame is still incomplete; read more bytes.
/// * `Ok(Some((req, consumed)))` — one frame decoded; drop `consumed`
///   bytes from the front.
/// * `Err(_)` — the bytes can never become a valid frame.
pub fn decode_request(buf: &[u8]) -> Result<Option<(FrameRequest, usize)>, FrameError> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf[0] != MAGIC {
        return Err(FrameError(format!("bad magic 0x{:02x}", buf[0])));
    }
    if buf.len() >= 2 && buf[1] != OP_CLASSIFY && buf[1] != OP_CLASSIFY_SPARSE {
        return Err(FrameError(format!("unsupported request opcode 0x{:02x}", buf[1])));
    }
    if buf.len() >= 2 && buf[1] == OP_CLASSIFY_SPARSE {
        return decode_sparse_request(buf);
    }
    if buf.len() < REQ_HEADER {
        return Ok(None);
    }
    let model_len = buf[2] as usize;
    let n = u32_at(buf, 12) as usize;
    if n > MAX_PIXELS {
        return Err(FrameError(format!("pixel count {n} exceeds cap {MAX_PIXELS}")));
    }
    let total = REQ_HEADER + model_len + 4 * n;
    if buf.len() < total {
        return Ok(None);
    }
    let model = std::str::from_utf8(&buf[REQ_HEADER..REQ_HEADER + model_len])
        .map_err(|_| FrameError("model name is not UTF-8".into()))?
        .to_string();
    let mut pixels = Vec::with_capacity(n);
    let base = REQ_HEADER + model_len;
    for i in 0..n {
        let off = base + 4 * i;
        pixels.push(f32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]]));
    }
    Ok(Some((
        FrameRequest {
            req_id: u32_at(buf, 4),
            model,
            timeout_ms: u32_at(buf, 8),
            payload: FramePayload::Dense(pixels),
        },
        total,
    )))
}

/// [`decode_request`]'s sparse arm (`buf[1] == OP_CLASSIFY_SPARSE`,
/// already checked). Same `Ok(None)`/`Err` contract.
fn decode_sparse_request(buf: &[u8]) -> Result<Option<(FrameRequest, usize)>, FrameError> {
    if buf.len() < SPARSE_REQ_HEADER {
        return Ok(None);
    }
    let model_len = buf[2] as usize;
    let n_bags = u32_at(buf, 12) as usize;
    let n_indices = u32_at(buf, 16) as usize;
    if n_bags > MAX_BAGS {
        return Err(FrameError(format!("bag count {n_bags} exceeds cap {MAX_BAGS}")));
    }
    if n_indices > MAX_INDICES {
        return Err(FrameError(format!("index count {n_indices} exceeds cap {MAX_INDICES}")));
    }
    let total = SPARSE_REQ_HEADER + model_len + 4 * (n_bags + n_indices);
    if buf.len() < total {
        return Ok(None);
    }
    let model = std::str::from_utf8(&buf[SPARSE_REQ_HEADER..SPARSE_REQ_HEADER + model_len])
        .map_err(|_| FrameError("model name is not UTF-8".into()))?
        .to_string();
    let obase = SPARSE_REQ_HEADER + model_len;
    let mut offsets = Vec::with_capacity(n_bags);
    for i in 0..n_bags {
        offsets.push(u32_at(buf, obase + 4 * i));
    }
    let ibase = obase + 4 * n_bags;
    let mut indices = Vec::with_capacity(n_indices);
    for i in 0..n_indices {
        indices.push(u32_at(buf, ibase + 4 * i));
    }
    Ok(Some((
        FrameRequest {
            req_id: u32_at(buf, 4),
            model,
            timeout_ms: u32_at(buf, 8),
            payload: FramePayload::Sparse { indices, offsets },
        },
        total,
    )))
}

/// Append one success reply frame to `buf`.
pub fn encode_reply_ok(
    buf: &mut Vec<u8>,
    req_id: u32,
    class: u32,
    latency_us: u32,
    probs: &[f32],
) {
    buf.reserve(REPLY_HEADER + 4 * probs.len());
    encode_reply_ok_header(buf, req_id, class, latency_us, probs.len() as u32);
    for p in probs {
        buf.extend_from_slice(&p.to_le_bytes());
    }
}

/// Append just the 20-byte success header, declaring `n_items` payload
/// values that the caller supplies separately. This is the vectored
/// write path in `serve/conn.rs`: the header and the payload buffers
/// flush in one `writev(2)` instead of being copied together first.
pub fn encode_reply_ok_header(
    buf: &mut Vec<u8>,
    req_id: u32,
    class: u32,
    latency_us: u32,
    n_items: u32,
) {
    buf.reserve(REPLY_HEADER);
    buf.push(MAGIC);
    buf.push(OP_REPLY_OK);
    buf.push(0);
    buf.push(0);
    buf.extend_from_slice(&req_id.to_le_bytes());
    buf.extend_from_slice(&latency_us.to_le_bytes());
    buf.extend_from_slice(&class.to_le_bytes());
    buf.extend_from_slice(&n_items.to_le_bytes());
}

/// Append one error reply frame to `buf`.
pub fn encode_reply_err(
    buf: &mut Vec<u8>,
    req_id: u32,
    code: u8,
    retry_after_ms: u32,
    latency_us: u32,
    message: &str,
) {
    let msg = message.as_bytes();
    let msg = &msg[..msg.len().min(u16::MAX as usize)];
    buf.reserve(REPLY_HEADER + msg.len());
    buf.push(MAGIC);
    buf.push(OP_REPLY_ERR);
    buf.push(code);
    buf.push(0);
    buf.extend_from_slice(&req_id.to_le_bytes());
    buf.extend_from_slice(&latency_us.to_le_bytes());
    buf.extend_from_slice(&retry_after_ms.to_le_bytes());
    buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    buf.extend_from_slice(msg);
}

/// Try to decode one reply frame from the front of `buf`; same contract
/// as [`decode_request`].
pub fn decode_reply(buf: &[u8]) -> Result<Option<(FrameReply, usize)>, FrameError> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf[0] != MAGIC {
        return Err(FrameError(format!("bad magic 0x{:02x}", buf[0])));
    }
    if buf.len() >= 2 && buf[1] != OP_REPLY_OK && buf[1] != OP_REPLY_ERR {
        return Err(FrameError(format!("unsupported reply opcode 0x{:02x}", buf[1])));
    }
    if buf.len() < REPLY_HEADER {
        return Ok(None);
    }
    let op = buf[1];
    let n = u32_at(buf, 16) as usize;
    if n > MAX_REPLY_ITEMS {
        return Err(FrameError(format!("payload count {n} exceeds cap {MAX_REPLY_ITEMS}")));
    }
    let req_id = u32_at(buf, 4);
    let latency_us = u32_at(buf, 8);
    let aux = u32_at(buf, 12);
    if op == OP_REPLY_OK {
        let total = REPLY_HEADER + 4 * n;
        if buf.len() < total {
            return Ok(None);
        }
        let mut probs = Vec::with_capacity(n);
        for i in 0..n {
            let off = REPLY_HEADER + 4 * i;
            probs.push(f32::from_le_bytes([
                buf[off],
                buf[off + 1],
                buf[off + 2],
                buf[off + 3],
            ]));
        }
        Ok(Some((FrameReply::Ok { req_id, class: aux, latency_us, probs }, total)))
    } else {
        let total = REPLY_HEADER + n;
        if buf.len() < total {
            return Ok(None);
        }
        let message = String::from_utf8_lossy(&buf[REPLY_HEADER..total]).into_owned();
        Ok(Some((
            FrameReply::Err { req_id, code: buf[2], retry_after_ms: aux, message },
            total,
        )))
    }
}

/// Minimal blocking client speaking the binary protocol — the
/// counterpart of [`crate::serve::Client`] for tests and the
/// connection-scale bench.
pub struct FrameClient {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    next_id: u32,
}

impl FrameClient {
    pub fn connect(addr: &str) -> Result<FrameClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(FrameClient { stream, inbuf: Vec::new(), outbuf: Vec::new(), next_id: 1 })
    }

    /// Bound how long [`FrameClient::read_reply`] blocks (None = forever).
    pub fn set_read_timeout(&mut self, timeout: Option<std::time::Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// One classify round trip against the default model.
    pub fn classify(&mut self, pixels: &[f32]) -> Result<FrameReply> {
        self.classify_model("", pixels, 0)
    }

    /// One classify round trip: empty `model` = server default,
    /// `timeout_ms` 0 = server default deadline. Returns the decoded
    /// reply frame — a typed error frame is an `Ok(FrameReply::Err …)`,
    /// not an `Err`, mirroring `Client::classify_raw`.
    pub fn classify_model(
        &mut self,
        model: &str,
        pixels: &[f32],
        timeout_ms: u32,
    ) -> Result<FrameReply> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        self.outbuf.clear();
        encode_request(&mut self.outbuf, id, model, timeout_ms, pixels);
        self.round_trip(id)
    }

    /// One sparse bag-lookup round trip (embedding models). On success
    /// the reply's `class` is the bag count and `probs` the flattened
    /// `bags × dim` values.
    pub fn classify_sparse(
        &mut self,
        model: &str,
        indices: &[u32],
        offsets: &[u32],
        timeout_ms: u32,
    ) -> Result<FrameReply> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        self.outbuf.clear();
        encode_sparse_request(&mut self.outbuf, id, model, timeout_ms, indices, offsets);
        self.round_trip(id)
    }

    fn round_trip(&mut self, id: u32) -> Result<FrameReply> {
        self.stream.write_all(&self.outbuf)?;
        let reply = self.read_reply()?;
        let got = match &reply {
            FrameReply::Ok { req_id, .. } | FrameReply::Err { req_id, .. } => *req_id,
        };
        if got != id {
            return Err(anyhow!("reply req_id {got} does not match request {id}"));
        }
        Ok(reply)
    }

    /// Read one reply frame (blocking).
    pub fn read_reply(&mut self) -> Result<FrameReply> {
        let mut chunk = [0u8; 4096];
        loop {
            match decode_reply(&self.inbuf).map_err(|e| anyhow!("{e}"))? {
                Some((reply, consumed)) => {
                    self.inbuf.drain(..consumed);
                    return Ok(reply);
                }
                None => {
                    let n = self.stream.read(&mut chunk)?;
                    if n == 0 {
                        return Err(anyhow!("server closed the connection"));
                    }
                    self.inbuf.extend_from_slice(&chunk[..n]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_roundtrip(req_id: u32, model: &str, timeout_ms: u32, pixels: &[f32]) {
        let mut buf = Vec::new();
        encode_request(&mut buf, req_id, model, timeout_ms, pixels);
        let (decoded, consumed) = decode_request(&buf).unwrap().expect("complete frame");
        assert_eq!(consumed, buf.len());
        assert_eq!(decoded.req_id, req_id);
        assert_eq!(decoded.model, model);
        assert_eq!(decoded.timeout_ms, timeout_ms);
        assert_eq!(decoded.payload, FramePayload::Dense(pixels.to_vec()));
    }

    fn sparse_roundtrip(req_id: u32, model: &str, indices: &[u32], offsets: &[u32]) {
        let mut buf = Vec::new();
        encode_sparse_request(&mut buf, req_id, model, 7, indices, offsets);
        let (decoded, consumed) = decode_request(&buf).unwrap().expect("complete frame");
        assert_eq!(consumed, buf.len());
        assert_eq!(decoded.req_id, req_id);
        assert_eq!(decoded.model, model);
        assert_eq!(
            decoded.payload,
            FramePayload::Sparse { indices: indices.to_vec(), offsets: offsets.to_vec() }
        );
    }

    #[test]
    fn request_roundtrip_property() {
        // deterministic pseudo-random sweep over sizes, ids and payloads
        let mut rng = crate::util::rng::Pcg32::new(0xF4A3, 17);
        for case in 0..200 {
            let n = (rng.next_u32() % 300) as usize;
            let model_len = (rng.next_u32() % 20) as usize;
            let model: String = (0..model_len).map(|i| (b'a' + (i as u8 % 26)) as char).collect();
            let pixels: Vec<f32> = (0..n)
                .map(|_| f32::from_bits(rng.next_u32()))
                .map(|f| if f.is_nan() { 0.5 } else { f }) // NaN != NaN breaks eq
                .collect();
            let _ = case;
            req_roundtrip(rng.next_u32(), &model, rng.next_u32() % 100_000, &pixels);
        }
        // NaN/Inf payload bits survive bit-exactly even when eq can't see it
        let mut buf = Vec::new();
        encode_request(&mut buf, 7, "m", 0, &[f32::NAN, f32::INFINITY, -0.0]);
        let (d, _) = decode_request(&buf).unwrap().unwrap();
        let FramePayload::Dense(pixels) = d.payload else { panic!("dense frame") };
        assert_eq!(pixels[0].to_bits(), f32::NAN.to_bits());
        assert_eq!(pixels[1], f32::INFINITY);
        assert_eq!(pixels[2].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn sparse_request_roundtrip_property() {
        let mut rng = crate::util::rng::Pcg32::new(0xBA6, 23);
        for _ in 0..200 {
            let n_bags = (rng.next_u32() % 20) as usize;
            let per = (rng.next_u32() % 8) as usize;
            let mut offsets = Vec::with_capacity(n_bags);
            let mut indices = Vec::new();
            for _ in 0..n_bags {
                offsets.push(indices.len() as u32);
                for _ in 0..per {
                    indices.push(rng.next_u32());
                }
            }
            let model_len = (rng.next_u32() % 20) as usize;
            let model: String = (0..model_len).map(|i| (b'a' + (i as u8 % 26)) as char).collect();
            sparse_roundtrip(rng.next_u32(), &model, &indices, &offsets);
        }
        // degenerate shapes round-trip too
        sparse_roundtrip(1, "", &[], &[]); // zero-length payload
        sparse_roundtrip(2, "m", &[], &[0, 0, 0]); // all-empty bags
        sparse_roundtrip(3, "m", &[9, 9, 9], &[0]); // one bag, all indices
    }

    #[test]
    fn reply_roundtrip_both_kinds() {
        let mut buf = Vec::new();
        encode_reply_ok(&mut buf, 42, 3, 1234, &[0.1, 0.2, 0.7]);
        let (r, consumed) = decode_reply(&buf).unwrap().unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(
            r,
            FrameReply::Ok { req_id: 42, class: 3, latency_us: 1234, probs: vec![0.1, 0.2, 0.7] }
        );
        buf.clear();
        encode_reply_err(&mut buf, 43, ERR_OVERLOADED, 25, 9, "queue full");
        let (r, consumed) = decode_reply(&buf).unwrap().unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(
            r,
            FrameReply::Err {
                req_id: 43,
                code: ERR_OVERLOADED,
                retry_after_ms: 25,
                message: "queue full".into()
            }
        );
    }

    #[test]
    fn truncated_frames_ask_for_more_bytes_at_every_prefix() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 9, "digits", 250, &[1.0, 2.0, 3.0]);
        for cut in 0..buf.len() {
            match decode_request(&buf[..cut]) {
                Ok(None) => {}
                other => panic!("prefix {cut}/{} must be incomplete, got {other:?}", buf.len()),
            }
        }
        let mut buf = Vec::new();
        encode_sparse_request(&mut buf, 9, "bags", 250, &[1, 2, 3], &[0, 2]);
        for cut in 0..buf.len() {
            match decode_request(&buf[..cut]) {
                Ok(None) => {}
                other => panic!("sparse prefix {cut}/{} must be incomplete, got {other:?}", buf.len()),
            }
        }
        let mut buf = Vec::new();
        encode_reply_ok(&mut buf, 9, 0, 1, &[0.5, 0.5]);
        for cut in 0..buf.len() {
            assert_eq!(decode_reply(&buf[..cut]), Ok(None), "reply prefix {cut}");
        }
        let mut buf = Vec::new();
        encode_reply_err(&mut buf, 9, ERR_BAD_INPUT, 0, 1, "nope");
        for cut in 0..buf.len() {
            assert_eq!(decode_reply(&buf[..cut]), Ok(None), "err-reply prefix {cut}");
        }
    }

    /// Satellite property test: headers with hostile declared lengths,
    /// zero-length payloads, and arbitrary byte soup must always come
    /// back as `Ok(None)` (incomplete), `Ok(Some(..))` (valid), or
    /// `Err` (unrecoverable) — never a panic, never a huge allocation.
    #[test]
    fn header_parsing_never_panics_property() {
        // (1) oversized declared lengths on every length field
        for (bag_cnt, idx_cnt) in
            [(u32::MAX, 0u32), (0, u32::MAX), ((MAX_BAGS + 1) as u32, 0), (0, (MAX_INDICES + 1) as u32)]
        {
            let mut buf = vec![MAGIC, OP_CLASSIFY_SPARSE, 0, 0];
            buf.extend_from_slice(&1u32.to_le_bytes()); // req_id
            buf.extend_from_slice(&0u32.to_le_bytes()); // timeout
            buf.extend_from_slice(&bag_cnt.to_le_bytes());
            buf.extend_from_slice(&idx_cnt.to_le_bytes());
            assert!(decode_request(&buf).is_err(), "bags {bag_cnt} indices {idx_cnt}");
        }
        let mut buf = vec![MAGIC, OP_REPLY_OK, 0, 0];
        buf.extend_from_slice(&[0u8; 12]);
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // payload count
        assert!(decode_reply(&buf).is_err());

        // (2) zero-length payloads are valid complete frames
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, "", 0, &[]);
        let (req, used) = decode_request(&buf).unwrap().expect("empty dense frame");
        assert_eq!(used, buf.len());
        assert_eq!(req.payload, FramePayload::Dense(vec![]));
        let mut buf = Vec::new();
        encode_reply_ok(&mut buf, 1, 0, 0, &[]);
        assert!(decode_reply(&buf).unwrap().is_some());
        let mut buf = Vec::new();
        encode_reply_err(&mut buf, 1, ERR_ENGINE, 0, 0, "");
        assert!(decode_reply(&buf).unwrap().is_some());

        // (3) deterministic fuzz: random bytes through both decoders —
        // the contract is "no panic", whatever the outcome enum says
        let mut rng = crate::util::rng::Pcg32::new(0xFEED, 3);
        for round in 0..2_000 {
            let len = (rng.next_u32() % 64) as usize;
            let mut bytes: Vec<u8> = (0..len).map(|_| (rng.next_u32() & 0xff) as u8).collect();
            // bias half the rounds toward plausible frames so the deep
            // paths get exercised, not just the magic check
            if round % 2 == 0 && !bytes.is_empty() {
                bytes[0] = MAGIC;
                if bytes.len() > 1 {
                    bytes[1] = [OP_CLASSIFY, OP_CLASSIFY_SPARSE, OP_REPLY_OK, OP_REPLY_ERR]
                        [(rng.next_u32() % 4) as usize];
                }
            }
            let _ = decode_request(&bytes);
            let _ = decode_reply(&bytes);
        }
    }

    #[test]
    fn pipelined_frames_decode_one_at_a_time() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, "", 0, &[1.0]);
        encode_request(&mut buf, 2, "other", 5, &[2.0, 3.0]);
        let (first, used) = decode_request(&buf).unwrap().unwrap();
        assert_eq!(first.req_id, 1);
        let (second, used2) = decode_request(&buf[used..]).unwrap().unwrap();
        assert_eq!(second.req_id, 2);
        assert_eq!(second.model, "other");
        assert_eq!(used + used2, buf.len());
    }

    #[test]
    fn malformed_frames_fail_without_panicking() {
        // wrong magic
        assert!(decode_request(b"{\"pixels\":[]}").is_err());
        // unknown opcode
        assert!(decode_request(&[MAGIC, 0x7f]).is_err());
        assert!(decode_reply(&[MAGIC, 0x01]).is_err());
        // hostile pixel count: must reject, not try to allocate 4 GiB
        let mut buf = vec![MAGIC, OP_CLASSIFY, 0, 0];
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_request(&buf).is_err());
        // non-UTF-8 model name
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, "ab", 0, &[]);
        buf[REQ_HEADER] = 0xff;
        buf[REQ_HEADER + 1] = 0xfe;
        assert!(decode_request(&buf).is_err());
    }

    #[test]
    fn error_code_mapping_is_a_bijection_over_known_codes() {
        for code in [
            "overloaded",
            "deadline",
            "timeout",
            "engine",
            "bad_input",
            "unloaded",
            "unknown_model",
            "bad_frame",
        ] {
            let n = code_to_num(code);
            assert_ne!(n, 0, "{code} must have a wire byte");
            assert_eq!(num_to_code(n), code);
        }
        assert_eq!(num_to_code(0), "unknown");
        assert_eq!(code_to_num("nonsense"), 0);
    }

    #[test]
    fn magic_byte_cannot_start_utf8_text() {
        // 0x95 is a continuation byte: no valid UTF-8 string starts with
        // it, so JSON lines and binary frames are unambiguous.
        assert!(std::str::from_utf8(&[MAGIC]).is_err());
        assert!(std::str::from_utf8(&[MAGIC, b'{']).is_err());
    }
}
