//! Inference serving: the deployment story the paper's introduction
//! motivates (compressed models on memory-constrained devices).
//!
//! Architecture (vLLM-router-style, scaled to this system):
//!
//! * [`engine::InferenceEngine`] — the backend abstraction: `predict`
//!   plus shape metadata. [`engine::NativeEngine`] wraps the in-process
//!   `nn::Network` (HashPlan kernels, `Send + Sync`, multi-worker);
//!   [`engine::RuntimeEngine`] wraps a PJRT artifact executable
//!   (single worker — PJRT handles are not `Send`). Selection is a
//!   [`engine::Backend`]: `native`, `runtime`, or `auto` (runtime when
//!   artifact loading works, native otherwise).
//! * [`batcher::DynamicBatcher`] — request queue + batch former:
//!   collects requests until `max_batch` or `max_wait` elapses, runs
//!   one `predict` call, scatters replies. Shareable by several worker
//!   threads, which is how one native model serves N workers without
//!   locks around the parameters.
//! * [`server`] — the TCP front end: model registry, admin commands,
//!   and the blocking [`server::Client`]. Connections are driven by
//!   one event-loop thread (`serve/conn.rs`, private) over a readiness
//!   reactor ([`poll`]: raw `poll(2)`/epoll bindings, no new crates);
//!   per-connection state machines parse requests and feed each
//!   model's bounded batcher, so 10k idle connections cost buffers,
//!   not threads (tokio is not vendored offline; the reactor plays
//!   its role).
//! * Two wire protocols share the port, auto-detected per message
//!   from the first byte: newline-delimited JSON
//!   (`{"model": "...", "pixels": [...]}` → `{"class": c, ...}`) and
//!   the length-prefixed binary frame format in [`frame`] (magic
//!   `0x95` + opcode + model name + raw little-endian f32 pixels) —
//!   same request semantics, same error taxonomy, a fraction of the
//!   parse/allocation work per request.
//!   The registry is **mutable**, so one process serves multiple named
//!   models and hot-(re)loads them at runtime: `{"cmd":"load"}` swaps
//!   a freshly trained bundle in without a restart;
//!   `unload`/`reload`/`models` manage the rest.
//!
//! The model is one self-describing [`crate::model::ModelBundle`] —
//! total server memory per model is the *compressed* parameter count,
//! which is the paper's point.
//!
//! Resilience (PR 6): admission control (bounded queues, explicit
//! `overloaded` rejection), per-request deadlines (expired before the
//! model runs), panic containment in dispatch/worker loops, and a
//! seeded [`chaos::ChaosEngine`] fault injector that the soak test
//! drives through the real server. The event loop (PR 7) submits
//! through the same bounded admission path, so all of it carries over
//! unchanged. See `ARCHITECTURE.md` §Resilience and §Event loop.

pub mod batcher;
pub mod chaos;
mod conn;
pub mod engine;
pub mod frame;
pub mod poll;
pub mod server;

pub use batcher::{BatchStats, DynamicBatcher, ReplySender, Request, Response, ServeError};
pub use chaos::{ChaosConfig, ChaosEngine, ChaosStats};
pub use engine::{Backend, InferenceEngine, ModelConfig, NativeEngine, RuntimeEngine};
pub use frame::{FrameClient, FrameReply, FrameRequest};
pub use poll::PollerKind;
pub use server::{serve, Client, ServeOptions, Server};
