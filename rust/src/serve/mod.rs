//! Inference serving: the deployment story the paper's introduction
//! motivates (compressed models on memory-constrained devices).
//!
//! Architecture (vLLM-router-style, scaled to this system):
//!
//! * [`batcher::DynamicBatcher`] — request queue + batch former: collects
//!   requests until `max_batch` or `max_wait` elapses, pads to the
//!   artifact's static batch, runs one `predict` call, scatters replies.
//! * [`server`] — a std-net TCP front end speaking newline-delimited
//!   JSON (`{"pixels": [...784 floats...]}` → `{"class": c, "probs": [...]}`),
//!   with a worker thread owning the PJRT executable (tokio is not
//!   vendored offline; blocking I/O + threads serve the same purpose).
//!
//! The model is a trained checkpoint (`ModelState::save`) plus an
//! artifact name — total server memory for the model is the *compressed*
//! parameter count, which is the paper's point.

pub mod batcher;
pub mod server;

pub use batcher::{BatchStats, DynamicBatcher, Request, Response};
pub use server::{serve, Client, ServeOptions};
