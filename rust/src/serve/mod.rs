//! Inference serving: the deployment story the paper's introduction
//! motivates (compressed models on memory-constrained devices).
//!
//! Architecture (vLLM-router-style, scaled to this system):
//!
//! * [`engine::InferenceEngine`] — the backend abstraction: `predict`
//!   plus shape metadata. [`engine::NativeEngine`] wraps the in-process
//!   `nn::Network` (HashPlan kernels, `Send + Sync`, multi-worker);
//!   [`engine::RuntimeEngine`] wraps a PJRT artifact executable
//!   (single worker — PJRT handles are not `Send`). Selection is a
//!   [`engine::Backend`]: `native`, `runtime`, or `auto` (runtime when
//!   artifact loading works, native otherwise).
//! * [`batcher::DynamicBatcher`] — request queue + batch former:
//!   collects requests until `max_batch` or `max_wait` elapses, runs
//!   one `predict` call, scatters replies. Shareable by several worker
//!   threads, which is how one native model serves N workers without
//!   locks around the parameters.
//! * [`server`] — a std-net TCP front end speaking newline-delimited
//!   JSON (`{"model": "...", "pixels": [...]}` → `{"class": c, ...}`),
//!   routing per-request to a **mutable** engine registry so one
//!   process serves multiple named models and can hot-(re)load them at
//!   runtime: `{"cmd":"load","path":"m.hnb"}` swaps a freshly trained
//!   bundle in without a restart, `unload`/`reload`/`models` manage
//!   the rest (tokio is not vendored offline; blocking I/O + threads
//!   serve the same purpose).
//!
//! The model is one self-describing [`crate::model::ModelBundle`] —
//! total server memory per model is the *compressed* parameter count,
//! which is the paper's point.

//!
//! Resilience (PR 6): admission control (bounded queues, explicit
//! `overloaded` rejection), per-request deadlines (expired before the
//! model runs), panic containment in dispatch/worker loops, and a
//! seeded [`chaos::ChaosEngine`] fault injector that the soak test
//! drives through the real server. See `ARCHITECTURE.md` §Resilience.

pub mod batcher;
pub mod chaos;
pub mod engine;
pub mod server;

pub use batcher::{BatchStats, DynamicBatcher, Request, Response, ServeError};
pub use chaos::{ChaosConfig, ChaosEngine, ChaosStats};
pub use engine::{Backend, InferenceEngine, ModelConfig, NativeEngine, RuntimeEngine};
pub use server::{serve, Client, ServeOptions, Server};
