//! Deterministic fault injection for serving: [`ChaosEngine`] wraps
//! any [`InferenceEngine`] and injects errors, latency spikes, and
//! panics at configured rates from a seeded [`Pcg32`] stream.
//!
//! Resilience claims that are never exercised are decoration. The
//! chaos wrapper plugs into the real serving stack through
//! `Server::bind_with_engines` — same batcher, same workers, same wire
//! protocol — so the soak test (`rust/tests/serve_chaos.rs`) drives
//! genuine overload/fault traffic through the exact code paths
//! production requests take, and the seed makes a failing run
//! reproducible instead of a flake.
//!
//! Fault draw order per `predict` call is fixed (latency, then
//! panic/error) so a given `(seed, call index)` always yields the same
//! fault — two runs with the same seed inject identically.

use super::engine::InferenceEngine;
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Fault rates for a [`ChaosEngine`]. All rates are probabilities in
/// `[0, 1]` drawn independently per `predict` call; `panic_rate` is
/// checked before `error_rate`, so with both set a call panics with
/// probability `panic_rate` and errors with probability `error_rate`
/// (disjoint draws from one uniform sample).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Seed for the deterministic fault stream.
    pub seed: u64,
    /// Probability a call returns `Err("chaos: injected error")`.
    pub error_rate: f64,
    /// Probability a call panics (exercises `catch_unwind` containment).
    pub panic_rate: f64,
    /// Probability a call sleeps `latency` before proceeding.
    pub latency_rate: f64,
    /// The injected latency spike.
    pub latency: Duration,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0xC4A05,
            error_rate: 0.0,
            panic_rate: 0.0,
            latency_rate: 0.0,
            latency: Duration::from_millis(5),
        }
    }
}

/// Counters of what the chaos layer actually injected — the soak test
/// asserts these are non-zero, proving the run exercised the faults it
/// claims to survive.
#[derive(Debug, Default, Clone)]
pub struct ChaosStats {
    pub calls: u64,
    pub errors_injected: u64,
    pub panics_injected: u64,
    pub spikes_injected: u64,
}

/// An [`InferenceEngine`] decorator that misbehaves on schedule.
///
/// Shape metadata delegates to the inner engine, so the server batches
/// and validates exactly as it would for the real model; only
/// `predict` is intercepted.
pub struct ChaosEngine {
    inner: Arc<dyn InferenceEngine + Send + Sync>,
    cfg: ChaosConfig,
    rng: Mutex<Pcg32>,
    calls: AtomicU64,
    errors_injected: AtomicU64,
    panics_injected: AtomicU64,
    spikes_injected: AtomicU64,
}

impl ChaosEngine {
    pub fn new(inner: Arc<dyn InferenceEngine + Send + Sync>, cfg: ChaosConfig) -> ChaosEngine {
        let rng = Mutex::new(Pcg32::new(cfg.seed, 0xFA17));
        ChaosEngine {
            inner,
            cfg,
            rng,
            calls: AtomicU64::new(0),
            errors_injected: AtomicU64::new(0),
            panics_injected: AtomicU64::new(0),
            spikes_injected: AtomicU64::new(0),
        }
    }

    /// What has been injected so far.
    pub fn stats(&self) -> ChaosStats {
        ChaosStats {
            calls: self.calls.load(Ordering::Relaxed),
            errors_injected: self.errors_injected.load(Ordering::Relaxed),
            panics_injected: self.panics_injected.load(Ordering::Relaxed),
            spikes_injected: self.spikes_injected.load(Ordering::Relaxed),
        }
    }
}

impl InferenceEngine for ChaosEngine {
    fn predict(&self, x: &Matrix) -> Result<Matrix> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        // Draw both samples inside one short lock scope and release it
        // before sleeping or panicking — a poisoned rng mutex would
        // turn one injected panic into a permanently broken engine,
        // which is the chaos layer causing the very failure mode the
        // stack is meant to contain.
        let (spike, fault) = {
            let mut rng = self.rng.lock().unwrap();
            (rng.next_f64(), rng.next_f64())
        };
        if spike < self.cfg.latency_rate {
            self.spikes_injected.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.cfg.latency);
        }
        if fault < self.cfg.panic_rate {
            self.panics_injected.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected panic (seed {})", self.cfg.seed);
        }
        if fault < self.cfg.panic_rate + self.cfg.error_rate {
            self.errors_injected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("chaos: injected error (seed {})", self.cfg.seed));
        }
        self.inner.predict(x)
    }

    fn n_in(&self) -> usize {
        self.inner.n_in()
    }

    fn n_out(&self) -> usize {
        self.inner.n_out()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn name(&self) -> &'static str {
        "chaos"
    }

    fn fixed_batch(&self) -> bool {
        self.inner.fixed_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::NativeEngine;
    use crate::nn::{LayerKind, Network};

    fn tiny_engine() -> Arc<dyn InferenceEngine + Send + Sync> {
        let mut net = Network::from_dims(
            &[6, 5, 3],
            vec![LayerKind::Hashed { k: 12 }, LayerKind::Dense],
            crate::hash::DEFAULT_SEED_BASE,
        );
        net.init(&mut Pcg32::new(9, 9));
        Arc::new(NativeEngine::from_network(net, 8))
    }

    fn outcome_trace(chaos: &ChaosEngine, x: &Matrix, n: usize) -> Vec<&'static str> {
        (0..n)
            .map(|_| {
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| chaos.predict(x))) {
                    Ok(Ok(_)) => "ok",
                    Ok(Err(_)) => "err",
                    Err(_) => "panic",
                }
            })
            .collect()
    }

    #[test]
    fn zero_rates_are_passthrough() {
        let inner = tiny_engine();
        let x = Matrix::from_fn(2, 6, |i, j| (i + j) as f32 * 0.1);
        let want = inner.predict(&x).unwrap();
        let chaos = ChaosEngine::new(inner, ChaosConfig::default());
        assert_eq!(chaos.n_in(), 6);
        assert_eq!(chaos.n_out(), 3);
        assert_eq!(chaos.max_batch(), 8);
        assert_eq!(chaos.name(), "chaos");
        let got = chaos.predict(&x).unwrap();
        assert_eq!(got.data, want.data);
        let s = chaos.stats();
        assert_eq!(s.calls, 1);
        assert_eq!(s.errors_injected + s.panics_injected + s.spikes_injected, 0);
    }

    #[test]
    fn same_seed_injects_identical_fault_sequence() {
        let cfg = ChaosConfig {
            seed: 42,
            error_rate: 0.3,
            panic_rate: 0.2,
            latency_rate: 0.0,
            ..ChaosConfig::default()
        };
        let x = Matrix::zeros(1, 6);
        let a = outcome_trace(&ChaosEngine::new(tiny_engine(), cfg.clone()), &x, 50);
        let b = outcome_trace(&ChaosEngine::new(tiny_engine(), cfg), &x, 50);
        assert_eq!(a, b, "same seed must inject the same faults");
        assert!(a.contains(&"ok") && a.contains(&"err") && a.contains(&"panic"), "{a:?}");
    }

    #[test]
    fn injected_panic_is_containable_and_engine_stays_usable() {
        let cfg = ChaosConfig { seed: 7, panic_rate: 1.0, ..ChaosConfig::default() };
        let chaos = ChaosEngine::new(tiny_engine(), cfg);
        let x = Matrix::zeros(1, 6);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| chaos.predict(&x)));
        assert!(caught.is_err(), "panic_rate 1.0 must panic");
        // the rng lock was released before the panic: the engine is
        // not poisoned and keeps injecting deterministically
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| chaos.predict(&x)));
        assert!(caught.is_err());
        assert_eq!(chaos.stats().panics_injected, 2);
    }

    #[test]
    fn error_rate_one_always_errors_explicitly() {
        let cfg = ChaosConfig { seed: 3, error_rate: 1.0, ..ChaosConfig::default() };
        let chaos = ChaosEngine::new(tiny_engine(), cfg);
        let e = chaos.predict(&Matrix::zeros(1, 6)).unwrap_err();
        assert!(e.to_string().contains("chaos: injected error"), "{e}");
        assert_eq!(chaos.stats().errors_injected, 1);
    }
}
