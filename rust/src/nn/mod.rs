//! Native neural-network engine: the same math as the L2 JAX models,
//! re-implemented in Rust.
//!
//! Purpose (DESIGN.md §1):
//! 1. **Cross-validation** — because [`crate::hash`] is bit-identical to
//!    the Python hashing, a native HashedNet and the AOT artifact
//!    decompress *exactly* the same virtual matrices; integration tests
//!    compare logits between the two stacks.
//! 2. **No-XLA fallback** — train/evaluate anywhere the PJRT runtime
//!    isn't available.
//! 3. **Native baseline** for the performance benches (hand-written
//!    decompress-on-the-fly matmul vs. the XLA-compiled kernel).
//!
//! Mirrors `python/compile/model.py`: bias columns are hashed with the
//! weights (input augmented with a constant-1 column), hidden
//! activations are ReLU with inverted dropout, the loss is softmax
//! cross-entropy (optionally blended with dark-knowledge soft targets),
//! and updates are SGD with momentum.

pub mod embed;
pub mod layers;
pub mod network;

pub use embed::EmbedBag;
pub use layers::{Layer, LayerKind, TrainOptions};
pub use network::{DkTargets, Network, TrainHyper};
