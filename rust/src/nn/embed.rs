//! Hashed embedding bags: the paper's Eq. 7 weight sharing applied to a
//! lookup table whose virtual size can exceed RAM.
//!
//! A [`EmbedBag`] is a virtual `num_categories × dim` table `V` backed
//! by `k` real weights: `V[r][c] = ξ(r,c) · w[h(r,c)]` with the same
//! `xxh32` bucket/sign mapping every hashed layer uses
//! ([`crate::hash::bucket_sign`]). The crucial difference from
//! [`super::Layer`]'s `LayerKind::Hashed` is **when** the mapping is
//! evaluated: a hashed layer builds a per-cell [`crate::hash::HashPlan`]
//! eagerly (4 bytes per virtual cell — fine at 785×1000, fatal at
//! 1M×64), while an embedding bag hashes `(row, col)` lazily per
//! requested row. The bucket array `w` plus `(num_categories, dim, k,
//! seed)` is the *only* representation; the virtual table is never
//! allocated, so resident memory is `O(k)` however large
//! `num_categories` grows (ROADMAP item 3).
//!
//! # Mapping to the paper
//!
//! | code | paper |
//! |------|-------|
//! | [`EmbedBag::forward`] | Eq. 8 specialized to one-hot bags: `z_c = Σ_{r ∈ bag} ξ(r,c)·w_{h(r,c)}` — the activation `a_j` is the bag's multiset indicator |
//! | [`EmbedBag::backward`] | Eq. 12 over the *touched* cells only: `∂w_b = Σ_{(r,c): h(r,c)=b} ξ(r,c)·δ_c`, accumulated sequentially per bucket off a per-batch mini inverse map |
//! | `k` | the real-weight budget `K` (§4.1) |
//!
//! Structured Multi-Hashing (Eban et al.) motivates the kernel shape:
//! the per-row inner loop runs contiguously over `dim` (`c = 0..dim`,
//! one hash + one multiply-add per column, output row contiguous), so
//! the gather stays vectorizable instead of striding the bucket array.
//!
//! # Bags
//!
//! Requests arrive CSR-style as `indices` + `offsets` (the
//! `EmbeddingBag` convention): bag `i` is
//! `indices[offsets[i] .. offsets[i+1]]`, the last bag ending at
//! `indices.len()`. An empty bag reduces to the zero vector in both
//! modes.
//!
//! # Determinism
//!
//! Forward: each bag is produced by exactly one pool task and its
//! summation order is the request's index order, so results are
//! bit-identical at any thread count. Backward: the mini inverse map
//! fixes each bucket's cell order to the batch scan order, buckets are
//! accumulated sequentially within disjoint bucket ranges
//! ([`crate::rt::pool::run_parts`] over `split_at_mut` spans), so `∂w`
//! is bit-identical at any thread count in *both* reduction modes —
//! the same contract as `nn::layers::inverse_weight_grad`.

use crate::hash::{bucket_sign, layer_seeds};
use crate::model::{BagMode, ModelError, ModelSpec, ParamStore};
use crate::tensor::Matrix;

use super::TrainOptions;

/// Below this many hash+multiply-add cells a call stays single-threaded
/// (same spawn-amortization bar as `nn::layers`).
const PAR_WORK_THRESHOLD: usize = 1 << 21;

/// A hashed embedding bag: `k` stored weights standing in for a
/// `num_categories × dim` virtual table. See the module docs.
#[derive(Debug, Clone)]
pub struct EmbedBag {
    pub num_categories: usize,
    pub dim: usize,
    pub mode: BagMode,
    pub seed_base: u32,
    /// Bucket hash seed (`h` of §4.2, layer index 0).
    seed_h: u32,
    /// Sign hash seed (`ξ` of §4.2).
    seed_xi: u32,
    /// The stored bucket array — the entire model (`len == k`). A
    /// [`ParamStore`] so a served bag can borrow the buckets straight
    /// out of an mmap'd bundle; training writes copy-on-write.
    pub w: ParamStore,
}

impl EmbedBag {
    /// Build with zeroed weights.
    pub fn new(num_categories: usize, dim: usize, k: usize, mode: BagMode, seed_base: u32) -> EmbedBag {
        Self::build(num_categories, dim, mode, seed_base, vec![0.0; k].into())
    }

    /// The one real constructor: every path (zeroed, owned tensor,
    /// mapped tensor) funnels through the same shape assertions.
    fn build(
        num_categories: usize,
        dim: usize,
        mode: BagMode,
        seed_base: u32,
        w: ParamStore,
    ) -> EmbedBag {
        assert!(num_categories > 0 && dim > 0 && !w.is_empty(), "zero embedding shape");
        assert!(
            num_categories.checked_mul(dim).is_some_and(|c| c <= u32::MAX as usize),
            "virtual table exceeds the u32 cell-key space"
        );
        let (seed_h, seed_xi) = layer_seeds(0, seed_base);
        EmbedBag { num_categories, dim, mode, seed_base, seed_h, seed_xi, w }
    }

    /// He-style init matching `Layer::init`'s hashed arm (fan-in = dim).
    pub fn init(&mut self, rng: &mut crate::util::rng::Pcg32) {
        let std = (2.0 / self.dim as f32).sqrt();
        rng.fill_normal(&mut self.w, std);
    }

    /// Build from a spec + its single parameter tensor (bundle load).
    pub fn from_spec(spec: &ModelSpec, w: Vec<f32>) -> Result<EmbedBag, ModelError> {
        Self::from_store(spec, w.into())
    }

    /// [`EmbedBag::from_spec`] generalized over the buffer's home:
    /// accepts a mapped store, so the zero-copy load path
    /// (`EmbedBag::from_bundle_map`) never materializes the buckets.
    pub fn from_store(spec: &ModelSpec, w: ParamStore) -> Result<EmbedBag, ModelError> {
        let Some((nc, dim, k, mode)) = spec.embedding_shape() else {
            return Err(ModelError::InvalidSpec(format!(
                "method '{}' is not an embedding spec",
                spec.method.as_str()
            )));
        };
        if w.len() != k {
            return Err(ModelError::ShapeMismatch(format!(
                "embedding weights: expected {k} values, got {}",
                w.len()
            )));
        }
        Ok(EmbedBag::build(nc, dim, mode, spec.seed_base, w))
    }

    pub fn k(&self) -> usize {
        self.w.len()
    }

    /// The bucket/sign mapping of virtual cell `(row, col)` — lazy
    /// twin of `HashPlan`'s packed entry, computed per lookup.
    #[inline]
    pub fn cell(&self, row: usize, col: usize) -> (usize, f32) {
        let (b, sign) =
            bucket_sign(row as u32, col as u32, self.dim as u32, self.w.len() as u32, self.seed_h, self.seed_xi);
        (b as usize, sign)
    }

    /// Decompress virtual row `row` into `out` (`len == dim`):
    /// `out[c] = ξ(row,c)·w[h(row,c)]`. The contiguous-over-`dim`
    /// primitive both forward and backward are built on.
    #[inline]
    pub fn decompress_row_into(&self, row: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        for (c, o) in out.iter_mut().enumerate() {
            let (b, sign) = self.cell(row, c);
            *o = sign * self.w[b];
        }
    }

    /// Validate a CSR `indices`/`offsets` request against this table.
    /// Returns the bag count, or a human-readable reason (`bad_input`
    /// at the wire) — the same checks every entry path applies, so
    /// JSON and binary requests fail identically.
    pub fn validate_bags(&self, indices: &[u32], offsets: &[u32]) -> Result<usize, String> {
        validate_bags(indices, offsets, self.num_categories)
    }

    /// Forward bag lookup (Eq. 8 over one-hot bags): returns a
    /// `(n_bags × dim)` matrix, bag `i` reduced over
    /// `indices[offsets[i]..offsets[i+1]]`. Bags are split across pool
    /// tasks when the total work clears the spawn-amortization bar;
    /// each bag is computed by exactly one task in request order, so
    /// the result is bit-identical at any thread count.
    pub fn forward(&self, indices: &[u32], offsets: &[u32]) -> Matrix {
        let n_bags = offsets.len();
        let dim = self.dim;
        let mut z = Matrix::zeros(n_bags, dim);
        if n_bags == 0 {
            return z;
        }
        let work = indices.len() * dim;
        let threads = if work < PAR_WORK_THRESHOLD {
            1
        } else {
            crate::rt::pool::max_concurrency().min(n_bags).max(1)
        };
        let bags_per = n_bags.div_ceil(threads);
        crate::rt::pool::run_parts(
            z.data.chunks_mut(bags_per * dim).collect(),
            |t, chunk: &mut [f32]| {
                let bag0 = t * bags_per;
                for (bi, zrow) in chunk.chunks_mut(dim).enumerate() {
                    self.forward_bag_into(indices, offsets, bag0 + bi, zrow);
                }
            },
        );
        z
    }

    /// One bag's gather-reduce into `zrow` (`len == dim`). The inner
    /// loop is contiguous over `dim` per row — one hash + one fused
    /// multiply-add per column.
    fn forward_bag_into(&self, indices: &[u32], offsets: &[u32], bag: usize, zrow: &mut [f32]) {
        let (start, end) = bag_bounds(indices.len(), offsets, bag);
        for &r in &indices[start..end] {
            let r = r as usize;
            for (c, z) in zrow.iter_mut().enumerate() {
                let (b, sign) = self.cell(r, c);
                *z += sign * self.w[b];
            }
        }
        if self.mode == BagMode::Mean && end > start {
            let inv = 1.0 / (end - start) as f32;
            zrow.iter_mut().for_each(|z| *z *= inv);
        }
    }

    /// Backward (Eq. 12 restricted to the batch's touched cells):
    /// accumulates `∂L/∂w` into `grad` given `delta` (`n_bags × dim`,
    /// `∂L/∂z`). There is no input gradient — bag indices are discrete.
    ///
    /// A per-batch **mini inverse map** is built by counting sort over
    /// only the `total_indices × dim` cells this batch touches (the
    /// full-table `InversePlan` would be `num_categories × dim` and is
    /// exactly what this type exists to avoid). Buckets are then
    /// accumulated **sequentially** per bucket, parallel over disjoint
    /// bucket ranges balanced by cell count — no partial buffers, no
    /// scatter, and `∂w` is bit-identical at any thread count in both
    /// reduction modes. `opts` only sizes the worker count.
    pub fn backward(
        &self,
        indices: &[u32],
        offsets: &[u32],
        delta: &Matrix,
        grad: &mut [f32],
        opts: &TrainOptions,
    ) {
        let k = self.w.len();
        assert_eq!(grad.len(), k);
        let dim = self.dim;
        let n_bags = offsets.len();
        assert_eq!((delta.rows, delta.cols), (n_bags, dim), "delta shape");
        let n_cells = indices.len() * dim;
        if n_cells == 0 {
            return;
        }

        // Pass 1 — hash every touched cell once and record its bucket
        // and signed contribution ξ(r,c)·δ_{bag,c} (mean mode folds the
        // 1/|bag| into the contribution). Disjoint per-index spans, so
        // this pass parallelizes freely.
        let mut buckets = vec![0u32; n_cells];
        let mut contrib = vec![0.0f32; n_cells];
        // per flat index position: which bag it belongs to + its scale
        let mut pos_bag: Vec<(u32, f32)> = Vec::with_capacity(indices.len());
        for bag in 0..n_bags {
            let (start, end) = bag_bounds(indices.len(), offsets, bag);
            let scale = match self.mode {
                BagMode::Sum => 1.0,
                BagMode::Mean if end > start => 1.0 / (end - start) as f32,
                BagMode::Mean => 0.0,
            };
            for _ in start..end {
                pos_bag.push((bag as u32, scale));
            }
        }
        debug_assert_eq!(pos_bag.len(), indices.len());
        let threads = if n_cells < PAR_WORK_THRESHOLD {
            1
        } else {
            opts.resolved_threads().min(indices.len()).max(1)
        };
        let per = indices.len().div_ceil(threads);
        let bucket_parts: Vec<(usize, &mut [u32], &mut [f32])> = buckets
            .chunks_mut(per * dim)
            .zip(contrib.chunks_mut(per * dim))
            .enumerate()
            .map(|(t, (bc, cc))| (t * per, bc, cc))
            .collect();
        crate::rt::pool::run_parts(
            bucket_parts,
            |_t, (p0, bchunk, cchunk): (usize, &mut [u32], &mut [f32])| {
                for (pi, (brow, crow)) in
                    bchunk.chunks_mut(dim).zip(cchunk.chunks_mut(dim)).enumerate()
                {
                    let p = p0 + pi;
                    let r = indices[p] as usize;
                    let (bag, scale) = pos_bag[p];
                    let drow = delta.row(bag as usize);
                    for c in 0..dim {
                        let (b, sign) = self.cell(r, c);
                        brow[c] = b as u32;
                        crow[c] = sign * scale * drow[c];
                    }
                }
            },
        );

        // Pass 2 — counting sort into a mini CSR by bucket: counts →
        // prefix starts → cell placement in scan order (sequential so
        // every bucket's cell order is the batch scan order, which is
        // what makes the reduction order thread-count-independent).
        let mut counts = vec![0u32; k];
        for &b in &buckets {
            counts[b as usize] += 1;
        }
        let mut starts = vec![0u32; k + 1];
        for b in 0..k {
            starts[b + 1] = starts[b] + counts[b];
        }
        let mut cursor = starts[..k].to_vec();
        let mut sorted = vec![0.0f32; n_cells];
        for (p, &b) in buckets.iter().enumerate() {
            let slot = cursor[b as usize];
            sorted[slot as usize] = contrib[p];
            cursor[b as usize] = slot + 1;
        }

        // Pass 3 — Eq. 12: one sequential accumulation per bucket,
        // parallel over bucket ranges of roughly equal cell count
        // writing disjoint grad spans.
        let bounds = balanced_bucket_ranges(&starts, threads.min(k));
        let mut parts: Vec<(usize, &mut [f32])> = Vec::with_capacity(bounds.len() - 1);
        let mut rest = grad;
        let mut prev = 0usize;
        for &b in &bounds[1..] {
            let (head, tail) = rest.split_at_mut(b - prev);
            parts.push((prev, head));
            rest = tail;
            prev = b;
        }
        crate::rt::pool::run_parts(parts, |_t, (k0, gpart): (usize, &mut [f32])| {
            for (kk, g) in gpart.iter_mut().enumerate() {
                let b = k0 + kk;
                let (s, e) = (starts[b] as usize, starts[b + 1] as usize);
                if s == e {
                    continue;
                }
                let mut acc = 0.0f32;
                for &v in &sorted[s..e] {
                    acc += v;
                }
                *g += acc;
            }
        });
    }

    /// One SGD step on a batch of bags against dense targets
    /// (`n_bags × dim`), squared-error loss `½‖z − y‖²`. Returns the
    /// batch loss. The demo training loop `hashednets train` drives for
    /// embedding specs — the full cross-entropy stack stays with
    /// `Network`.
    pub fn sgd_step(
        &mut self,
        indices: &[u32],
        offsets: &[u32],
        targets: &Matrix,
        lr: f32,
        opts: &TrainOptions,
    ) -> f32 {
        let z = self.forward(indices, offsets);
        assert_eq!((z.rows, z.cols), (targets.rows, targets.cols));
        let mut delta = z;
        let mut loss = 0.0f32;
        for (d, &y) in delta.data.iter_mut().zip(&targets.data) {
            *d -= y;
            loss += 0.5 * *d * *d;
        }
        let mut grad = vec![0.0f32; self.w.len()];
        self.backward(indices, offsets, &delta, &mut grad, opts);
        for (w, g) in self.w.iter_mut().zip(&grad) {
            *w -= lr * g;
        }
        loss / (targets.rows.max(1) as f32)
    }
}

/// Bag `bag`'s index span: `offsets[bag] .. offsets[bag+1]` (the last
/// bag ends at `n_indices`). Callers validate monotonicity first.
#[inline]
fn bag_bounds(n_indices: usize, offsets: &[u32], bag: usize) -> (usize, usize) {
    let start = offsets[bag] as usize;
    let end = offsets.get(bag + 1).map(|&o| o as usize).unwrap_or(n_indices);
    (start, end)
}

/// Structural + range validation of a CSR bag request, shared by every
/// entry path (JSON, binary, CLI) so the failure taxonomy is identical:
/// offsets must start at 0, be monotone non-decreasing and stay within
/// `indices`; every index must be `< num_categories`.
pub fn validate_bags(indices: &[u32], offsets: &[u32], num_categories: usize) -> Result<usize, String> {
    if offsets.is_empty() {
        return Err("offsets must contain at least one bag start".into());
    }
    if offsets[0] != 0 {
        return Err(format!("offsets must start at 0, got {}", offsets[0]));
    }
    let mut prev = 0u32;
    for &o in offsets {
        if o < prev {
            return Err(format!("offsets must be non-decreasing ({prev} then {o})"));
        }
        prev = o;
    }
    if prev as usize > indices.len() {
        return Err(format!(
            "offset {prev} exceeds {} indices",
            indices.len()
        ));
    }
    if let Some(&bad) = indices.iter().find(|&&i| i as usize >= num_categories) {
        return Err(format!("index {bad} out of range (num_categories = {num_categories})"));
    }
    Ok(offsets.len())
}

/// Split buckets `0..k` into `parts` contiguous ranges of roughly equal
/// cell count, given the CSR `starts` array (`len == k+1`). Returns the
/// range boundaries (`parts+1` entries, first 0, last `k`) — the mini
/// twin of `InversePlan::balanced_ranges`.
fn balanced_bucket_ranges(starts: &[u32], parts: usize) -> Vec<usize> {
    let k = starts.len() - 1;
    let parts = parts.clamp(1, k.max(1));
    let total = starts[k] as usize;
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0);
    let per = total.div_ceil(parts).max(1);
    let mut next_target = per;
    for b in 0..k {
        if bounds.len() == parts {
            break;
        }
        if starts[b + 1] as usize >= next_target && b + 1 < k {
            bounds.push(b + 1);
            next_target = (bounds.len()) * per;
        }
    }
    while bounds.len() < parts {
        bounds.push(k);
    }
    bounds.push(k);
    // ensure monotone (degenerate distributions can stall the cursor)
    for i in 1..bounds.len() {
        if bounds[i] < bounds[i - 1] {
            bounds[i] = bounds[i - 1];
        }
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn bag(nc: usize, dim: usize, k: usize, mode: BagMode) -> EmbedBag {
        let mut e = EmbedBag::new(nc, dim, k, mode, crate::hash::DEFAULT_SEED_BASE);
        let mut rng = Pcg32::new(41, 41);
        e.init(&mut rng);
        e
    }

    /// Reference: materialize the virtual table (small shapes only).
    fn dense_table(e: &EmbedBag) -> Matrix {
        let mut t = Matrix::zeros(e.num_categories, e.dim);
        for r in 0..e.num_categories {
            e.decompress_row_into(r, t.row_mut(r));
        }
        t
    }

    #[test]
    fn forward_matches_materialized_table_bit_exact() {
        for mode in [BagMode::Sum, BagMode::Mean] {
            let e = bag(100, 16, 37, mode);
            let t = dense_table(&e);
            let indices: Vec<u32> = vec![3, 99, 0, 7, 7, 42, 13];
            let offsets: Vec<u32> = vec![0, 3, 3, 5]; // bag 1 empty, last bag len 2 (+tail)
            let z = e.forward(&indices, &offsets);
            assert_eq!((z.rows, z.cols), (4, 16));
            for b in 0..4 {
                let (s, en) = bag_bounds(indices.len(), &offsets, b);
                let mut want = vec![0.0f32; 16];
                for &r in &indices[s..en] {
                    for (w, &v) in want.iter_mut().zip(t.row(r as usize)) {
                        *w += v;
                    }
                }
                if mode == BagMode::Mean && en > s {
                    want.iter_mut().for_each(|w| *w /= (en - s) as f32);
                }
                assert_eq!(
                    z.row(b).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "bag {b} mode {mode:?}"
                );
            }
            // empty bag is exactly zero
            assert!(z.row(1).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn forward_is_thread_count_invariant() {
        // force the parallel path with a large-enough workload and
        // compare against the serial answer computed bag by bag
        let e = bag(10_000, 64, 257, BagMode::Sum);
        let mut rng = Pcg32::new(5, 5);
        let n_bags = 600usize;
        let mut indices = Vec::new();
        let mut offsets = Vec::with_capacity(n_bags);
        for _ in 0..n_bags {
            offsets.push(indices.len() as u32);
            for _ in 0..(rng.next_u32() % 120) {
                indices.push(rng.next_u32() % 10_000);
            }
        }
        let par = e.forward(&indices, &offsets);
        let mut serial = Matrix::zeros(n_bags, 64);
        for b in 0..n_bags {
            e.forward_bag_into(&indices, &offsets, b, serial.row_mut(b));
        }
        assert_eq!(
            par.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            serial.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn backward_matches_finite_difference() {
        for mode in [BagMode::Sum, BagMode::Mean] {
            let mut e = bag(50, 6, 23, mode);
            let indices: Vec<u32> = vec![1, 4, 4, 49, 0, 17];
            let offsets: Vec<u32> = vec![0, 2, 2, 4];
            let mut rng = Pcg32::new(3, 3);
            let co = Matrix::from_fn(4, 6, |_, _| rng.normal());
            let loss = |e: &EmbedBag| -> f32 {
                e.forward(&indices, &offsets)
                    .data
                    .iter()
                    .zip(&co.data)
                    .map(|(z, c)| z * c)
                    .sum()
            };
            let mut grad = vec![0.0f32; e.k()];
            e.backward(&indices, &offsets, &co, &mut grad, &TrainOptions::default());
            let eps = 1e-2f32;
            for p in 0..e.k() {
                let orig = e.w[p];
                e.w[p] = orig + eps;
                let lp = loss(&e);
                e.w[p] = orig - eps;
                let lm = loss(&e);
                e.w[p] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                assert!(
                    (fd - grad[p]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "mode {mode:?} param {p}: fd {fd} vs ad {}",
                    grad[p]
                );
            }
        }
    }

    #[test]
    fn backward_is_bit_identical_across_thread_counts() {
        // big enough to clear PAR_WORK_THRESHOLD so the pool actually
        // engages; ∂w must match threads=1 bit for bit in both modes
        let e = bag(100_000, 64, 1024, BagMode::Sum);
        let mut rng = Pcg32::new(77, 7);
        let n_bags = 400usize;
        let mut indices = Vec::new();
        let mut offsets = Vec::with_capacity(n_bags);
        for _ in 0..n_bags {
            offsets.push(indices.len() as u32);
            for _ in 0..(10 + rng.next_u32() % 150) {
                indices.push(rng.next_u32() % 100_000);
            }
        }
        let delta = Matrix::from_fn(n_bags, 64, |_, _| rng.normal());
        let grad_with = |opts: TrainOptions| -> Vec<u32> {
            let mut g = vec![0.0f32; e.k()];
            e.backward(&indices, &offsets, &delta, &mut g, &opts);
            g.iter().map(|v| v.to_bits()).collect()
        };
        let g1 = grad_with(TrainOptions::with_threads(1));
        for t in [2usize, 4, 8] {
            assert_eq!(g1, grad_with(TrainOptions::with_threads(t)), "fast t{t}");
            assert_eq!(g1, grad_with(TrainOptions::with_threads(t).ordered()), "ordered t{t}");
        }
    }

    #[test]
    fn validate_bags_catches_malformed_requests() {
        assert!(validate_bags(&[1, 2], &[], 10).is_err()); // no bags
        assert!(validate_bags(&[1, 2], &[1, 2], 10).is_err()); // must start at 0
        assert!(validate_bags(&[1, 2], &[0, 2, 1], 10).is_err()); // decreasing
        assert!(validate_bags(&[1, 2], &[0, 3], 10).is_err()); // past the end
        assert!(validate_bags(&[1, 10], &[0, 1], 10).is_err()); // index out of range
        assert_eq!(validate_bags(&[1, 2], &[0, 2], 10), Ok(2));
        assert_eq!(validate_bags(&[], &[0, 0, 0], 10), Ok(3)); // all-empty bags
    }

    #[test]
    fn sgd_step_reduces_loss() {
        let mut e = bag(200, 8, 31, BagMode::Mean);
        let mut rng = Pcg32::new(9, 1);
        let indices: Vec<u32> = (0..60).map(|_| rng.next_u32() % 200).collect();
        let offsets: Vec<u32> = (0..12).map(|b| (b * 5) as u32).collect();
        let targets = Matrix::from_fn(12, 8, |_, _| rng.normal());
        let opts = TrainOptions::default();
        let l0 = e.sgd_step(&indices, &offsets, &targets, 0.05, &opts);
        let mut l = l0;
        for _ in 0..50 {
            l = e.sgd_step(&indices, &offsets, &targets, 0.05, &opts);
        }
        assert!(l < 0.5 * l0, "loss did not drop: {l0} -> {l}");
    }

    #[test]
    fn balanced_ranges_cover_all_buckets() {
        // uniform counts
        let starts: Vec<u32> = (0..=16u32).map(|b| b * 4).collect();
        for parts in [1usize, 2, 3, 5, 16, 40] {
            let bounds = balanced_bucket_ranges(&starts, parts);
            assert_eq!(*bounds.first().unwrap(), 0);
            assert_eq!(*bounds.last().unwrap(), 16);
            for w in bounds.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
        // heavily skewed: everything in bucket 0
        let skew: Vec<u32> = (0..=8u32).map(|b| if b == 0 { 0 } else { 100 }).collect();
        let bounds = balanced_bucket_ranges(&skew, 4);
        assert_eq!(*bounds.last().unwrap(), 8);
    }

    #[test]
    fn resident_memory_is_bounded_by_k_not_the_virtual_table() {
        // 1M × 64 virtual cells (256 MB as f32) backed by 4096 weights;
        // construction + a lookup must not allocate the table
        let e = EmbedBag::new(1_000_000, 64, 4096, BagMode::Sum, 1);
        assert_eq!(e.k(), 4096);
        let z = e.forward(&[999_999, 0, 123_456], &[0, 3]);
        assert_eq!((z.rows, z.cols), (1, 64));
    }
}
