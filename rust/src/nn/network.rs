//! Multi-layer network: forward/backward with ReLU + inverted dropout,
//! softmax cross-entropy (± dark-knowledge soft targets), SGD+momentum.
//!
//! `train_step` / `fit` take a [`TrainOptions`] that controls the
//! threaded backward (worker count + reduction order); the default is
//! the historical single-thread behavior, and ordered mode makes the
//! trained parameters bit-identical across thread counts — see
//! `nn::layers::TrainOptions` for the contract.

use super::layers::{Layer, LayerKind, TrainOptions};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Training hyperparameters (paper §6: SGD, minibatch 50, dropout,
/// momentum; tuned per method).
#[derive(Debug, Clone, Copy)]
pub struct TrainHyper {
    pub lr: f32,
    pub momentum: f32,
    pub keep_prob: f32,
    /// DK blend weight on the hard-label term (1.0 = no soft targets).
    pub lam: f32,
    /// DK temperature.
    pub temp: f32,
}

impl Default for TrainHyper {
    fn default() -> Self {
        TrainHyper { lr: 0.1, momentum: 0.9, keep_prob: 0.9, lam: 1.0, temp: 4.0 }
    }
}

/// Teacher soft targets for dark-knowledge training (temperature-softened
/// probabilities, one row per training example).
pub struct DkTargets {
    pub probs: Matrix,
}

/// A feed-forward network of [`Layer`]s with momentum buffers.
pub struct Network {
    pub layers: Vec<Layer>,
    momenta: Vec<Vec<f32>>,
}

impl Network {
    pub fn new(layers: Vec<Layer>) -> Network {
        let momenta = layers.iter().map(|l| vec![0.0; l.params.len()]).collect();
        Network { layers, momenta }
    }

    /// Build from virtual dims + per-layer kinds.
    pub fn from_dims(dims: &[usize], kinds: Vec<LayerKind>, seed_base: u32) -> Network {
        assert_eq!(dims.len() - 1, kinds.len());
        let layers = kinds
            .into_iter()
            .enumerate()
            .map(|(l, kind)| Layer::new(dims[l], dims[l + 1], kind, l, seed_base))
            .collect();
        Network::new(layers)
    }

    pub fn init(&mut self, rng: &mut Pcg32) {
        for l in &mut self.layers {
            l.init(rng);
        }
    }

    pub fn stored_params(&self) -> usize {
        self.layers.iter().map(Layer::n_stored).sum()
    }

    /// Input width (virtual columns of the first layer).
    pub fn n_in(&self) -> usize {
        self.layers.first().map(|l| l.m).unwrap_or(0)
    }

    /// Output width (rows of the last layer — the logit count).
    pub fn n_out(&self) -> usize {
        self.layers.last().map(|l| l.n).unwrap_or(0)
    }

    /// Pre-build every hashed layer's inverse plan (the lazily-built
    /// CSR-by-bucket view behind the batch-1 forward kernel and the
    /// Eq. 12 gradient). Serving engines call this at model build /
    /// hot-load time so the first single-row request never pays the
    /// counting-sort construction inline.
    pub fn warm(&self) {
        for l in &self.layers {
            if let Some(plan) = l.plan() {
                plan.inverse();
            }
        }
    }

    /// Inference forward pass (no dropout).
    ///
    /// Takes `&self`: hashed layers read their shared `Arc<HashPlan>`,
    /// so one network can serve predictions from many threads
    /// concurrently without locks or cloning the parameters.
    pub fn predict(&self, x: &Matrix) -> Matrix {
        let mut a = x.clone();
        let n_layers = self.layers.len();
        for (l, layer) in self.layers.iter().enumerate() {
            let z = layer.forward(&a);
            a = if l < n_layers - 1 { z.map(|v| v.max(0.0)) } else { z };
        }
        a
    }

    /// Classification error rate in [0,1] on labeled data.
    pub fn error_rate(&self, x: &Matrix, labels: &[u8]) -> f64 {
        let logits = self.predict(x);
        let pred = logits.argmax_rows();
        let wrong = pred.iter().zip(labels).filter(|(p, l)| **p != **l as usize).count();
        wrong as f64 / labels.len() as f64
    }

    /// One SGD-with-momentum step on a minibatch. Returns the loss.
    ///
    /// Matches the artifact `train_step` semantics: inverted dropout on
    /// hidden activations, mean CE loss, `v' = mom·v − lr·g, p += v'`.
    /// `opts` drives the threaded backward ([`Layer::backward`]).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &mut self,
        x: &Matrix,
        y: &[i32],
        soft: Option<(&DkTargets, &[u32])>, // (targets, row indices into probs)
        hyper: &TrainHyper,
        opts: &TrainOptions,
        rng: &mut Pcg32,
    ) -> f32 {
        let batch = x.rows;
        let n_layers = self.layers.len();

        // ---- forward, stashing inputs & dropout masks -----------------
        let mut inputs: Vec<Matrix> = Vec::with_capacity(n_layers);
        let mut masks: Vec<Vec<f32>> = Vec::with_capacity(n_layers - 1);
        let mut a = x.clone();
        for l in 0..n_layers {
            inputs.push(a.clone());
            let z = self.layers[l].forward(&a);
            if l < n_layers - 1 {
                let mut act = z.map(|v| v.max(0.0));
                let mut mask = vec![0.0f32; act.data.len()];
                for (mv, av) in mask.iter_mut().zip(act.data.iter_mut()) {
                    if rng.next_f32() < hyper.keep_prob {
                        *mv = 1.0 / hyper.keep_prob;
                        *av *= *mv;
                    } else {
                        *av = 0.0;
                    }
                }
                masks.push(mask);
                a = act;
            } else {
                a = z;
            }
        }
        let logits = a;

        // ---- loss & output delta --------------------------------------
        let probs = logits.softmax_rows();
        let mut loss = 0.0f32;
        for b in 0..batch {
            loss -= (probs.at(b, y[b] as usize)).max(1e-12).ln();
        }
        loss /= batch as f32;
        // delta = (softmax − onehot)/B
        let mut delta = probs.clone();
        for b in 0..batch {
            *delta.at_mut(b, y[b] as usize) -= 1.0;
        }
        delta.scale(1.0 / batch as f32);

        if let Some((dk, rows)) = soft {
            if hyper.lam < 1.0 {
                // blended objective: lam·CE(y) + (1−lam)·T²·CE(teacher_T, student_T)
                let t = hyper.temp;
                let logits_t = logits.map(|v| v); // copy
                let mut lt = logits_t;
                lt.scale(1.0 / t);
                let probs_t = lt.softmax_rows();
                let mut soft_loss = 0.0f32;
                let mut soft_delta = Matrix::zeros(batch, delta.cols);
                for b in 0..batch {
                    let target = dk.probs.row(rows[b % rows.len()] as usize);
                    for c in 0..delta.cols {
                        soft_loss -= target[c] * probs_t.at(b, c).max(1e-12).ln();
                        // d/dlogits of T²·CE(target, softmax(z/T)) = T·(p_T − target)
                        *soft_delta.at_mut(b, c) += t * (probs_t.at(b, c) - target[c]);
                    }
                }
                soft_loss /= batch as f32;
                soft_delta.scale(1.0 / batch as f32);
                loss = hyper.lam * loss + (1.0 - hyper.lam) * t * t * soft_loss;
                delta.scale(hyper.lam);
                soft_delta.scale(1.0 - hyper.lam);
                delta.add_assign(&soft_delta);
            }
        }

        // ---- backward ---------------------------------------------------
        let mut d = delta;
        for l in (0..n_layers).rev() {
            let mut grad = vec![0.0f32; self.layers[l].params.len()];
            let mut da = self.layers[l].backward(&inputs[l], &d, &mut grad, opts);
            // momentum update
            let (layer, mom) = (&mut self.layers[l], &mut self.momenta[l]);
            for ((p, v), g) in layer.params.iter_mut().zip(mom.iter_mut()).zip(&grad) {
                *v = hyper.momentum * *v - hyper.lr * g;
                *p += *v;
            }
            if l > 0 {
                // through dropout mask and ReLU of the previous layer
                let mask = &masks[l - 1];
                let prev_in = &inputs[l]; // activations after relu+dropout
                for (idx, dv) in da.data.iter_mut().enumerate() {
                    // relu' is 1 where the post-dropout activation > 0
                    *dv *= if prev_in.data[idx] > 0.0 { mask[idx] } else { 0.0 };
                }
                d = da;
            }
        }
        loss
    }

    /// Train for `epochs` over `(x, labels)` with shuffled minibatches.
    /// Returns per-epoch mean losses. `opts` drives the threaded
    /// backward; ordered mode makes the result thread-count-invariant.
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        &mut self,
        x: &Matrix,
        labels: &[u8],
        batch: usize,
        epochs: usize,
        hyper: &TrainHyper,
        opts: &TrainOptions,
        dk: Option<&DkTargets>,
        rng: &mut Pcg32,
    ) -> Vec<f32> {
        let n = labels.len();
        let mut epoch_losses = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            let perm = rng.permutation(n);
            let mut total = 0.0f32;
            let mut count = 0;
            for chunk in perm.chunks(batch) {
                let (bx, by) = gather(x, labels, chunk, batch);
                let soft = dk.map(|t| (t, chunk));
                total += self.train_step(&bx, &by, soft, hyper, opts, rng);
                count += 1;
            }
            epoch_losses.push(total / count as f32);
        }
        epoch_losses
    }
}

fn gather(x: &Matrix, labels: &[u8], idx: &[u32], batch: usize) -> (Matrix, Vec<i32>) {
    let mut bx = Matrix::zeros(batch, x.cols);
    let mut by = vec![0i32; batch];
    for b in 0..batch {
        let i = idx[b % idx.len()] as usize;
        bx.row_mut(b).copy_from_slice(x.row(i));
        by[b] = labels[i] as i32;
    }
    (bx, by)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Kind, Split};

    fn toy_net(kinds: Vec<LayerKind>, dims: &[usize]) -> Network {
        let mut net = Network::from_dims(dims, kinds, crate::hash::DEFAULT_SEED_BASE);
        let mut rng = Pcg32::new(42, 0);
        net.init(&mut rng);
        net
    }

    #[test]
    fn loss_decreases_all_kinds() {
        let ds = generate(Kind::Basic, Split::Train, 200, 5);
        for kinds in [
            vec![LayerKind::Dense, LayerKind::Dense],
            vec![LayerKind::Hashed { k: 3000 }, LayerKind::Hashed { k: 120 }],
            vec![LayerKind::Masked { k: 6000 }, LayerKind::Masked { k: 150 }],
            vec![LayerKind::LowRank { r: 6 }, LayerKind::LowRank { r: 4 }],
        ] {
            let mut net = toy_net(kinds.clone(), &[784, 24, 10]);
            let mut rng = Pcg32::new(1, 2);
            // LRD learns slowly through its fixed random projection —
            // it needs a hotter lr to make visible progress in 10 epochs
            let lr = if matches!(kinds[0], LayerKind::LowRank { .. }) { 0.3 } else { 0.05 };
            let hyper = TrainHyper { lr, keep_prob: 1.0, ..Default::default() };
            let losses =
                net.fit(&ds.images, &ds.labels, 50, 10, &hyper, &TrainOptions::default(), None, &mut rng);
            assert!(
                losses.last().unwrap() < &(losses[0] * 0.85),
                "{kinds:?}: {losses:?}"
            );
        }
    }

    #[test]
    fn trained_hashnet_beats_chance() {
        let tr = generate(Kind::Basic, Split::Train, 600, 5);
        let te = generate(Kind::Basic, Split::Test, 300, 5);
        let mut net = toy_net(
            vec![LayerKind::Hashed { k: 6000 }, LayerKind::Hashed { k: 300 }],
            &[784, 32, 10],
        );
        let mut rng = Pcg32::new(2, 3);
        let hyper = TrainHyper { lr: 0.08, keep_prob: 0.95, ..Default::default() };
        net.fit(&tr.images, &tr.labels, 50, 15, &hyper, &TrainOptions::default(), None, &mut rng);
        let err = net.error_rate(&te.images, &te.labels);
        assert!(err < 0.5, "test error {err} vs chance 0.9");
    }

    #[test]
    fn dropout_keep1_is_deterministic_in_eval() {
        let net = toy_net(vec![LayerKind::Dense, LayerKind::Dense], &[10, 8, 3]);
        let x = Matrix::from_fn(4, 10, |i, j| (i + j) as f32 * 0.1);
        let a = net.predict(&x);
        let b = net.predict(&x);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn concurrent_predict_shares_one_network() {
        // &self predict + Arc<HashPlan> lets N callers serve one model
        // with no locks and no parameter clones — results must be
        // bit-identical to the serial path. Sharded across the shared
        // PoolExec, the same substrate the serve workers ride.
        let net = toy_net(
            vec![LayerKind::Hashed { k: 500 }, LayerKind::Hashed { k: 60 }],
            &[784, 16, 10],
        );
        let x = Matrix::from_fn(8, 784, |i, j| ((i * 31 + j) % 17) as f32 * 0.05);
        let serial = net.predict(&x);
        let mut results: Vec<Option<Matrix>> = (0..4).map(|_| None).collect();
        crate::rt::pool::run_parts(results.iter_mut().collect(), |_t, slot: &mut Option<Matrix>| {
            *slot = Some(net.predict(&x));
        });
        assert_eq!(results.len(), 4);
        for r in results {
            assert_eq!(r.expect("task ran").data, serial.data);
        }
    }

    #[test]
    fn dk_soft_targets_pull_towards_teacher() {
        // teacher says class 2 always; student trained with lam=0 should
        // drift toward predicting class 2 regardless of labels
        let mut net = toy_net(vec![LayerKind::Dense, LayerKind::Dense], &[6, 8, 3]);
        let n = 64;
        let x = Matrix::from_fn(n, 6, |i, j| ((i * 7 + j) % 5) as f32 * 0.2);
        let labels: Vec<u8> = (0..n).map(|i| (i % 3) as u8).collect();
        let mut probs = Matrix::zeros(n, 3);
        for i in 0..n {
            probs.row_mut(i).copy_from_slice(&[0.05, 0.05, 0.9]);
        }
        let dk = DkTargets { probs };
        let hyper = TrainHyper { lr: 0.2, keep_prob: 1.0, lam: 0.0, temp: 1.0, ..Default::default() };
        let mut rng = Pcg32::new(3, 4);
        net.fit(&x, &labels, 16, 30, &hyper, &TrainOptions::default(), Some(&dk), &mut rng);
        let pred = net.predict(&x).argmax_rows();
        let frac2 = pred.iter().filter(|&&p| p == 2).count() as f64 / n as f64;
        assert!(frac2 > 0.9, "teacher not followed: {frac2}");
    }

    #[test]
    fn ordered_training_is_thread_count_invariant() {
        // the determinism contract at network level: same seed, same
        // data, ordered reduction — 1 thread and 4 threads must produce
        // bit-identical parameters after a few epochs
        let ds = generate(Kind::Basic, Split::Train, 120, 9);
        let hyper = TrainHyper { lr: 0.05, keep_prob: 0.9, ..Default::default() };
        let params_with = |threads: usize| -> Vec<Vec<u32>> {
            let mut net = toy_net(
                vec![LayerKind::Hashed { k: 900 }, LayerKind::Hashed { k: 70 }],
                &[784, 12, 10],
            );
            let mut rng = Pcg32::new(5, 6);
            // block_rows 4 < hidden width 12 forces a multi-block
            // partition, so the ordered reduction is actually exercised
            let opts = TrainOptions { threads, block_rows: 4, deterministic: true };
            net.fit(&ds.images, &ds.labels, 20, 2, &hyper, &opts, None, &mut rng);
            net.layers
                .iter()
                .map(|l| l.params.iter().map(|p| p.to_bits()).collect())
                .collect()
        };
        assert_eq!(params_with(1), params_with(4));
    }

    #[test]
    fn stored_params_accounting() {
        let net = toy_net(
            vec![LayerKind::Hashed { k: 100 }, LayerKind::Hashed { k: 20 }],
            &[784, 16, 10],
        );
        assert_eq!(net.stored_params(), 120);
    }
}
