//! Layer implementations: dense, hashed (the paper's contribution),
//! masked-dense (RER) and low-rank (LRD).
//!
//! # Mapping to the paper (Chen et al., ICML 2015)
//!
//! | code                                | paper |
//! |-------------------------------------|-------|
//! | [`Layer::forward_hashed_gather`]    | Eq. 8 — `z_i = Σ_j ξ(i,j)·w_{h(i,j)}·a_j`, one gathered read per virtual cell |
//! | [`Layer::forward_hashed_bucket`]    | Eq. 10 — bucket-major: scatter `ξ(i,j)·a_j` into a K-sized accumulator, one streaming dot with `w` |
//! | [`Layer::forward_hashed_inverse`]   | Eq. 10 read off the [`InversePlan`]: for each bucket `k`, add `ξ·w_k·a_j` into `z_i` per cell — `w` streams in order (the B = 1 serving default) |
//! | [`Layer::forward_hashed_scratch`]   | Eq. 7 made batch-amortized: decompress each virtual row `V_i` once, dense dot across the batch |
//! | hashed backward ([`Layer::backward`]) | Eqs. 11 & 12 — `∂L/∂a_j = Σ_i ξ(i,j)·w_{h(i,j)}·δ_i` and `∂L/∂w_k = Σ_{(i,j): h(i,j)=k} ξ(i,j)·a_j·δ_i` (Eq. 12 walks the inverse plan: one sequential write per bucket) |
//! | [`Layer::forward_hashed_tiled`]     | Eq. 7 at tile granularity (the Structured Multi-Hashing direction): contiguous tile runs + full-width 8-lane SIMD dot over `[a|1|0…]` |
//! | tiled backward ([`Layer::backward`]) | Eqs. 11 & 12 over tile runs: `∂a` via [`crate::tensor::simd::axpy8`] rows, `∂w` via sequential per-tile run writes ([`tiled_weight_grad`]) |
//! | `LayerKind::Hashed { k }`           | the per-layer real-weight budget `K^ℓ` (§4.1) |
//! | `LayerKind::HashedTile { k, tile }` | same budget `K^ℓ`, hash domain coarsened from cells to `th×tw` tiles ([`TilePlan`]) |
//! | the ξ sign bit                      | §4.2's sign factor, packed into bit 31 of each [`HashPlan`] / [`TilePlan`] entry |
//!
//! Each layer owns its stored parameters as a flat
//! [`ParamStore`] (owned floats, or a zero-copy borrow of an mmap'd
//! bundle) whose layout matches the corresponding artifact parameter in
//! `artifacts/manifest.json`, so parameters can be moved between the
//! native engine and the PJRT runtime freely.
//!
//! Hashed layers build an immutable [`HashPlan`] eagerly at construction
//! and share it via `Arc`, so every entry point here takes `&self`
//! (`backward` mutates only the caller's gradient buffer): one layer
//! (and so one [`super::Network`]) can serve forward passes from many
//! threads concurrently without locks or cloning. See `hash::plan` for
//! the plan's memory layout and the kernel-variant selection heuristic
//! implemented in [`Layer::forward`].
//!
//! # Threaded backward
//!
//! `Layer::backward` takes a [`TrainOptions`]; everything parallel runs
//! on the shared [`crate::rt::PoolExec`] (parked workers, no per-call
//! spawn/join). The hashed backward splits Eq. 11 and Eq. 12:
//!
//! * **Eq. 12 (`∂w`)** goes through the [`InversePlan`]: first
//!   `S = δᵀ·[a|1]` ([`Matrix::matmul_tn_aug`], bit-identical at any
//!   thread count), then one sequential write per bucket
//!   (`∂w_k += Σ_{cells of k} ξ·S_{ij}`), parallel over disjoint bucket
//!   ranges — **no partial buffers**, and since each bucket's cell
//!   order is fixed by the plan, the result is bit-identical for every
//!   thread count in *both* reduction modes.
//! * **Eq. 11 (`∂a`)** is parallelized over output-row *blocks*, each
//!   block accumulating into a private partial, followed by an
//!   order-preserving chunked reduction into the shared buffer.
//!
//! The dense backward runs its transpose matmuls through the
//! row-parallel [`Matrix::matmul_tn_par`] / [`Matrix::matmul_par`],
//! which are bit-identical to their serial forms at any thread count.
//! Ordered mode (`TrainOptions::deterministic`) fixes the `∂a` block
//! partition and reduction order independently of the thread count, so
//! `--threads N` reproduces `--threads 1` bit for bit — see
//! [`TrainOptions`] for the exact contract.

use crate::hash::{hash_gaussian, hash_uniform, layer_seeds, plan::InversePlan, HashPlan, TilePlan};
use crate::model::ParamStore;
use crate::tensor::{dot_unrolled, simd, Matrix};
use crate::util::rng::Pcg32;
use std::sync::Arc;

/// Below this many multiply-adds a kernel stays single-threaded (even
/// pool dispatch costs a queue push and a wakeup).
const PAR_WORK_THRESHOLD: usize = 1 << 21;

/// Worker count for a parallel forward kernel: the shared pool's lane
/// count ([`crate::rt::pool::max_concurrency`], machine-capped at 8 —
/// diminishing returns on a memory-bound kernel), capped by the number
/// of output rows.
fn par_threads(work: usize, rows: usize) -> usize {
    if work < PAR_WORK_THRESHOLD {
        return 1;
    }
    crate::rt::pool::max_concurrency().min(rows).max(1)
}

/// Execution policy for the training path — how [`Layer::backward`]
/// (and everything above it, up to `hashednets train --threads`)
/// schedules and reduces gradient work.
///
/// # Determinism contract
///
/// * **Fast mode** (`deterministic: false`, the default): the hashed
///   `∂a` pass splits output rows into one block per worker, so results
///   are reproducible for a *fixed* `threads` value but the `∂a` float
///   summation order — and therefore its low bits — changes with the
///   thread count. (The hashed `∂w` is bit-identical at any thread
///   count even here: the inverse-plan pass has a fixed per-bucket
///   summation order.)
/// * **Ordered mode** (`deterministic: true`): rows are split into
///   fixed-size blocks of `block_rows` regardless of the thread count,
///   each block accumulates into its own partial, and partials are
///   reduced in ascending block order (work may be *chunked* across
///   threads by index range, which preserves the per-element order).
///   Training with `threads = N` then produces **bit-identical**
///   parameters — and so byte-identical [`crate::model::ModelBundle`]s
///   — to `threads = 1`, at the cost of zeroing and reducing
///   `⌈n / block_rows⌉` partial buffers.
///
/// The dense / masked / low-rank backward paths go through row-parallel
/// matmuls that are bit-identical to their serial forms at any thread
/// count, so both modes are deterministic there.
///
/// An explicit `threads` value is always honored; `threads = 0` (auto)
/// uses the machine's parallelism but falls back to one worker when the
/// layer is too small to amortize a spawn.
///
/// ```
/// use hashednets::nn::TrainOptions;
///
/// let fast = TrainOptions::with_threads(4);            // fast unordered reduction
/// let repro = TrainOptions::with_threads(4).ordered(); // bit-identical to threads = 1
/// assert!(!fast.deterministic);
/// assert!(repro.deterministic);
/// assert_eq!(TrainOptions::default().threads, 1);      // single-thread by default
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrainOptions {
    /// Worker threads for the backward pass; `0` = auto (machine
    /// parallelism, capped at 8, small layers stay serial). Default 1.
    pub threads: usize,
    /// Output rows per reduction block in ordered mode; `0` = auto
    /// ([`TrainOptions::AUTO_BLOCK_ROWS`]). Ignored in fast mode, where
    /// the block size is derived from the thread count.
    pub block_rows: usize,
    /// Fixed-order (thread-count-independent) gradient reduction.
    pub deterministic: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { threads: 1, block_rows: 0, deterministic: false }
    }
}

impl TrainOptions {
    /// Default ordered-mode block height: small enough to expose
    /// parallelism on the paper's 1000-row layers, large enough that
    /// per-block buffer zeroing stays negligible.
    pub const AUTO_BLOCK_ROWS: usize = 64;

    /// Fast-mode options with an explicit worker count.
    pub fn with_threads(threads: usize) -> TrainOptions {
        TrainOptions { threads, ..TrainOptions::default() }
    }

    /// Switch to the fixed-order reduction (see the type-level docs).
    pub fn ordered(mut self) -> TrainOptions {
        self.deterministic = true;
        self
    }

    /// `threads` with `0` resolved to the shared pool's lane count
    /// ([`crate::rt::pool::max_concurrency`]: machine parallelism
    /// capped at 8 — the backward is memory-bound past that — or the
    /// `HASHEDNETS_POOL_THREADS` override).
    pub fn resolved_threads(&self) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        crate::rt::pool::max_concurrency()
    }

    /// `block_rows` with `0` resolved to [`Self::AUTO_BLOCK_ROWS`].
    pub fn resolved_block_rows(&self) -> usize {
        if self.block_rows == 0 {
            Self::AUTO_BLOCK_ROWS
        } else {
            self.block_rows
        }
    }

    /// Workers to use for `work` multiply-adds over `rows` output rows.
    /// An explicit request is honored as-is (minus the row cap); only
    /// auto mode applies the spawn-amortization threshold.
    fn par_threads(&self, work: usize, rows: usize) -> usize {
        let t = if self.threads == 0 && work < PAR_WORK_THRESHOLD {
            1
        } else {
            self.resolved_threads()
        };
        t.min(rows).max(1)
    }
}

/// What kind of weight structure a layer uses.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Standard dense `W (n×m)` + bias `b (n)`.
    Dense,
    /// HashedNets: `K` real weights, virtual `V (n×(m+1))` decompressed
    /// via `V_ij = ξ(i,j) · w_{h(i,j)}` (paper Eq. 7).
    Hashed { k: usize },
    /// Block-structured HashedNets: `tile.0 × tile.1` tiles of `V` map
    /// to contiguous runs of the `K` stored weights with one ξ sign per
    /// tile ([`TilePlan`]) — Eq. 7 at tile granularity, with SIMD-width
    /// contiguous inner loops instead of per-cell gathers.
    HashedTile { k: usize, tile: (usize, usize) },
    /// Random Edge Removal: dense-but-masked `(n×(m+1))`, hash mask.
    Masked { k: usize },
    /// Low-Rank Decomposition: learned output-side `W (n×r)`, fixed
    /// hash-Gaussian input projection `U (r×(m+1))` (V = W·U).
    LowRank { r: usize },
}

/// One network layer: `m` inputs (excluding bias) → `n` outputs.
#[derive(Debug, Clone)]
pub struct Layer {
    pub m: usize,
    pub n: usize,
    pub kind: LayerKind,
    pub index: usize,     // layer number (selects hash seeds)
    pub seed_base: u32,
    /// Stored parameters, artifact layout:
    /// Dense: `[W (n*m), b (n)]`; Hashed: `[w (k)]`;
    /// Masked: `[Wm (n*(m+1))]`; LowRank: `[Wl (n*r)]`.
    /// A [`ParamStore`] so a served model can borrow these straight out
    /// of an mmap'd bundle; training writes copy-on-write.
    pub params: ParamStore,
    /// Sign-packed decompression plan (hashed layers only), built
    /// eagerly and shared immutably across threads/clones.
    plan: Option<Arc<HashPlan>>,
    /// Tile-run decompression plan (hashed-tile layers only), likewise
    /// eager and `Arc`-shared. Nothing in it is lazy — there is no
    /// inverse view to warm.
    tile_plan: Option<Arc<TilePlan>>,
}

impl Layer {
    pub fn new(m: usize, n: usize, kind: LayerKind, index: usize, seed_base: u32) -> Layer {
        let n_params = match kind {
            LayerKind::Dense => n * m + n,
            LayerKind::Hashed { k } | LayerKind::HashedTile { k, .. } => k,
            LayerKind::Masked { .. } => n * (m + 1),
            LayerKind::LowRank { r } => n * r,
        };
        let plan = match kind {
            LayerKind::Hashed { k } => {
                Some(Arc::new(HashPlan::build(n, m + 1, k, index as u32, seed_base)))
            }
            _ => None,
        };
        let tile_plan = match kind {
            LayerKind::HashedTile { k, tile } => {
                Some(Arc::new(TilePlan::build(n, m + 1, k, tile, index as u32, seed_base)))
            }
            _ => None,
        };
        Layer { m, n, kind, index, seed_base, params: vec![0.0; n_params].into(), plan, tile_plan }
    }

    /// He-style init matching `model.py`'s `ParamSpec.init_std`.
    pub fn init(&mut self, rng: &mut Pcg32) {
        let m = self.m;
        match self.kind {
            LayerKind::Dense => {
                let std = (2.0 / m as f32).sqrt();
                let nm = self.n * m;
                rng.fill_normal(&mut self.params[..nm], std);
                self.params[nm..].iter_mut().for_each(|b| *b = 0.0);
            }
            LayerKind::Hashed { .. } | LayerKind::HashedTile { .. } => {
                let std = (2.0 / (m + 1) as f32).sqrt();
                rng.fill_normal(&mut self.params, std);
            }
            LayerKind::Masked { k } => {
                let keep = k as f32 / ((m + 1) * self.n) as f32;
                let std = (2.0 / (keep * (m + 1) as f32).max(1.0)).sqrt();
                rng.fill_normal(&mut self.params, std);
            }
            LayerKind::LowRank { r } => {
                let std = (2.0 / r as f32).sqrt();
                rng.fill_normal(&mut self.params, std);
            }
        }
    }

    pub fn n_stored(&self) -> usize {
        match self.kind {
            LayerKind::Masked { k } => k, // logical storage (kept edges)
            _ => self.params.len(),
        }
    }

    /// The shared decompression plan (hashed layers only).
    pub fn plan(&self) -> Option<&Arc<HashPlan>> {
        self.plan.as_ref()
    }

    /// The shared tile-run plan (hashed-tile layers only).
    pub fn tile_plan(&self) -> Option<&Arc<TilePlan>> {
        self.tile_plan.as_ref()
    }

    fn plan_ref(&self) -> &HashPlan {
        self.plan.as_deref().expect("hashed layer without a HashPlan")
    }

    fn tile_plan_ref(&self) -> &TilePlan {
        self.tile_plan.as_deref().expect("hashed-tile layer without a TilePlan")
    }

    /// LRD's fixed random input projection `U (r × (m+1))`,
    /// hash-generated with std `1/sqrt(m+1)` (mirrors `model._lrd_layer`).
    fn lrd_fixed_u(&self, r: usize) -> Matrix {
        let m1 = self.m + 1;
        let (s_u, _) = layer_seeds(2000 + self.index as u32, self.seed_base);
        let std = (m1 as f32).powf(-0.5);
        let mut u = Matrix::zeros(r, m1);
        for (idx, out) in u.data.iter_mut().enumerate() {
            *out = hash_gaussian(idx as u32, std, s_u);
        }
        u
    }

    /// Materialize the effective weight matrix `V (n × m_eff)` where
    /// `m_eff = m` for Dense and `m+1` (bias column) otherwise.
    /// Used by tests, the compressor, and the simple backward path.
    pub fn virtual_matrix(&self) -> Matrix {
        let (m1, n) = (self.m + 1, self.n);
        match self.kind {
            LayerKind::Dense => {
                let mut v = Matrix::zeros(n, self.m);
                v.data.copy_from_slice(&self.params[..n * self.m]);
                v
            }
            LayerKind::Hashed { .. } => {
                let plan = self.plan_ref();
                let mut v = Matrix::zeros(n, m1);
                for i in 0..n {
                    plan.decompress_row_into(i, &self.params, v.row_mut(i));
                }
                v
            }
            LayerKind::HashedTile { .. } => {
                let plan = self.tile_plan_ref();
                let mut v = Matrix::zeros(n, m1);
                for i in 0..n {
                    plan.decompress_row_into(i, &self.params, v.row_mut(i));
                }
                v
            }
            LayerKind::Masked { k } => {
                let keep = k as f32 / (m1 * n) as f32;
                let (s_mask, _) = layer_seeds(1000 + self.index as u32, self.seed_base);
                let mut v = Matrix::zeros(n, m1);
                for (idx, (out, &p)) in v.data.iter_mut().zip(&self.params).enumerate() {
                    let u = hash_uniform(idx as u32, s_mask);
                    *out = if u < keep { p } else { 0.0 };
                }
                v
            }
            LayerKind::LowRank { r } => {
                // V (n×(m+1)) = W (n×r) · U (r×(m+1)), U fixed
                let u = self.lrd_fixed_u(r);
                let w = Matrix::from_vec(n, r, self.params.to_vec());
                w.matmul(&u)
            }
        }
    }

    /// Forward: `z = a·Vᵀ (+ b)`; `a` is `(B × m)` un-augmented.
    ///
    /// Hashed layers dispatch on the heuristic documented in
    /// `hash::plan`: the inverse-plan kernel (streaming `w` in bucket
    /// order) for B = 1, scratch-row (batch-amortized, pool-parallel on
    /// big layers) for B ≥ 2. The bias column is handled implicitly —
    /// no kernel materializes `a.augment_ones()`.
    pub fn forward(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.cols, self.m);
        match self.kind {
            LayerKind::Dense => {
                let n = self.n;
                let w = Matrix::from_vec(n, self.m, self.params[..n * self.m].to_vec());
                let b = &self.params[n * self.m..];
                // row-parallel on big batches (bit-identical to serial),
                // mirroring the scratch kernel's auto-threading policy
                let threads = par_threads(a.rows * n * self.m, a.rows);
                let mut z = a.matmul_nt_par(&w, threads);
                for r in 0..z.rows {
                    for (zv, &bv) in z.row_mut(r).iter_mut().zip(b) {
                        *zv += bv;
                    }
                }
                z
            }
            LayerKind::Hashed { .. } => {
                if a.rows == 1 {
                    self.forward_hashed_inverse(a)
                } else {
                    self.forward_hashed_scratch(a)
                }
            }
            // tile runs decompress contiguously, so one kernel serves
            // every batch size — no B = 1 special case needed
            LayerKind::HashedTile { .. } => self.forward_hashed_tiled(a),
            _ => {
                let v = self.virtual_matrix();
                a.matmul_nt_aug(&v)
            }
        }
    }

    /// Legacy decompress-on-the-fly kernel (paper Eq. 8): per batch row,
    /// per virtual cell, gather `w[h(i,j)]` through the plan. One random
    /// read per cell per batch row — kept as the bench baseline the
    /// other kernels are measured against.
    pub fn forward_hashed_gather(&self, a: &Matrix) -> Matrix {
        let (m, n) = (self.m, self.n);
        let plan = self.plan_ref();
        let params: &[f32] = &self.params;
        let mut z = Matrix::zeros(a.rows, n);
        for b in 0..a.rows {
            let arow = a.row(b);
            let zrow = z.row_mut(b);
            for i in 0..n {
                let prow = plan.row(i);
                // bias column j = m contributes ξ·w with a_j ≡ 1
                let eb = prow[m];
                let mut acc = HashPlan::apply_sign(eb, params[HashPlan::bucket(eb)]);
                for (&e, &av) in prow[..m].iter().zip(arow) {
                    acc += HashPlan::apply_sign(e, params[HashPlan::bucket(e)]) * av;
                }
                zrow[i] = acc;
            }
        }
        z
    }

    /// Scratch-row kernel: decompress each virtual row **once** into a
    /// per-task scratch buffer, then run a dense unrolled dot against
    /// every batch row — the K-gather is amortized over B rows instead
    /// of repeated B times. Output rows are computed transposed
    /// (`n × B`) so row blocks are contiguous and split cleanly across
    /// [`crate::rt::PoolExec`] tasks without locks.
    pub fn forward_hashed_scratch(&self, a: &Matrix) -> Matrix {
        let (m, m1, n) = (self.m, self.m + 1, self.n);
        let plan = self.plan_ref();
        let params: &[f32] = &self.params;
        let rows_b = a.rows;
        if rows_b == 0 {
            return Matrix::zeros(0, n);
        }
        let mut zt = Matrix::zeros(n, rows_b);
        let threads = par_threads(n * m1 * (rows_b + 1), n);
        let rows_per = n.div_ceil(threads);
        crate::rt::pool::run_parts(
            zt.data.chunks_mut(rows_per * rows_b).collect(),
            |blk, chunk: &mut [f32]| {
                let i0 = blk * rows_per;
                let mut scratch = vec![0.0f32; m1];
                for (r, zrow) in chunk.chunks_mut(rows_b).enumerate() {
                    plan.decompress_row_into(i0 + r, params, &mut scratch);
                    let bias = scratch[m];
                    for (b, zv) in zrow.iter_mut().enumerate() {
                        *zv = bias + simd::dot8(a.row(b), &scratch[..m]);
                    }
                }
            },
        );
        let mut z = Matrix::zeros(rows_b, n);
        for i in 0..n {
            for b in 0..rows_b {
                *z.at_mut(b, i) = zt.at(i, b);
            }
        }
        z
    }

    /// Tiled SIMD kernel (`LayerKind::HashedTile`): decompress each
    /// virtual row as `tiles_c` **contiguous** `tw`-length runs at the
    /// tile-padded width, then one full-width [`simd::dot8`] against
    /// tile-padded activations `[a | 1 | 0…]` per batch row — no
    /// per-cell gathers, no edge branches, no separate bias add (the
    /// implicit bias column rides in the padding). Output rows are
    /// computed transposed (`n × B`) and split across pool tasks exactly
    /// like [`Layer::forward_hashed_scratch`]. The zero tail of the
    /// padded activations makes the out-of-range columns of edge tiles
    /// numerically inert.
    pub fn forward_hashed_tiled(&self, a: &Matrix) -> Matrix {
        let (m, n) = (self.m, self.n);
        let plan = self.tile_plan_ref();
        let params: &[f32] = &self.params;
        let rows_b = a.rows;
        if rows_b == 0 {
            return Matrix::zeros(0, n);
        }
        let mp = plan.padded_width();
        let mut a_pad = Matrix::zeros(rows_b, mp);
        for b in 0..rows_b {
            a_pad.row_mut(b)[..m].copy_from_slice(a.row(b));
            a_pad.row_mut(b)[m] = 1.0;
        }
        let mut zt = Matrix::zeros(n, rows_b);
        let threads = par_threads(n * mp * (rows_b + 1), n);
        let rows_per = n.div_ceil(threads);
        crate::rt::pool::run_parts(
            zt.data.chunks_mut(rows_per * rows_b).collect(),
            |blk, chunk: &mut [f32]| {
                let i0 = blk * rows_per;
                let mut scratch = vec![0.0f32; mp];
                for (r, zrow) in chunk.chunks_mut(rows_b).enumerate() {
                    plan.decompress_padded_row_into(i0 + r, params, &mut scratch);
                    for (b, zv) in zrow.iter_mut().enumerate() {
                        *zv = simd::dot8(a_pad.row(b), &scratch);
                    }
                }
            },
        );
        let mut z = Matrix::zeros(rows_b, n);
        for i in 0..n {
            for b in 0..rows_b {
                *z.at_mut(b, i) = zt.at(i, b);
            }
        }
        z
    }

    /// Bucket-major kernel (paper Eq. 10): per output row, scatter
    /// ξ(i,j)·aⱼ into a K-sized accumulator, then one streaming dot with
    /// the stored weights — `z_i = Σ_k w_k · Σ_{j: h(i,j)=k} ξ(i,j) a_j`.
    /// The former B = 1 `K ≤ m+1` default, kept as a bench variant next
    /// to [`Layer::forward_hashed_inverse`].
    pub fn forward_hashed_bucket(&self, a: &Matrix) -> Matrix {
        let LayerKind::Hashed { k } = self.kind else {
            unreachable!("bucket kernel on a non-hashed layer")
        };
        let (m, n) = (self.m, self.n);
        let plan = self.plan_ref();
        let mut z = Matrix::zeros(a.rows, n);
        let mut acc = vec![0.0f32; k];
        for b in 0..a.rows {
            let arow = a.row(b);
            let zrow = z.row_mut(b);
            for i in 0..n {
                acc.iter_mut().for_each(|x| *x = 0.0);
                let prow = plan.row(i);
                for (&e, &av) in prow[..m].iter().zip(arow) {
                    acc[HashPlan::bucket(e)] += HashPlan::apply_sign(e, av);
                }
                let eb = prow[m]; // implicit bias column, a_j ≡ 1
                acc[HashPlan::bucket(eb)] += HashPlan::apply_sign(eb, 1.0);
                zrow[i] = dot_unrolled(&acc, &self.params);
            }
        }
        z
    }

    /// Inverse-plan kernel: Eq. 10 evaluated off the CSR-by-bucket
    /// [`InversePlan`] — for each bucket `k` (ascending), add
    /// `ξ(i,j)·w_k·a_j` into `z_i` for every cell of the bucket. The
    /// stored weights stream **in order** (one sequential read each)
    /// and the per-cell random traffic is confined to the small `z`
    /// and `a` vectors, which is what makes unstructured hashing
    /// cache-friendly at B = 1; the inverse view is built lazily on
    /// first call and cached on the shared plan.
    pub fn forward_hashed_inverse(&self, a: &Matrix) -> Matrix {
        let (m, m1, n) = (self.m, self.m + 1, self.n);
        let plan = self.plan_ref();
        let inv = plan.inverse();
        let mut z = Matrix::zeros(a.rows, n);
        for b in 0..a.rows {
            let arow = a.row(b);
            let zrow = z.row_mut(b);
            for (k, &w) in self.params.iter().enumerate() {
                for &cell in inv.cells_of(k) {
                    let idx = (cell & HashPlan::BUCKET_MASK) as usize;
                    let (i, j) = (idx / m1, idx % m1);
                    let av = if j < m { arow[j] } else { 1.0 };
                    zrow[i] += HashPlan::apply_sign(cell, w) * av;
                }
            }
        }
        z
    }

    /// Backward: given `delta (B×n)` (dL/dz) and input `a (B×m)`,
    /// returns `da (B×m)` and accumulates the stored-parameter gradient
    /// into `grad` (same layout as `params`).
    ///
    /// `opts` controls the worker count and the reduction order — see
    /// [`TrainOptions`] for the determinism contract (within-version:
    /// the hashed `∂w` summation order moved to the inverse plan's
    /// bucket order, so gradients match pre-inverse releases only to
    /// float tolerance, not bit for bit).
    pub fn backward(
        &self,
        a: &Matrix,
        delta: &Matrix,
        grad: &mut [f32],
        opts: &TrainOptions,
    ) -> Matrix {
        assert_eq!(grad.len(), self.params.len());
        match self.kind {
            LayerKind::Dense => {
                let n = self.n;
                let m = self.m;
                let threads = opts.par_threads(2 * delta.rows * n * m, n);
                let w = Matrix::from_vec(n, m, self.params[..n * m].to_vec());
                // dW = deltaᵀ·a ; db = Σ_b delta
                let dw = delta.matmul_tn_par(a, threads); // (n×m)
                grad[..n * m].iter_mut().zip(&dw.data).for_each(|(g, &d)| *g += d);
                for b in 0..delta.rows {
                    for (g, &d) in grad[n * m..].iter_mut().zip(delta.row(b)) {
                        *g += d;
                    }
                }
                delta.matmul_par(&w, threads)
            }
            LayerKind::Hashed { .. } => self.backward_hashed(a, delta, grad, opts),
            LayerKind::HashedTile { .. } => self.backward_tiled(a, delta, grad, opts),
            LayerKind::Masked { k } => {
                let m1 = self.m + 1;
                let threads = opts.par_threads(2 * delta.rows * self.n * m1, self.n);
                let v = self.virtual_matrix();
                let da_aug = delta.matmul_par(&v, threads);
                let g_dense = delta.matmul_tn_aug(a, threads); // (n×(m+1)), implicit bias col
                let keep = k as f32 / (m1 * self.n) as f32;
                let (s_mask, _) = layer_seeds(1000 + self.index as u32, self.seed_base);
                for (idx, (g, &gd)) in grad.iter_mut().zip(&g_dense.data).enumerate() {
                    if hash_uniform(idx as u32, s_mask) < keep {
                        *g += gd;
                    }
                }
                da_aug.drop_last_col()
            }
            LayerKind::LowRank { r } => {
                let m1 = self.m + 1;
                let threads = opts.par_threads(delta.rows * self.n * m1, self.n);
                let v = self.virtual_matrix();
                let da_aug = delta.matmul_par(&v, threads);
                // h = [a|1]·Uᵀ (B×r); dW = deltaᵀ·h (n×r)
                let u = self.lrd_fixed_u(r);
                let h = a.matmul_nt_aug(&u);
                let dw = delta.matmul_tn(&h); // (n×r) — r is small, stay serial
                grad.iter_mut().zip(&dw.data).for_each(|(g, &d)| *g += d);
                da_aug.drop_last_col()
            }
        }
    }

    /// Hashed backward (paper Eqs. 11 & 12), split by gradient:
    ///
    /// * **Eq. 12 (`∂w`)** — `S = δᵀ·[a|1]` via the bit-identical
    ///   row-parallel [`Matrix::matmul_tn_aug`] (`S.row(i)` *is* the
    ///   batch reduction `s_j = Σ_b δ_bi a_bj` of row `i`), then one
    ///   **sequential** write per bucket off the [`InversePlan`]:
    ///   `∂w_k += Σ_{(i,j) ∈ bucket k} ξ(i,j)·S_{ij}` — see
    ///   [`inverse_weight_grad`]. No per-block partial buffers, no
    ///   random scatter, and the result is bit-identical for every
    ///   thread count in both reduction modes.
    /// * **Eq. 11 (`∂a`)** — per virtual row, decompress once and
    ///   accumulate `da_b += δ_bi·V_i`. Output rows are split into
    ///   blocks on the shared pool, each block accumulating into a
    ///   private `∂a` partial, then reduced in ascending block order
    ///   with the reduction chunked by index range
    ///   ([`reduce_block_partials`]) — which keeps the per-element
    ///   summation order independent of the thread count. In ordered
    ///   mode the block partition is fixed by `block_rows`, so `∂a` is
    ///   thread-count-invariant too; in fast mode there is one block
    ///   per lane, and `threads = 1` skips the partials entirely.
    fn backward_hashed(
        &self,
        a: &Matrix,
        delta: &Matrix,
        grad: &mut [f32],
        opts: &TrainOptions,
    ) -> Matrix {
        let (m1, n, m) = (self.m + 1, self.n, self.m);
        let plan = self.plan_ref();
        let params: &[f32] = &self.params;
        let rows_b = a.rows;
        let mut da = Matrix::zeros(rows_b, m);
        if rows_b == 0 {
            return da;
        }
        let threads = opts.par_threads(n * m1 * (rows_b + 2), n);

        // Eq. 12 through the inverse plan (scatter-free, no partials)
        let s = delta.matmul_tn_aug(a, threads);
        inverse_weight_grad(plan, &s, grad, threads);

        // Eq. 11: da = δ·V over decompressed rows
        if threads == 1 && !opts.deterministic {
            // serial fast path: accumulate straight into the shared buffer
            let mut vrow = vec![0.0f32; m1];
            hashed_da_rows(plan, params, delta, 0..n, m, &mut da.data, &mut vrow);
            return da;
        }
        // block partition: thread-count-independent in ordered mode,
        // one block per lane in fast mode
        let block_rows = if opts.deterministic {
            opts.resolved_block_rows().min(n)
        } else {
            n.div_ceil(threads)
        };
        let n_blocks = n.div_ceil(block_rows);
        let threads = threads.min(n_blocks);
        let mut partials: Vec<Vec<f32>> =
            (0..n_blocks).map(|_| vec![0.0f32; rows_b * m]).collect();
        let blocks_per = n_blocks.div_ceil(threads);
        crate::rt::pool::run_parts(
            partials.chunks_mut(blocks_per).collect(),
            |t, pchunk: &mut [Vec<f32>]| {
                let mut vrow = vec![0.0f32; m1];
                for (bi, pda) in pchunk.iter_mut().enumerate() {
                    let i0 = (t * blocks_per + bi) * block_rows;
                    let i1 = (i0 + block_rows).min(n);
                    hashed_da_rows(plan, params, delta, i0..i1, m, pda, &mut vrow);
                }
            },
        );
        let dparts: Vec<&[f32]> = partials.iter().map(Vec::as_slice).collect();
        reduce_block_partials(&mut da.data, &dparts, threads);
        da
    }

    /// Tiled backward (Eqs. 11 & 12 at tile granularity):
    ///
    /// * **Eq. 12 (`∂w`)** — `S = δᵀ·[a|1]` via the bit-identical
    ///   row-parallel [`Matrix::matmul_tn_aug`], then a fixed-order tile
    ///   walk adding `ξ_t·S_{ij}` into each tile's **contiguous** run of
    ///   `grad` ([`tiled_weight_grad`]) — sequential writes, no per-cell
    ///   scatter. Runs *overlap* across tiles (unlike the per-cell
    ///   inverse plan's disjoint bucket ranges), so the parallel path
    ///   accumulates tile-row-block partials and reduces them in
    ///   ascending block order; in ordered mode the block partition is
    ///   fixed by `block_rows`, making `∂w` thread-count-invariant.
    /// * **Eq. 11 (`∂a`)** — same block/partial/ordered-reduction
    ///   structure as [`Layer::backward_hashed`]'s `∂a` pass, with
    ///   padded tile-run decompression and [`simd::axpy8`] row
    ///   accumulation ([`tiled_da_rows`]).
    fn backward_tiled(
        &self,
        a: &Matrix,
        delta: &Matrix,
        grad: &mut [f32],
        opts: &TrainOptions,
    ) -> Matrix {
        let (m1, n, m) = (self.m + 1, self.n, self.m);
        let plan = self.tile_plan_ref();
        let params: &[f32] = &self.params;
        let rows_b = a.rows;
        let mut da = Matrix::zeros(rows_b, m);
        if rows_b == 0 {
            return da;
        }
        let threads = opts.par_threads(n * m1 * (rows_b + 2), n);

        // Eq. 12 over tile runs
        let s = delta.matmul_tn_aug(a, threads);
        tiled_weight_grad(plan, &s, grad, threads, opts);

        // Eq. 11: da = δ·V over padded decompressed rows
        if threads == 1 && !opts.deterministic {
            let mut vrow = vec![0.0f32; plan.padded_width()];
            tiled_da_rows(plan, params, delta, 0..n, m, &mut da.data, &mut vrow);
            return da;
        }
        let block_rows = if opts.deterministic {
            opts.resolved_block_rows().min(n)
        } else {
            n.div_ceil(threads)
        };
        let n_blocks = n.div_ceil(block_rows);
        let threads = threads.min(n_blocks);
        let mut partials: Vec<Vec<f32>> =
            (0..n_blocks).map(|_| vec![0.0f32; rows_b * m]).collect();
        let blocks_per = n_blocks.div_ceil(threads);
        crate::rt::pool::run_parts(
            partials.chunks_mut(blocks_per).collect(),
            |t, pchunk: &mut [Vec<f32>]| {
                let mut vrow = vec![0.0f32; plan.padded_width()];
                for (bi, pda) in pchunk.iter_mut().enumerate() {
                    let i0 = (t * blocks_per + bi) * block_rows;
                    let i1 = (i0 + block_rows).min(n);
                    tiled_da_rows(plan, params, delta, i0..i1, m, pda, &mut vrow);
                }
            },
        );
        let dparts: Vec<&[f32]> = partials.iter().map(Vec::as_slice).collect();
        reduce_block_partials(&mut da.data, &dparts, threads);
        da
    }

    /// Legacy Eq. 12 path — the fused row-major loop that **scatters**
    /// `ξ(i,j)·s_j` into the bucket gradient, one random write per
    /// virtual cell (serial). Kept as the baseline the inverse-plan
    /// gradient is benchmarked and cross-checked against
    /// (`benches/train_throughput.rs`, `rust/tests/kernels.rs`).
    pub fn backward_hashed_scatter(&self, a: &Matrix, delta: &Matrix, grad: &mut [f32]) -> Matrix {
        assert_eq!(grad.len(), self.params.len());
        let (m1, n, m) = (self.m + 1, self.n, self.m);
        let plan = self.plan_ref();
        let params: &[f32] = &self.params;
        let rows_b = a.rows;
        let mut da = Matrix::zeros(rows_b, m);
        let mut vrow = vec![0.0f32; m1];
        let mut srow = vec![0.0f32; m1];
        for i in 0..n {
            if (0..rows_b).all(|b| delta.at(b, i) == 0.0) {
                continue;
            }
            plan.decompress_row_into(i, params, &mut vrow);
            srow.iter_mut().for_each(|x| *x = 0.0);
            for b in 0..rows_b {
                let d = delta.at(b, i);
                if d == 0.0 {
                    continue;
                }
                for (dv, &vv) in da.data[b * m..(b + 1) * m].iter_mut().zip(&vrow[..m]) {
                    *dv += d * vv;
                }
                for (sv, &av) in srow[..m].iter_mut().zip(a.row(b)) {
                    *sv += d * av;
                }
                srow[m] += d; // implicit bias column, a_j ≡ 1
            }
            // Eq. 12 scattered: dw_{h(i,j)} += ξ(i,j) Σ_b a_bj δ_bi
            for (&e, &sv) in plan.row(i).iter().zip(&*srow) {
                grad[HashPlan::bucket(e)] += HashPlan::apply_sign(e, sv);
            }
        }
        da
    }
}

/// Eq. 11 contribution of virtual rows `rows`: per row, decompress once
/// into `vrow` and accumulate `da_b += δ_bi · V_i` for every batch row
/// with a nonzero delta. `da` is either the shared flattened `(B × m)`
/// output buffer (serial path) or a block-private partial (pool path).
fn hashed_da_rows(
    plan: &HashPlan,
    params: &[f32],
    delta: &Matrix,
    rows: std::ops::Range<usize>,
    m: usize,
    da: &mut [f32],
    vrow: &mut [f32],
) {
    let rows_b = delta.rows;
    for i in rows {
        if (0..rows_b).all(|b| delta.at(b, i) == 0.0) {
            continue;
        }
        plan.decompress_row_into(i, params, vrow);
        for b in 0..rows_b {
            let d = delta.at(b, i);
            if d == 0.0 {
                continue;
            }
            for (dv, &vv) in da[b * m..(b + 1) * m].iter_mut().zip(&vrow[..m]) {
                *dv += d * vv;
            }
        }
    }
}

/// Eq. 12 through the [`InversePlan`]: `∂w_k += Σ_{(i,j): h(i,j)=k}
/// ξ(i,j)·S_{ij}` where `S = δᵀ·[a|1]` — the inverse plan's flat cell
/// index addresses `S.data` directly, so the pass does one *sequential*
/// write per bucket with gathered reads from `S`, instead of one random
/// write per virtual cell.
///
/// Buckets are split across pool tasks by ranges of roughly equal cell
/// count ([`InversePlan::balanced_ranges`]); ranges write **disjoint**
/// `grad` spans, so no partial buffers or reduction are needed, and
/// since each bucket's cell order is fixed by the plan, the result is
/// **bit-identical for every thread count** — the weight gradient is
/// deterministic in both reduction modes by construction.
fn inverse_weight_grad(plan: &HashPlan, s: &Matrix, grad: &mut [f32], threads: usize) {
    debug_assert_eq!(grad.len(), plan.k);
    debug_assert_eq!(s.data.len(), plan.n * plan.m1);
    let inv: &InversePlan = plan.inverse();
    let threads = if inv.cells.len() < PAR_WORK_THRESHOLD { 1 } else { threads.max(1) };
    let bounds = inv.balanced_ranges(threads.min(grad.len()));
    let mut parts: Vec<(usize, &mut [f32])> = Vec::with_capacity(bounds.len() - 1);
    let mut rest = grad;
    let mut prev = 0usize;
    for &b in &bounds[1..] {
        let (head, tail) = rest.split_at_mut(b - prev);
        parts.push((prev, head));
        rest = tail;
        prev = b;
    }
    crate::rt::pool::run_parts(parts, |_t, (k0, gpart): (usize, &mut [f32])| {
        for (kk, g) in gpart.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for &cell in inv.cells_of(k0 + kk) {
                let idx = (cell & HashPlan::BUCKET_MASK) as usize;
                acc += HashPlan::apply_sign(cell, s.data[idx]);
            }
            *g += acc;
        }
    });
}

/// Eq. 11 contribution of virtual rows `rows` for a tiled layer: per
/// row, decompress once at padded width (contiguous tile runs) and
/// accumulate `da_b += δ_bi · V_i[..m]` via [`simd::axpy8`] for every
/// batch row with a nonzero delta. The twin of [`hashed_da_rows`].
fn tiled_da_rows(
    plan: &TilePlan,
    params: &[f32],
    delta: &Matrix,
    rows: std::ops::Range<usize>,
    m: usize,
    da: &mut [f32],
    vrow: &mut [f32],
) {
    let rows_b = delta.rows;
    for i in rows {
        if (0..rows_b).all(|b| delta.at(b, i) == 0.0) {
            continue;
        }
        plan.decompress_padded_row_into(i, params, vrow);
        for b in 0..rows_b {
            let d = delta.at(b, i);
            if d == 0.0 {
                continue;
            }
            simd::axpy8(&mut da[b * m..(b + 1) * m], &vrow[..m], d);
        }
    }
}

/// Eq. 12 contribution of tile-rows `trs`: for every tile, add
/// `ξ_t·S_{ij}` into the tile's contiguous run of `grad` — sequential
/// writes into a `th·tw` span per tile, walking tiles in fixed
/// row-major grid order (which pins the summation order for a given
/// block partition).
fn tiled_grad_tile_rows(
    plan: &TilePlan,
    s: &Matrix,
    trs: std::ops::Range<usize>,
    grad: &mut [f32],
) {
    let (th, tw) = plan.tile;
    let (_, tiles_c) = plan.tiles();
    let (n, m1) = (plan.n, plan.m1);
    for tr in trs {
        let i0 = tr * th;
        let i1 = (i0 + th).min(n);
        for tc in 0..tiles_c {
            let e = plan.tile_entry(tr, tc);
            let base = TilePlan::base(e);
            let j0 = tc * tw;
            let j1 = (j0 + tw).min(m1);
            for i in i0..i1 {
                let run = base + (i - i0) * tw;
                let srow = &s.data[i * m1 + j0..i * m1 + j1];
                for (o, &sv) in srow.iter().enumerate() {
                    grad[run + o] += HashPlan::apply_sign(e, sv);
                }
            }
        }
    }
}

/// Eq. 12 for a tiled layer: `∂w[base_t + off] += ξ_t · S_{ij}` over
/// every tile, where `S = δᵀ·[a|1]`. Tile runs **overlap** across
/// tiles, so (unlike [`inverse_weight_grad`]'s disjoint bucket ranges)
/// the parallel path cannot split `grad` itself: tile-rows are split
/// into blocks, each block accumulates into a private `k`-length
/// partial, and partials reduce in ascending block order
/// ([`reduce_block_partials`]). In ordered mode the block partition is
/// fixed by `block_rows` (converted to tile-rows), so `∂w` is
/// bit-identical at any thread count; in fast mode there is one block
/// per lane and `threads = 1` scatters straight into `grad`.
fn tiled_weight_grad(
    plan: &TilePlan,
    s: &Matrix,
    grad: &mut [f32],
    threads: usize,
    opts: &TrainOptions,
) {
    debug_assert_eq!(grad.len(), plan.k);
    debug_assert_eq!(s.data.len(), plan.n * plan.m1);
    let (tiles_r, _) = plan.tiles();
    if threads == 1 && !opts.deterministic {
        tiled_grad_tile_rows(plan, s, 0..tiles_r, grad);
        return;
    }
    let block_tr = if opts.deterministic {
        opts.resolved_block_rows().div_ceil(plan.tile.0).max(1).min(tiles_r)
    } else {
        tiles_r.div_ceil(threads)
    };
    let n_blocks = tiles_r.div_ceil(block_tr);
    let threads = threads.min(n_blocks).max(1);
    let mut partials: Vec<Vec<f32>> = (0..n_blocks).map(|_| vec![0.0f32; plan.k]).collect();
    let blocks_per = n_blocks.div_ceil(threads);
    crate::rt::pool::run_parts(
        partials.chunks_mut(blocks_per).collect(),
        |t, pchunk: &mut [Vec<f32>]| {
            for (bi, pg) in pchunk.iter_mut().enumerate() {
                let t0 = (t * blocks_per + bi) * block_tr;
                let t1 = (t0 + block_tr).min(tiles_r);
                tiled_grad_tile_rows(plan, s, t0..t1, pg);
            }
        },
    );
    let parts: Vec<&[f32]> = partials.iter().map(Vec::as_slice).collect();
    reduce_block_partials(grad, &parts, threads);
}

/// `dst[j] += Σ_blk parts[blk][j]`, always summing blocks in ascending
/// order for every element. Large reductions are chunked across pool
/// tasks by *index range*, never by block, so the float addition
/// order — and therefore the result, bit for bit — is independent of
/// the thread count ("tree" step of the backward's block reduction).
fn reduce_block_partials(dst: &mut [f32], parts: &[&[f32]], threads: usize) {
    /// Below this many output elements per task, dispatch costs more
    /// than the adds.
    const CHUNK_MIN: usize = 1 << 13;
    if dst.is_empty() || parts.is_empty() {
        return;
    }
    let threads = threads.clamp(1, dst.len().div_ceil(CHUNK_MIN));
    let chunk = dst.len().div_ceil(threads);
    crate::rt::pool::run_parts(dst.chunks_mut(chunk).collect(), |c, dchunk: &mut [f32]| {
        let off = c * chunk;
        for part in parts {
            for (d, &p) in dchunk.iter_mut().zip(&part[off..]) {
                *d += p;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_matrix(rows: usize, cols: usize, rng: &mut Pcg32) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    fn mk(kind: LayerKind, m: usize, n: usize) -> Layer {
        let mut l = Layer::new(m, n, kind, 0, crate::hash::DEFAULT_SEED_BASE);
        let mut rng = Pcg32::new(9, 9);
        l.init(&mut rng);
        l
    }

    #[test]
    fn hashed_forward_matches_virtual_matrix() {
        let l = mk(LayerKind::Hashed { k: 13 }, 10, 6);
        let mut rng = Pcg32::new(1, 1);
        let a = rand_matrix(4, 10, &mut rng);
        let z_fast = l.forward(&a);
        let v = l.virtual_matrix();
        let z_ref = a.augment_ones().matmul_nt(&v);
        for (x, y) in z_fast.data.iter().zip(&z_ref.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn all_hashed_kernels_agree() {
        let l = mk(LayerKind::Hashed { k: 9 }, 12, 7);
        let mut rng = Pcg32::new(5, 5);
        for batch in [1usize, 2, 6] {
            let a = rand_matrix(batch, 12, &mut rng);
            let z_ref = a.augment_ones().matmul_nt(&l.virtual_matrix());
            for (name, z) in [
                ("gather", l.forward_hashed_gather(&a)),
                ("scratch", l.forward_hashed_scratch(&a)),
                ("bucket", l.forward_hashed_bucket(&a)),
                ("inverse", l.forward_hashed_inverse(&a)),
            ] {
                for (x, y) in z.data.iter().zip(&z_ref.data) {
                    assert!((x - y).abs() < 1e-5, "{name} b={batch}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn scatter_and_inverse_weight_gradients_agree() {
        // the legacy random-scatter Eq. 12 and the inverse-plan pass
        // sum the same terms in different orders — they must agree to
        // float tolerance on every kernel regime
        for (m, n, k, batch) in [(12usize, 30usize, 40usize, 5usize), (8, 6, 100, 1), (20, 10, 7, 50)] {
            let l = mk(LayerKind::Hashed { k }, m, n);
            let mut rng = Pcg32::new(13, k as u64);
            let a = rand_matrix(batch, m, &mut rng);
            let co = rand_matrix(batch, n, &mut rng);
            let mut g_inv = vec![0.0f32; k];
            let da_inv = l.backward(&a, &co, &mut g_inv, &TrainOptions::default());
            let mut g_sc = vec![0.0f32; k];
            let da_sc = l.backward_hashed_scatter(&a, &co, &mut g_sc);
            for (x, y) in g_inv.iter().zip(&g_sc).chain(da_inv.data.iter().zip(&da_sc.data)) {
                assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "k={k} b={batch}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn inverse_weight_gradient_is_thread_count_invariant_even_in_fast_mode() {
        // Eq. 12 off the inverse plan has a fixed per-bucket summation
        // order, so ∂w is bit-identical across thread counts without
        // the ordered-reduction machinery
        let l = mk(LayerKind::Hashed { k: 64 }, 20, 40);
        let mut rng = Pcg32::new(17, 17);
        let a = rand_matrix(10, 20, &mut rng);
        let co = rand_matrix(10, 40, &mut rng);
        let grad_with = |threads: usize| -> Vec<u32> {
            let mut g = vec![0.0f32; l.params.len()];
            l.backward(&a, &co, &mut g, &TrainOptions::with_threads(threads));
            g.iter().map(|v| v.to_bits()).collect()
        };
        let g1 = grad_with(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(g1, grad_with(threads), "t{threads}");
        }
    }

    #[test]
    fn hashed_weight_sharing_actually_shares() {
        let l = mk(LayerKind::Hashed { k: 3 }, 8, 8);
        let v = l.virtual_matrix();
        // only 3 distinct |values| may occur
        let mut mags: Vec<u32> = v.data.iter().map(|x| x.abs().to_bits()).collect();
        mags.sort_unstable();
        mags.dedup();
        assert!(mags.len() <= 3, "found {} distinct magnitudes", mags.len());
    }

    #[test]
    fn plan_is_shared_across_clones() {
        let l = mk(LayerKind::Hashed { k: 5 }, 6, 4);
        let l2 = l.clone();
        assert!(Arc::ptr_eq(l.plan().unwrap(), l2.plan().unwrap()));
        assert_eq!(l.plan().unwrap().bytes(), 4 * 4 * 7);
    }

    fn finite_diff_check(mut layer: Layer) {
        let mut rng = Pcg32::new(2, 2);
        let a = rand_matrix(3, layer.m, &mut rng);
        let co = rand_matrix(3, layer.n, &mut rng); // cotangent

        let loss = |l: &Layer| -> f32 {
            let z = l.forward(&a);
            z.data.iter().zip(&co.data).map(|(z, c)| z * c).sum()
        };
        let mut grad = vec![0.0f32; layer.params.len()];
        let _da = layer.backward(&a, &co, &mut grad, &TrainOptions::default());
        let eps = 1e-2f32;
        // spot-check a handful of parameters
        let step = (layer.params.len() / 7).max(1);
        for p in (0..layer.params.len()).step_by(step) {
            let orig = layer.params[p];
            layer.params[p] = orig + eps;
            let lp = loss(&layer);
            layer.params[p] = orig - eps;
            let lm = loss(&layer);
            layer.params[p] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[p]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {p}: fd {fd} vs ad {}",
                grad[p]
            );
        }
    }

    #[test]
    fn gradients_dense() {
        finite_diff_check(mk(LayerKind::Dense, 7, 5));
    }

    #[test]
    fn gradients_hashed() {
        finite_diff_check(mk(LayerKind::Hashed { k: 11 }, 7, 5));
    }

    #[test]
    fn gradients_masked() {
        finite_diff_check(mk(LayerKind::Masked { k: 20 }, 7, 5));
    }

    #[test]
    fn gradients_tiled() {
        finite_diff_check(mk(LayerKind::HashedTile { k: 11, tile: (1, 8) }, 7, 5));
        finite_diff_check(mk(LayerKind::HashedTile { k: 70, tile: (8, 8) }, 7, 5));
    }

    #[test]
    fn tiled_forward_matches_virtual_matrix() {
        // odd dims → partial edge tiles on both axes
        for (tile, m, n) in [((1usize, 8usize), 10usize, 6usize), ((8, 8), 13, 9), ((2, 4), 7, 5)] {
            let l = mk(LayerKind::HashedTile { k: 90, tile }, m, n);
            let mut rng = Pcg32::new(1, tile.0 as u64);
            for batch in [1usize, 4] {
                let a = rand_matrix(batch, m, &mut rng);
                let z_fast = l.forward(&a);
                let z_ref = a.augment_ones().matmul_nt(&l.virtual_matrix());
                for (x, y) in z_fast.data.iter().zip(&z_ref.data) {
                    assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{tile:?} b={batch}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn tiled_input_gradient_matches_fd() {
        let layer = mk(LayerKind::HashedTile { k: 16, tile: (1, 8) }, 6, 4);
        let mut rng = Pcg32::new(3, 3);
        let mut a = rand_matrix(2, 6, &mut rng);
        let co = rand_matrix(2, 4, &mut rng);
        let mut grad = vec![0.0f32; layer.params.len()];
        let da = layer.backward(&a.clone(), &co, &mut grad, &TrainOptions::default());
        let eps = 1e-2f32;
        for probe in [(0usize, 0usize), (1, 3), (0, 5)] {
            let orig = a.at(probe.0, probe.1);
            *a.at_mut(probe.0, probe.1) = orig + eps;
            let zp: f32 = layer.forward(&a).data.iter().zip(&co.data).map(|(z, c)| z * c).sum();
            *a.at_mut(probe.0, probe.1) = orig - eps;
            let zm: f32 = layer.forward(&a).data.iter().zip(&co.data).map(|(z, c)| z * c).sum();
            *a.at_mut(probe.0, probe.1) = orig;
            let fd = (zp - zm) / (2.0 * eps);
            let ad = da.at(probe.0, probe.1);
            assert!((fd - ad).abs() < 2e-2 * (1.0 + fd.abs()), "{fd} vs {ad}");
        }
    }

    #[test]
    fn tiled_backward_modes_agree() {
        let l = mk(LayerKind::HashedTile { k: 80, tile: (8, 8) }, 12, 30);
        let mut rng = Pcg32::new(11, 11);
        let a = rand_matrix(5, 12, &mut rng);
        let co = rand_matrix(5, 30, &mut rng);
        let run = |opts: &TrainOptions| {
            let mut g = vec![0.0f32; l.params.len()];
            let da = l.backward(&a, &co, &mut g, opts);
            (g, da)
        };
        // fast mode: threaded within float tolerance of serial
        let (g1, da1) = run(&TrainOptions::default());
        let (g4, da4) = run(&TrainOptions::with_threads(4));
        for (x, y) in g1.iter().zip(&g4).chain(da1.data.iter().zip(&da4.data)) {
            assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
        }
        // ordered mode: ∂w and ∂a bit-identical across thread counts
        let ordered = |t: usize| TrainOptions { threads: t, block_rows: 8, deterministic: true };
        let (go1, dao1) = run(&ordered(1));
        for t in [2usize, 4, 8] {
            let (got, daot) = run(&ordered(t));
            assert_eq!(
                go1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "∂w t{t}"
            );
            assert_eq!(
                dao1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                daot.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "∂a t{t}"
            );
        }
    }

    #[test]
    fn tiled_weight_sharing_shares_runs() {
        // k = 8 with 1×8 tiles → at most 8 distinct |values| in V
        let l = mk(LayerKind::HashedTile { k: 8, tile: (1, 8) }, 8, 8);
        let v = l.virtual_matrix();
        let mut mags: Vec<u32> = v.data.iter().map(|x| x.abs().to_bits()).collect();
        mags.sort_unstable();
        mags.dedup();
        assert!(mags.len() <= 8, "found {} distinct magnitudes", mags.len());
    }

    #[test]
    fn tile_plan_is_shared_across_clones() {
        let l = mk(LayerKind::HashedTile { k: 10, tile: (1, 8) }, 6, 4);
        let l2 = l.clone();
        assert!(Arc::ptr_eq(l.tile_plan().unwrap(), l2.tile_plan().unwrap()));
        assert!(l.plan().is_none(), "tiled layers carry no per-cell plan");
    }

    #[test]
    fn gradients_lowrank() {
        finite_diff_check(mk(LayerKind::LowRank { r: 3 }, 7, 5));
    }

    #[test]
    fn input_gradient_matches_fd() {
        let layer = mk(LayerKind::Hashed { k: 9 }, 6, 4);
        let mut rng = Pcg32::new(3, 3);
        let mut a = rand_matrix(2, 6, &mut rng);
        let co = rand_matrix(2, 4, &mut rng);
        let mut grad = vec![0.0f32; layer.params.len()];
        let da = layer.backward(&a.clone(), &co, &mut grad, &TrainOptions::default());
        let eps = 1e-2f32;
        for probe in [(0usize, 0usize), (1, 3), (0, 5)] {
            let orig = a.at(probe.0, probe.1);
            *a.at_mut(probe.0, probe.1) = orig + eps;
            let zp: f32 = layer.forward(&a).data.iter().zip(&co.data).map(|(z, c)| z * c).sum();
            *a.at_mut(probe.0, probe.1) = orig - eps;
            let zm: f32 = layer.forward(&a).data.iter().zip(&co.data).map(|(z, c)| z * c).sum();
            *a.at_mut(probe.0, probe.1) = orig;
            let fd = (zp - zm) / (2.0 * eps);
            let ad = da.at(probe.0, probe.1);
            assert!((fd - ad).abs() < 2e-2 * (1.0 + fd.abs()), "{fd} vs {ad}");
        }
    }

    #[test]
    fn threaded_backward_modes_agree() {
        let l = mk(LayerKind::Hashed { k: 40 }, 12, 30);
        let mut rng = Pcg32::new(11, 11);
        let a = rand_matrix(5, 12, &mut rng);
        let co = rand_matrix(5, 30, &mut rng);
        let run = |opts: &TrainOptions| {
            let mut g = vec![0.0f32; l.params.len()];
            let da = l.backward(&a, &co, &mut g, opts);
            (g, da)
        };
        // fast mode: threaded within float tolerance of serial
        let (g1, da1) = run(&TrainOptions::default());
        let (g4, da4) = run(&TrainOptions::with_threads(4));
        for (x, y) in g1.iter().zip(&g4).chain(da1.data.iter().zip(&da4.data)) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()), "{x} vs {y}");
        }
        // ordered mode: bit-identical across thread counts (multi-block
        // partition forced via a small block height)
        let ordered = |t: usize| TrainOptions { threads: t, block_rows: 8, deterministic: true };
        let (go1, dao1) = run(&ordered(1));
        let (go4, dao4) = run(&ordered(4));
        assert_eq!(
            go1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            go4.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            dao1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            dao4.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn masked_layer_keeps_roughly_k_edges() {
        let (m, n, k) = (20usize, 15usize, 60usize);
        let l = mk(LayerKind::Masked { k }, m, n);
        let v = l.virtual_matrix();
        let nz = v.data.iter().filter(|&&x| x != 0.0).count();
        assert!((nz as f32 - k as f32).abs() < 0.35 * k as f32, "nz={nz}");
        assert_eq!(l.n_stored(), k);
    }

    #[test]
    fn lowrank_matrix_has_rank_r() {
        let l = mk(LayerKind::LowRank { r: 2 }, 9, 7);
        let v = l.virtual_matrix(); // 7×10, rank ≤ 2
        // crude rank check: any 3 rows are linearly dependent → the
        // 3rd singular-ish direction vanishes. Use Gram determinant.
        let rows = [v.row(0), v.row(2), v.row(5)];
        let gram: Vec<f32> = (0..3)
            .flat_map(|i| (0..3).map(move |j| (i, j)))
            .map(|(i, j)| rows[i].iter().zip(rows[j]).map(|(a, b)| a * b).sum())
            .collect();
        let det = gram[0] * (gram[4] * gram[8] - gram[5] * gram[7])
            - gram[1] * (gram[3] * gram[8] - gram[5] * gram[6])
            + gram[2] * (gram[3] * gram[7] - gram[4] * gram[6]);
        let scale = gram[0] * gram[4] * gram[8] + 1e-6;
        assert!((det / scale).abs() < 1e-3, "rank>2? det/scale={}", det / scale);
    }
}
